//! Micro-benchmarks on the L3 hot paths (custom harness; criterion is not
//! available offline). Run: `cargo bench --bench micro`.
//!
//! These are the §Perf instruments: service API throughput (the paper's
//! "response time largely consistent with respect to increasing number of
//! submitted Jobs" claim, §4.5), DES engine event rate, store index
//! lookups vs scans, JSON codec, and HTTP round-trip latency.

use std::time::Instant;

use balsam::service::api::{ApiRequest, JobCreate, JobFilter};
use balsam::service::models::JobState;
use balsam::service::ServiceCore;
use balsam::util::json::Json;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.min(3) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per < 1e-3 {
        (per * 1e6, "us")
    } else if per < 1.0 {
        (per * 1e3, "ms")
    } else {
        (per, "s")
    };
    println!("{name:<56} {val:>10.2} {unit}/iter  ({iters} iters)");
    per
}

fn setup_service(n_jobs: usize) -> (ServiceCore, String, balsam::service::models::SiteId) {
    let svc = ServiceCore::new(b"bench");
    let tok = svc.admin_token();
    let site = svc
        .handle(0.0, &tok, ApiRequest::CreateSite {
            name: "theta".into(),
            hostname: "h".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    svc.handle(0.0, &tok, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();
    let jobs: Vec<JobCreate> = (0..n_jobs)
        .map(|i| {
            let mut jc = JobCreate::simple(site, "MD", "md_small");
            jc.transfers_in = vec![("APS".into(), 1000)];
            jc.tags = vec![("batch".into(), (i / 100).to_string())];
            jc
        })
        .collect();
    svc.handle(0.1, &tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
    (svc, tok, site)
}

fn main() {
    println!("== micro benches (L3 hot paths) ==");

    // Bulk job creation (the client burst path).
    bench("service: bulk-create 1000 jobs", 20, || {
        let _ = setup_service(1000);
    });

    // Session acquire against a large runnable backlog — the paper's
    // indexed-queries claim: latency must not grow with backlog size.
    for &backlog in &[1_000usize, 10_000, 50_000] {
        let (svc, tok, site) = setup_service(backlog);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        bench(&format!("service: acquire 32 of {backlog}-job backlog"), 200, || {
            let got = svc
                .handle(2.0, &tok, ApiRequest::SessionAcquire {
                    session: sid,
                    max_nodes: 32,
                    max_jobs: 32,
                })
                .unwrap()
                .jobs();
            // Release so the next iteration re-acquires.
            std::hint::black_box(&got);
            for j in got {
                svc.store.with_job_mut(j.id, |j| j.session = None).unwrap();
            }
            svc.store.with_session_mut(sid, |s| s.acquired.clear()).unwrap();
        });
    }

    // Backlog aggregation (shortest-backlog client polls this per batch).
    let (svc, tok, site) = setup_service(50_000);
    bench("service: SiteBacklog over 50k jobs", 200, || {
        let _ = std::hint::black_box(svc.handle(2.0, &tok, ApiRequest::SiteBacklog { site }));
    });

    // Indexed filter query vs tag scan.
    bench("service: indexed ListJobs(state, limit 64) of 50k", 200, || {
        let _ = svc.handle(2.0, &tok, ApiRequest::ListJobs {
            filter: JobFilter {
                site: Some(site),
                states: vec![JobState::Ready],
                limit: 64,
                ..Default::default()
            },
        });
    });

    // Pending-transfer query (transfer module tick path).
    bench("service: PendingTransferItems(limit 512) of 50k", 200, || {
        let _ = svc.handle(2.0, &tok, ApiRequest::PendingTransferItems {
            site,
            direction: balsam::service::models::Direction::In,
            limit: 512,
        });
    });

    // JSON codec on a bulk-create payload.
    let payload = balsam::service::http_gw::request_to_json(&ApiRequest::BulkCreateJobs {
        jobs: (0..100)
            .map(|_| {
                let mut jc = JobCreate::simple(site, "MD", "md_small");
                jc.transfers_in = vec![("APS".into(), 200_000_000)];
                jc
            })
            .collect(),
    })
    .to_string();
    println!("json payload: {} bytes", payload.len());
    bench("json: parse 100-job bulk-create", 500, || {
        let _ = std::hint::black_box(Json::parse(&payload).unwrap());
    });

    // HTTP round trip on loopback: dial-per-request vs one persistent
    // connection (the transport win keep-alive buys on the hot path).
    use balsam::service::api::ApiConn;
    use balsam::util::httpd::HttpConfig;
    let svc2 = std::sync::Arc::new(ServiceCore::new(b"bench"));
    let tok2 = svc2.admin_token();
    let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let server =
        balsam::service::http_gw::serve_with(svc2, "127.0.0.1:0", 4, ka.clone()).unwrap();
    let addr = server.addr.clone();
    bench("http: API round trip (new connection each)", 300, || {
        let no_ka = HttpConfig { keep_alive: false, ..HttpConfig::default() };
        let mut conn = balsam::service::http_gw::HttpConn::with_config(addr.clone(), no_ka);
        let _ = std::hint::black_box(conn.api(&tok2, ApiRequest::ListEvents { since: 0 }));
    });
    let mut conn = balsam::service::http_gw::HttpConn::with_config(addr.clone(), ka);
    bench("http: API round trip (keep-alive)", 300, || {
        let _ = std::hint::black_box(conn.api(&tok2, ApiRequest::ListEvents { since: 0 }));
    });
    server.stop();

    // DES engine raw wake rate.
    {
        use balsam::sim::{Actor, Engine};
        use balsam::world::World;
        struct Nop;
        impl Actor for Nop {
            fn name(&self) -> String {
                "nop".into()
            }
            fn wake(&mut self, now: f64, _w: &mut World) -> f64 {
                now + 1.0
            }
        }
        bench("sim: 1M actor wakes", 5, || {
            let mut eng = Engine::new();
            let mut world = World::for_tests();
            for _ in 0..10 {
                eng.add(Box::new(Nop));
            }
            eng.run_until(&mut world, 100_000.0);
        });
    }

    // End-to-end simulated experiment wall time (the repro harness cost).
    bench("sim: fig9 single panel (600 simulated s)", 3, || {
        let _ = std::hint::black_box(balsam::experiments::fig9::panel(&["APS"], 600.0, 1));
    });
    println!("\nmicro benches done");
}
