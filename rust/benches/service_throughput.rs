//! Service-throughput bench (custom harness; criterion is not available
//! offline). Run: `cargo bench --bench service_throughput` — quick mode
//! via `BENCH_QUICK=1` (the CI bench-smoke job).
//!
//! Drives N concurrent simulated launcher sessions over the HTTP gateway
//! against the sharded service and reports aggregate req/s — the paper's
//! §4.5 scalability instrument. Three axes are swept:
//!
//! * **gateway workers** (1 vs 8): store-shard + worker-pool scaling;
//! * **transport** (per-request connections vs HTTP/1.1 keep-alive): the
//!   connection-persistence win — each launcher session holding one
//!   pooled connection vs dialing per call;
//! * **fsync policy** (WAL flush-to-OS vs group commit vs fsync-always):
//!   the durability tax, and how much of it group commit buys back.
//!
//! Each launcher cycle is the bulk protocol: BulkCreateJobs ->
//! SessionAcquire -> BulkUpdateJobState(RUNNING) -> SessionSync(RUN_DONE +
//! POSTPROCESSED). Results are recorded in `BENCH_service.json` (override
//! the path with `BENCH_OUT`) so the perf trajectory is tracked across
//! PRs; `bench_trend.py` gates on the peak req/s per (transport, persist,
//! fsync) combination.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use balsam::service::api::{ApiConn, ApiRequest, JobCreate};
use balsam::service::http_gw::{serve_with, HttpConn};
use balsam::service::models::{JobId, JobState, SiteId};
use balsam::service::{EventLogConfig, FsyncPolicy, PersistMode, ServiceCore};
use balsam::util::httpd::HttpConfig;
use balsam::util::json::Json;

const SITES: usize = 4;
const CLIENTS: usize = 8;

struct PassResult {
    workers: usize,
    transport: &'static str,
    persist: &'static str,
    /// "none" (ephemeral) / "flush" / "group" / "always".
    fsync: &'static str,
    reqs: u64,
    secs: f64,
    reqs_per_s: f64,
}

fn run_pass(
    workers: usize,
    keep_alive: bool,
    secs: f64,
    wal: Option<(PathBuf, FsyncPolicy)>,
) -> PassResult {
    let transport = if keep_alive { "keepalive" } else { "per-request" };
    let persist = if wal.is_some() { "wal" } else { "ephemeral" };
    let fsync = wal.as_ref().map(|(_, f)| f.label()).unwrap_or("none");
    let wal_dir = wal.as_ref().map(|(d, _)| d.clone());
    let mode = match &wal {
        Some((dir, policy)) => {
            let _ = std::fs::remove_dir_all(dir);
            PersistMode::Wal {
                dir: dir.clone(),
                snapshot_every: 4096,
                fsync: *policy,
                events: EventLogConfig::default(),
            }
        }
        None => PersistMode::Ephemeral,
    };
    let http = HttpConfig { keep_alive, ..HttpConfig::default() };
    let svc = Arc::new(ServiceCore::with_persist(b"bench", mode).expect("open store"));
    let tok = svc.admin_token();
    let sites: Vec<SiteId> = (0..SITES)
        .map(|i| {
            let site = svc
                .handle(0.0, &tok, ApiRequest::CreateSite {
                    name: format!("site{i}"),
                    hostname: format!("host{i}"),
                    path: "/p".into(),
                })
                .unwrap()
                .site_id();
            svc.handle(0.0, &tok, ApiRequest::RegisterApp {
                site,
                name: "MD".into(),
                command_template: "md".into(),
                parameters: vec![],
            })
            .unwrap();
            site
        })
        .collect();
    let server = serve_with(svc.clone(), "127.0.0.1:0", workers, http.clone()).unwrap();

    let reqs = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let threads: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = server.addr.clone();
            let tok = tok.clone();
            let site = sites[c % SITES];
            let reqs = reqs.clone();
            let stop = stop.clone();
            let http = http.clone();
            std::thread::spawn(move || {
                // One persistent authenticated connection per launcher
                // session (or a dial per call in per-request mode).
                let mut conn = HttpConn::with_config(addr, http);
                let mut api = |req: ApiRequest| {
                    reqs.fetch_add(1, Ordering::Relaxed);
                    conn.api(&tok, req)
                };
                let sid = api(ApiRequest::CreateSession { site, batch_job: None })
                    .unwrap()
                    .session_id();
                while !stop.load(Ordering::Relaxed) {
                    // One launcher heartbeat cycle, all bulk calls.
                    let jobs: Vec<JobCreate> =
                        (0..4).map(|_| JobCreate::simple(site, "MD", "md_small")).collect();
                    api(ApiRequest::BulkCreateJobs { jobs }).unwrap();
                    let got = api(ApiRequest::SessionAcquire {
                        session: sid,
                        max_nodes: 1_000_000,
                        max_jobs: 4,
                    })
                    .unwrap()
                    .jobs();
                    if got.is_empty() {
                        continue;
                    }
                    let ids: Vec<JobId> = got.iter().map(|j| j.id).collect();
                    api(ApiRequest::BulkUpdateJobState {
                        jobs: ids.clone(),
                        to: JobState::Running,
                        data: String::new(),
                    })
                    .unwrap();
                    let updates = ids
                        .iter()
                        .flat_map(|&j| {
                            [
                                (j, JobState::RunDone, String::new()),
                                (j, JobState::Postprocessed, String::new()),
                            ]
                        })
                        .collect();
                    api(ApiRequest::SessionSync { session: sid, updates }).unwrap();
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let n = reqs.load(Ordering::Relaxed);
    server.stop();
    if let Some(dir) = wal_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    PassResult { workers, transport, persist, fsync, reqs: n, secs: dt, reqs_per_s: n as f64 / dt }
}

fn print_pass(r: &PassResult) {
    println!(
        "workers {:>2} | {:>11} | {:>9}/{:<6}: {:>7} reqs in {:.2}s  ->  {:>8.0} req/s",
        r.workers, r.transport, r.persist, r.fsync, r.reqs, r.secs, r.reqs_per_s
    );
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let secs = if quick { 1.5 } else { 6.0 };
    println!(
        "== service_throughput: {CLIENTS} concurrent launcher sessions over {SITES} site shards \
         ({secs}s per pass{}) ==",
        if quick { ", quick" } else { "" }
    );
    let mut results = Vec::new();
    // Worker scaling on the per-request transport (the historical
    // baseline), then the keep-alive transport at 8 workers.
    for (workers, keep_alive) in [(1usize, false), (8, false), (8, true)] {
        let r = run_pass(workers, keep_alive, secs, None);
        print_pass(&r);
        results.push(r);
    }
    let speedup = results[1].reqs_per_s / results[0].reqs_per_s.max(1e-9);
    let ka_speedup = results[2].reqs_per_s / results[1].reqs_per_s.max(1e-9);
    println!("aggregate speedup at 8 workers vs 1 (per-request): {speedup:.2}x");
    println!("keep-alive speedup at 8 workers vs per-request: {ka_speedup:.2}x");

    // Durability tax: the same 8-worker keep-alive traffic with the
    // per-shard WAL on, across the fsync-policy axis — flush-to-OS, group
    // commit (the ISSUE 4 acceptance leg), and fsync-per-append.
    let wal_dir = std::env::temp_dir().join(format!("balsam-bench-wal-{}", std::process::id()));
    let policies = [
        FsyncPolicy::Never,
        FsyncPolicy::Group { records: 64, interval_ms: 2 },
        FsyncPolicy::Always,
    ];
    for policy in policies {
        let r = run_pass(8, true, secs, Some((wal_dir.clone(), policy)));
        print_pass(&r);
        println!(
            "wal/{} tax: {:.0}% of ephemeral keep-alive throughput",
            r.fsync,
            100.0 * r.reqs_per_s / results[2].reqs_per_s.max(1e-9)
        );
        results.push(r);
    }
    let flush_rps = results[3].reqs_per_s;
    let group_rps = results[4].reqs_per_s;
    let group_vs_flush = group_rps / flush_rps.max(1e-9);
    println!(
        "group-commit vs flush-only WAL: {:.2}x ({:.0}% — acceptance floor 75%)",
        group_vs_flush,
        100.0 * group_vs_flush
    );

    let out = Json::obj(vec![
        ("bench", Json::str("service_throughput")),
        ("quick", Json::Bool(quick)),
        ("sites", Json::num(SITES as f64)),
        ("client_threads", Json::num(CLIENTS as f64)),
        ("secs_per_pass", Json::num(secs)),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("gateway_workers", Json::num(r.workers as f64)),
                            ("transport", Json::str(r.transport)),
                            ("persist", Json::str(r.persist)),
                            ("fsync", Json::str(r.fsync)),
                            ("reqs", Json::num(r.reqs as f64)),
                            ("secs", Json::num(r.secs)),
                            ("reqs_per_s", Json::num(r.reqs_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_8_vs_1", Json::num(speedup)),
        ("keepalive_speedup_8workers", Json::num(ka_speedup)),
        ("group_commit_vs_flush", Json::num(group_vs_flush)),
    ]);
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_service.json".to_string());
    std::fs::write(&path, out.to_string()).expect("write bench record");
    println!("recorded {path}");
}
