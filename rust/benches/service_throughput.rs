//! Service-throughput bench (custom harness; criterion is not available
//! offline). Run: `cargo bench --bench service_throughput` — quick mode
//! via `BENCH_QUICK=1` (the CI bench-smoke job).
//!
//! Drives N concurrent simulated launcher sessions over the HTTP gateway
//! against the sharded service and reports aggregate req/s — the paper's
//! §4.5 scalability instrument. Three axes are swept:
//!
//! * **gateway workers** (1 vs 8): store-shard + worker-pool scaling;
//! * **transport** (per-request connections vs HTTP/1.1 keep-alive): the
//!   connection-persistence win — each launcher session holding one
//!   pooled connection vs dialing per call;
//! * **fsync policy** (WAL flush-to-OS vs group commit vs fsync-always):
//!   the durability tax, and how much of it group commit buys back;
//! * **metrics** (recording on vs `--no-metrics`-style off): the
//!   observability overhead on the hottest leg (keep-alive + group-commit
//!   WAL) — `bench_trend.py` gates it at <= 5%;
//! * **codec** (JSON envelopes vs binary frames): the wire-serialization
//!   tax on the same sync-heavy durable leg — `bench_trend.py` gates
//!   binary >= 1.5x the JSON sibling in-run.
//!
//! Each launcher cycle is the bulk protocol: BulkCreateJobs ->
//! SessionAcquire -> BulkUpdateJobState(RUNNING) -> SessionSync(RUN_DONE +
//! POSTPROCESSED). Results are recorded in `BENCH_service.json` (override
//! the path with `BENCH_OUT`) so the perf trajectory is tracked across
//! PRs; `bench_trend.py` gates on the peak req/s per (transport, persist,
//! fsync, codec, metrics) combination.
//!
//! A fourth axis measures **stage-in propagation latency**: the time from
//! a transfer-completion RPC landing at the service to an observer
//! noticing the job turned PREPROCESSED — once with a `ListEvents` poll
//! loop (the paper's site behaviour; latency ~ half the poll period) and
//! once with a hanging `WatchEvents` subscription (push mode; latency ~
//! one wakeup). Recorded under `"propagation"` in `BENCH_service.json`;
//! `bench_trend.py` gates push < poll and the push latency trend.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use balsam::service::api::{ApiConn, ApiRequest, JobCreate};
use balsam::service::http_gw::{serve_with, HttpConn};
use balsam::service::models::{JobId, JobState, SiteId};
use balsam::service::{EventLogConfig, FsyncPolicy, PersistMode, ServiceCore, Wire};
use balsam::util::httpd::HttpConfig;
use balsam::util::json::Json;

const SITES: usize = 4;
const CLIENTS: usize = 8;

struct PassResult {
    workers: usize,
    transport: &'static str,
    persist: &'static str,
    /// "none" (ephemeral) / "flush" / "group" / "always".
    fsync: &'static str,
    /// "json" / "binary" — the wire codec the launcher sessions spoke.
    codec: &'static str,
    /// "on" / "off" — whether metric recording was enabled for the pass.
    metrics: &'static str,
    reqs: u64,
    secs: f64,
    reqs_per_s: f64,
}

fn run_pass(
    workers: usize,
    keep_alive: bool,
    secs: f64,
    wal: Option<(PathBuf, FsyncPolicy)>,
    wire: Wire,
    metrics_on: bool,
) -> PassResult {
    // The registry is process-global; restore recording after the pass so
    // later passes (and the propagation legs) stay instrumented.
    balsam::util::metrics::set_enabled(metrics_on);
    let transport = if keep_alive { "keepalive" } else { "per-request" };
    let persist = if wal.is_some() { "wal" } else { "ephemeral" };
    let fsync = wal.as_ref().map(|(_, f)| f.label()).unwrap_or("none");
    let codec = wire.label();
    let metrics = if metrics_on { "on" } else { "off" };
    let wal_dir = wal.as_ref().map(|(d, _)| d.clone());
    let mode = match &wal {
        Some((dir, policy)) => {
            let _ = std::fs::remove_dir_all(dir);
            PersistMode::Wal {
                dir: dir.clone(),
                snapshot_every: 4096,
                fsync: *policy,
                events: EventLogConfig::default(),
            }
        }
        None => PersistMode::Ephemeral,
    };
    let http = HttpConfig { keep_alive, ..HttpConfig::default() };
    let svc = Arc::new(ServiceCore::with_persist(b"bench", mode).expect("open store"));
    let tok = svc.admin_token();
    let sites: Vec<SiteId> = (0..SITES)
        .map(|i| {
            let site = svc
                .handle(0.0, &tok, ApiRequest::CreateSite {
                    name: format!("site{i}"),
                    hostname: format!("host{i}"),
                    path: "/p".into(),
                })
                .unwrap()
                .site_id();
            svc.handle(0.0, &tok, ApiRequest::RegisterApp {
                site,
                name: "MD".into(),
                command_template: "md".into(),
                parameters: vec![],
            })
            .unwrap();
            site
        })
        .collect();
    let server = serve_with(svc.clone(), "127.0.0.1:0", workers, http.clone()).unwrap();

    let reqs = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let threads: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = server.addr.clone();
            let tok = tok.clone();
            let site = sites[c % SITES];
            let reqs = reqs.clone();
            let stop = stop.clone();
            let http = http.clone();
            std::thread::spawn(move || {
                // One persistent authenticated connection per launcher
                // session (or a dial per call in per-request mode). The
                // wire codec is pinned explicitly so pass labels stay
                // truthful regardless of the ambient BALSAM_WIRE.
                let mut conn = HttpConn::with_wire(addr, http, wire);
                let mut api = |req: ApiRequest| {
                    reqs.fetch_add(1, Ordering::Relaxed);
                    conn.api(&tok, req)
                };
                let sid = api(ApiRequest::CreateSession { site, batch_job: None })
                    .unwrap()
                    .session_id();
                while !stop.load(Ordering::Relaxed) {
                    // One launcher heartbeat cycle, all bulk calls.
                    let jobs: Vec<JobCreate> =
                        (0..4).map(|_| JobCreate::simple(site, "MD", "md_small")).collect();
                    api(ApiRequest::BulkCreateJobs { jobs }).unwrap();
                    let got = api(ApiRequest::SessionAcquire {
                        session: sid,
                        max_nodes: 1_000_000,
                        max_jobs: 4,
                    })
                    .unwrap()
                    .jobs();
                    if got.is_empty() {
                        continue;
                    }
                    let ids: Vec<JobId> = got.iter().map(|j| j.id).collect();
                    api(ApiRequest::BulkUpdateJobState {
                        jobs: ids.clone(),
                        to: JobState::Running,
                        data: String::new(),
                    })
                    .unwrap();
                    let updates = ids
                        .iter()
                        .flat_map(|&j| {
                            [
                                (j, JobState::RunDone, String::new()),
                                (j, JobState::Postprocessed, String::new()),
                            ]
                        })
                        .collect();
                    api(ApiRequest::SessionSync { session: sid, updates }).unwrap();
                }
            })
        })
        .collect();
    std::thread::sleep(Duration::from_secs_f64(secs));
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        t.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let n = reqs.load(Ordering::Relaxed);
    server.stop();
    if let Some(dir) = wal_dir {
        let _ = std::fs::remove_dir_all(dir);
    }
    balsam::util::metrics::set_enabled(true);
    PassResult {
        workers,
        transport,
        persist,
        fsync,
        codec,
        metrics,
        reqs: n,
        secs: dt,
        reqs_per_s: n as f64 / dt,
    }
}

fn print_pass(r: &PassResult) {
    println!(
        "workers {:>2} | {:>11} | {:>9}/{:<6} | {:>6} | metrics {:<3}: {:>7} reqs in {:.2}s  \
         ->  {:>8.0} req/s",
        r.workers, r.transport, r.persist, r.fsync, r.codec, r.metrics, r.reqs, r.secs,
        r.reqs_per_s
    );
}

/// Observer poll period for the propagation baseline (ms). Short relative
/// to the paper's multi-second site poll periods, so the recorded poll
/// latency is a conservative lower bound on what push mode beats.
const PROP_POLL_MS: u64 = 25;

struct PropResult {
    mode: &'static str,
    iters: usize,
    avg_ms: f64,
    p95_ms: f64,
}

/// One stage-in propagation pass: for `iters` jobs, measure the time from
/// the `UpdateTransferItems(Done)` RPC to an independent observer (its own
/// HTTP connection) seeing the job's PREPROCESSED event — via a
/// `WatchEvents` long poll (push) or a `ListEvents` + sleep loop (poll).
fn run_propagation(push: bool, iters: usize) -> PropResult {
    use balsam::service::models::{Direction, TransferState};

    let http = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let svc = Arc::new(ServiceCore::new(b"bench-prop"));
    let tok = svc.admin_token();
    let site = svc
        .handle(0.0, &tok, ApiRequest::CreateSite {
            name: "prop".into(),
            hostname: "h".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    svc.handle(0.0, &tok, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();
    let server = serve_with(svc.clone(), "127.0.0.1:0", 4, http.clone()).unwrap();
    let mut producer = HttpConn::with_config(server.addr.clone(), http.clone());

    let mut lat_ms: Vec<f64> = Vec::with_capacity(iters);
    let mut cursor: usize = 0;
    for _ in 0..iters {
        let mut jc = JobCreate::simple(site, "MD", "md_small");
        jc.transfers_in = vec![("APS".into(), 1_000)];
        let job = producer
            .api(&tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] })
            .unwrap()
            .job_ids()[0];
        let item = producer
            .api(&tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items()
            .into_iter()
            .find(|t| t.job_id == job)
            .expect("created item is pending");
        // Consume the creation events so the observer arms on the
        // completion alone.
        let page = producer
            .api(&tok, ApiRequest::ListEvents { since: cursor })
            .unwrap()
            .events_page();
        if let Some(last) = page.events.last() {
            cursor = last.seq as usize + 1;
        }
        let (tx, rx) = std::sync::mpsc::channel::<Instant>();
        let (addr, otok, ohttp, since) = (server.addr.clone(), tok.clone(), http.clone(), cursor);
        let observer = std::thread::spawn(move || {
            let mut conn = HttpConn::with_config(addr, ohttp);
            loop {
                let page = if push {
                    conn.api(&otok, ApiRequest::WatchEvents {
                        site: Some(site),
                        since,
                        timeout_ms: 2_000,
                        max_events: 0,
                    })
                } else {
                    std::thread::sleep(Duration::from_millis(PROP_POLL_MS));
                    conn.api(&otok, ApiRequest::ListEvents { since })
                }
                .unwrap()
                .events_page();
                if page.events.iter().any(|e| e.job_id == job && e.to == JobState::Preprocessed) {
                    let _ = tx.send(Instant::now());
                    return;
                }
            }
        });
        // Give the push observer time to arm its watch (an un-armed
        // watch still sees the events — this only reduces jitter).
        std::thread::sleep(Duration::from_millis(5));
        let t0 = Instant::now();
        producer
            .api(&tok, ApiRequest::UpdateTransferItems {
                ids: vec![item.id],
                state: TransferState::Done,
                task_id: None,
            })
            .unwrap();
        let seen = rx.recv().expect("observer died");
        observer.join().unwrap();
        lat_ms.push(seen.duration_since(t0).as_secs_f64() * 1e3);
    }
    server.stop();
    let avg_ms = lat_ms.iter().sum::<f64>() / lat_ms.len() as f64;
    // Nearest-rank p95 (ceil(0.95 * n)-th smallest, 1-based): 20 samples
    // report the 19th value, not the maximum. Shared with the loadgen SLO
    // verdicts so every p95 in the bench record means the same thing.
    let p95_ms = balsam::util::stats::percentile_nearest_rank(&lat_ms, 95.0);
    PropResult { mode: if push { "push" } else { "poll" }, iters, avg_ms, p95_ms }
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let secs = if quick { 1.5 } else { 6.0 };
    println!(
        "== service_throughput: {CLIENTS} concurrent launcher sessions over {SITES} site shards \
         ({secs}s per pass{}) ==",
        if quick { ", quick" } else { "" }
    );
    let mut results = Vec::new();
    // Worker scaling on the per-request transport (the historical
    // baseline), then the keep-alive transport at 8 workers.
    for (workers, keep_alive) in [(1usize, false), (8, false), (8, true)] {
        let r = run_pass(workers, keep_alive, secs, None, Wire::Json, true);
        print_pass(&r);
        results.push(r);
    }
    let speedup = results[1].reqs_per_s / results[0].reqs_per_s.max(1e-9);
    let ka_speedup = results[2].reqs_per_s / results[1].reqs_per_s.max(1e-9);
    println!("aggregate speedup at 8 workers vs 1 (per-request): {speedup:.2}x");
    println!("keep-alive speedup at 8 workers vs per-request: {ka_speedup:.2}x");

    // Durability tax: the same 8-worker keep-alive traffic with the
    // per-shard WAL on, across the fsync-policy axis — flush-to-OS, group
    // commit (the ISSUE 4 acceptance leg), and fsync-per-append.
    let wal_dir = std::env::temp_dir().join(format!("balsam-bench-wal-{}", std::process::id()));
    let policies = [
        FsyncPolicy::Never,
        FsyncPolicy::Group { records: 64, interval_ms: 2 },
        FsyncPolicy::Always,
    ];
    for policy in policies {
        let r = run_pass(8, true, secs, Some((wal_dir.clone(), policy)), Wire::Json, true);
        print_pass(&r);
        println!(
            "wal/{} tax: {:.0}% of ephemeral keep-alive throughput",
            r.fsync,
            100.0 * r.reqs_per_s / results[2].reqs_per_s.max(1e-9)
        );
        results.push(r);
    }
    let flush_rps = results[3].reqs_per_s;
    let group_rps = results[4].reqs_per_s;
    let group_vs_flush = group_rps / flush_rps.max(1e-9);
    println!(
        "group-commit vs flush-only WAL: {:.2}x ({:.0}% — acceptance floor 75%)",
        group_vs_flush,
        100.0 * group_vs_flush
    );

    // Metrics-overhead axis: re-run the hottest durable leg (keep-alive +
    // group-commit WAL) with recording off. bench_trend.py compares this
    // in-run pair and gates the overhead at <= 5%.
    let off = run_pass(
        8,
        true,
        secs,
        Some((wal_dir.clone(), FsyncPolicy::Group { records: 64, interval_ms: 2 })),
        Wire::Json,
        false,
    );
    print_pass(&off);
    let metrics_overhead = 1.0 - group_rps / off.reqs_per_s.max(1e-9);
    println!(
        "metrics recording overhead on keepalive/wal/group: {:.1}% (gate: <= 5%)",
        100.0 * metrics_overhead
    );
    results.push(off);

    // Wire-codec axis: the same sync-heavy durable leg (keep-alive +
    // group-commit WAL, the chatty interior path) with the binary frame
    // codec on every launcher connection. bench_trend.py pairs this with
    // the JSON sibling in-run and gates binary >= MIN_CODEC_SPEEDUP x.
    let bin = run_pass(
        8,
        true,
        secs,
        Some((wal_dir.clone(), FsyncPolicy::Group { records: 64, interval_ms: 2 })),
        Wire::Binary,
        true,
    );
    print_pass(&bin);
    let codec_speedup = bin.reqs_per_s / group_rps.max(1e-9);
    println!(
        "binary frame codec vs JSON on keepalive/wal/group: {codec_speedup:.2}x \
         (bench_trend gate: >= 1.5x)"
    );
    results.push(bin);

    // Propagation-latency axis: poll baseline vs push-mode subscription.
    let prop_iters = if quick { 20 } else { 60 };
    let poll = run_propagation(false, prop_iters);
    let push = run_propagation(true, prop_iters);
    for p in [&poll, &push] {
        println!(
            "stage-in propagation [{:>4}]: avg {:.2} ms, p95 {:.2} ms ({} iters)",
            p.mode, p.avg_ms, p.p95_ms, p.iters
        );
    }
    let push_vs_poll = poll.avg_ms / push.avg_ms.max(1e-9);
    println!("push-mode propagation speedup vs {PROP_POLL_MS}ms polling: {push_vs_poll:.1}x");

    // Open-loop capacity axis: the `balsam loadgen` sweep (see
    // src/loadgen/). Each combo ladders offered rps until a stop rule
    // (failure rate / median latency) trips and declares the max
    // sustainable rps — bench_trend.py gates that number per combo.
    println!("== loadgen: open-loop capacity sweep ==");
    let mut lg_cfg = balsam::loadgen::LoadgenConfig::quick();
    if !quick {
        // Full runs afford longer rungs and a second site count; the
        // ladder shape stays the quick one so the stop rule still trips.
        lg_cfg.step_secs = 1.5;
        lg_cfg.sites_list = vec![1, 4];
        lg_cfg.sessions_list = vec![4];
    }
    let loadgen_report = balsam::loadgen::run(&lg_cfg).expect("loadgen sweep");
    for c in &loadgen_report.combos {
        println!(
            "loadgen mix={:>6} sites={} sessions={}: max sustainable {:>8.0} rps ({})",
            c.mix.label(),
            c.sites,
            c.sessions,
            c.max_sustainable_rps,
            c.declared_by
        );
    }

    // End-to-end scenario axis: the "two beamlines x three sites" run
    // (src/scenario/), healthy (faults live in tests/scenario_realtime.rs
    // only). Records trigger-to-result latency for the push-mode client
    // against the in-run poll-mode baseline; bench_trend.py gates the
    // p95 ratio >= 3x and lost/duplicated results at zero.
    println!("== scenario: two beamlines x three sites, push vs poll client ==");
    let mut scn_cfg = balsam::scenario::ScenarioConfig::quick();
    if !quick {
        scn_cfg.batches = 4;
        scn_cfg.batch = 6;
        scn_cfg.deadline_s = 120.0;
    }
    let scenario_report = balsam::scenario::run(&scn_cfg).expect("scenario run");
    println!(
        "scenario trigger-to-result: push p95 {:.1} ms vs poll p95 {:.1} ms \
         ({:.1}x, poll period {:.0} ms; lost {}, duplicates {}, undelivered {})",
        scenario_report.push.p95_ms,
        scenario_report.poll.p95_ms,
        scenario_report.push_speedup_p95(),
        scenario_report.poll_period_ms,
        scenario_report.lost,
        scenario_report.duplicates,
        scenario_report.undelivered
    );

    let out = Json::obj(vec![
        ("bench", Json::str("service_throughput")),
        ("quick", Json::Bool(quick)),
        ("sites", Json::num(SITES as f64)),
        ("client_threads", Json::num(CLIENTS as f64)),
        ("secs_per_pass", Json::num(secs)),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("gateway_workers", Json::num(r.workers as f64)),
                            ("transport", Json::str(r.transport)),
                            ("persist", Json::str(r.persist)),
                            ("fsync", Json::str(r.fsync)),
                            ("codec", Json::str(r.codec)),
                            ("metrics", Json::str(r.metrics)),
                            ("reqs", Json::num(r.reqs as f64)),
                            ("secs", Json::num(r.secs)),
                            ("reqs_per_s", Json::num(r.reqs_per_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("speedup_8_vs_1", Json::num(speedup)),
        ("keepalive_speedup_8workers", Json::num(ka_speedup)),
        ("group_commit_vs_flush", Json::num(group_vs_flush)),
        ("metrics_overhead", Json::num(metrics_overhead)),
        ("codec_speedup_sync_heavy", Json::num(codec_speedup)),
        (
            "propagation",
            Json::obj(vec![
                ("poll_period_ms", Json::num(PROP_POLL_MS as f64)),
                ("iters", Json::num(prop_iters as f64)),
                ("poll_avg_ms", Json::num(poll.avg_ms)),
                ("poll_p95_ms", Json::num(poll.p95_ms)),
                ("push_avg_ms", Json::num(push.avg_ms)),
                ("push_p95_ms", Json::num(push.p95_ms)),
            ]),
        ),
        ("push_vs_poll_stagein", Json::num(push_vs_poll)),
        ("loadgen", loadgen_report.to_json()),
        ("scenario", scenario_report.to_json()),
    ]);
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_service.json".to_string());
    std::fs::write(&path, out.to_string()).expect("write bench record");
    println!("recorded {path}");
}
