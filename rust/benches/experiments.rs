//! Experiment regeneration bench: runs every table/figure harness in fast
//! mode and reports wall time per experiment. `cargo bench --bench
//! experiments` therefore regenerates the entire evaluation section.
//!
//! For publication-fidelity parameters run `balsam repro all` (no --fast).

use std::time::Instant;

fn main() {
    println!("== regenerating all paper tables/figures (fast mode) ==");
    let t_all = Instant::now();
    for id in balsam::experiments::ALL {
        let t0 = Instant::now();
        balsam::experiments::run(id, true, 2021).unwrap_or_else(|e| panic!("{id} failed: {e}"));
        println!("\n[{id} regenerated in {:.2}s]\n{}", t0.elapsed().as_secs_f64(), "-".repeat(72));
    }
    println!(
        "\nall {} experiments regenerated in {:.1}s",
        balsam::experiments::ALL.len(),
        t_all.elapsed().as_secs_f64()
    );
}
