//! Integration: full round-trip pipelines in simulated mode, across all
//! layers of the coordinator (service + site modules + substrates).

use balsam::client::{Strategy, Submission, WorkloadClient};
use balsam::experiments::common::deploy;
use balsam::metrics::{job_table, stage_durations, summarize_stage};
use balsam::service::api::{ApiRequest, JobCreate};
use balsam::service::models::JobState;

#[test]
fn three_site_federation_processes_mixed_workload() {
    let mut d = deploy(101, &["theta", "summit", "cori"], 32, |c| {
        c.elastic.block_nodes = 16;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = 3600.0;
    });
    let sites: Vec<_> = ["theta", "summit", "cori"].iter().map(|f| d.sites[*f]).collect();
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "EigenCorr",
        "xpcs",
        Strategy::RoundRobin(sites.clone()),
        Submission::Bursts { batch: 6, period: 10.0 },
        101,
    )
    .with_max_jobs(36);
    d.add_client(client);
    d.run_until(2400.0);
    let total: usize =
        sites.iter().map(|&s| d.svc().store.count_in_state(s, JobState::JobFinished)).sum();
    assert_eq!(total, 36, "every job must complete its round trip");
    // Events exist for every stage of every job.
    let jobs = job_table(d.svc());
    let durs = stage_durations(&d.svc().store.events(), &jobs);
    assert_eq!(summarize_stage(&durs, |d| d.time_to_solution).count(), 36);
    // Store indexes stayed coherent across thousands of transitions.
    d.svc().store.check_indexes().unwrap();
}

#[test]
fn dag_workflow_runs_in_dependency_order() {
    let mut d = deploy(102, &["cori"], 16, |c| {
        c.elastic.block_nodes = 8;
        c.elastic.max_nodes = 16;
    });
    let site = d.sites["cori"];
    let tok = d.token.clone();
    // Diamond DAG: a -> (b, c) -> d.
    let a = d
        .world
        .service
        .handle(0.0, &tok, ApiRequest::BulkCreateJobs {
            jobs: vec![JobCreate::simple(site, "MD", "md_small")],
        })
        .unwrap()
        .job_ids()[0];
    let mut mk = |parents: Vec<balsam::service::models::JobId>| {
        let mut jc = JobCreate::simple(site, "MD", "md_small");
        jc.parents = parents;
        d.world
            .service
            .handle(0.0, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] })
            .unwrap()
            .job_ids()[0]
    };
    let b = mk(vec![a]);
    let c = mk(vec![a]);
    let leaf = mk(vec![b, c]);
    d.run_until(1200.0);
    let svc = d.svc();
    for id in [a, b, c, leaf] {
        assert_eq!(svc.store.job(id).unwrap().state, JobState::JobFinished, "job {id}");
    }
    // Ordering: leaf started only after b and c finished.
    let evs = svc.store.events();
    let ts_of = |id, to| {
        evs.iter().find(|e| e.job_id == id && e.to == to).map(|e| e.ts).unwrap()
    };
    assert!(ts_of(leaf, JobState::Running) >= ts_of(b, JobState::JobFinished));
    assert!(ts_of(leaf, JobState::Running) >= ts_of(c, JobState::JobFinished));
    assert!(ts_of(b, JobState::Running) >= ts_of(a, JobState::JobFinished));
}

#[test]
fn deterministic_given_seed() {
    let run = |seed: u64| {
        let mut d = deploy(seed, &["theta"], 16, |c| {
            c.elastic.block_nodes = 16;
            c.elastic.max_nodes = 16;
        });
        let site = d.sites["theta"];
        let client = WorkloadClient::new(
            d.token.clone(),
            "APS",
            "MD",
            "md_small",
            Strategy::Single(site),
            Submission::SteadyBacklog { target: 16, period: 2.0 },
            seed,
        )
        .with_max_jobs(40);
        d.add_client(client);
        d.run_until(1500.0);
        let evs = d.svc().store.events();
        (evs.len(), evs.iter().map(|e| e.ts).sum::<f64>())
    };
    let (n1, s1) = run(777);
    let (n2, s2) = run(777);
    assert_eq!(n1, n2);
    assert!((s1 - s2).abs() < 1e-9, "event timestamps must be bit-identical");
    let (_, s3) = run(778);
    assert!((s1 - s3).abs() > 1e-6, "different seeds should differ");
}

#[test]
fn failure_injection_exhausts_retries_without_losing_others() {
    let mut d = deploy(103, &["cori"], 16, |c| {
        c.elastic.block_nodes = 16;
        c.elastic.max_nodes = 16;
    });
    let site = d.sites["cori"];
    // 30% of runs fail.
    d.world.execs.get_mut("cori").unwrap().fail_prob = 0.3;
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "MD",
        "md_small",
        Strategy::Single(site),
        Submission::Bursts { batch: 30, period: 1e9 },
        103,
    )
    .with_max_jobs(30);
    d.add_client(client);
    d.run_until(3000.0);
    let svc = d.svc();
    let finished = svc.store.count_in_state(site, JobState::JobFinished);
    let failed = svc.store.count_in_state(site, JobState::Failed);
    assert_eq!(finished + failed, 30, "every job must reach a terminal state");
    // With p=0.3 and 3 attempts, most jobs should eventually succeed
    // (P[fail all 3] ≈ 2.7%).
    assert!(finished >= 24, "finished={finished} failed={failed}");
    // Retry accounting: nothing exceeds its budget.
    for j in svc.store.jobs_snapshot() {
        assert!(j.attempts <= j.max_attempts);
    }
}
