//! The paper's "two beamlines × three sites" demo as a test contract.
//!
//! Every test here drives [`balsam::scenario::run`]: two
//! `ExperimentClient`s submit concurrent triggered batches over real
//! sockets against a durable WAL + group-fsync service with one
//! push-mode `SiteAgent` per facility. Service poll fallbacks are pinned
//! at 1e9 s inside the harness (transfer poll, launcher acquire, client
//! result poll in push mode), so everything that completes does so purely
//! push-driven through `WatchEvents` cursors.
//!
//! Legs:
//! 1. healthy run — both beamlines complete, push trigger-to-result p95
//!    beats the in-run poll-mode baseline;
//! 2. kill one site agent mid-batch — lease expiry re-routes its jobs and
//!    a replacement agent re-provisions via the elastic scaler, with zero
//!    lost and zero duplicated results;
//! 3. restart the service mid-run — WAL recovery on a fresh port; agent
//!    and client cursors resume gap-free (no truncations, no reconciling
//!    list fallbacks).
//!
//! `SCENARIO_TIGHT=1` (the CI scenario smoke leg) tightens the per-pass
//! deadlines so a wedged run fails fast instead of riding the job
//! timeout.

use std::sync::Mutex;

use balsam::scenario::{run, ScenarioConfig};

// The scenario spins up a gateway + three agent threads + two beamline
// threads per pass; serialize tests so wall-clock latency assertions
// aren't skewed by a sibling scenario's CPU load.
static SCN_LOCK: Mutex<()> = Mutex::new(());

fn deadline(tight: f64, loose: f64) -> f64 {
    if std::env::var("SCENARIO_TIGHT").is_ok_and(|v| v == "1") {
        tight
    } else {
        loose
    }
}

#[test]
fn two_beamlines_three_sites_complete_purely_push_driven() {
    let _g = SCN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = ScenarioConfig::quick();
    cfg.batches = 3;
    cfg.batch = 4;
    cfg.deadline_s = deadline(40.0, 90.0);
    let r = run(&cfg).expect("scenario run");

    // Every job of both passes reached JobFinished exactly once and every
    // completion callback fired.
    assert_eq!(r.jobs_per_mode, 24);
    assert_eq!(r.lost, 0, "service lost jobs: {r:?}");
    assert_eq!(r.duplicates, 0, "duplicated results: {r:?}");
    assert_eq!(r.undelivered, 0, "callbacks never fired: {r:?}");
    assert_eq!(r.push.n, r.jobs_per_mode);
    assert_eq!(r.poll.n, r.jobs_per_mode);

    // Pure push: with the fallback poll at 1e9 s, a healthy run never
    // needs a reconciling list and never sees a truncated cursor.
    assert_eq!(r.reconciles, 0, "push pass fell back to polling: {r:?}");
    assert_eq!(r.truncations, 0);
    assert_eq!(r.restarts, 0);

    // The measured contract: push-mode trigger-to-result p95 beats the
    // in-run poll-mode client by a wide margin (the release-build bench
    // gates the full >= 3x ratio via bench_trend.py; debug-build test
    // machines get headroom).
    assert!(
        r.push.p95_ms > 0.0 && r.poll.p95_ms > 0.0,
        "missing latency samples: {r:?}"
    );
    assert!(
        r.poll.p95_ms >= 2.0 * r.push.p95_ms,
        "push p95 {:.1} ms not well below poll p95 {:.1} ms",
        r.push.p95_ms,
        r.poll.p95_ms
    );
}

#[test]
fn killing_one_site_agent_mid_batch_loses_nothing() {
    let _g = SCN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = ScenarioConfig::quick();
    cfg.batches = 4;
    cfg.batch = 4;
    // No transfer items in this leg: a hard-killed agent cannot complete
    // its in-flight stage-ins, and re-assigning transfer work is the
    // TransferModule's (poll-driven) job, not the kill-fault contract
    // under test — which is compute re-routing via lease expiry +
    // elastic re-provisioning.
    cfg.stage_data = false;
    // Slow the runs down a little so the killed site holds Running jobs
    // (the interesting re-route: RunTimeout -> RestartReady -> re-run).
    cfg.run_s = 0.4;
    cfg.kill_site_mid_batch = Some(1);
    cfg.deadline_s = deadline(60.0, 120.0);
    let r = run(&cfg).expect("scenario run");

    assert_eq!(r.jobs_per_mode, 32);
    assert_eq!(r.lost, 0, "kill leg lost jobs: {r:?}");
    assert_eq!(r.duplicates, 0, "kill leg duplicated results: {r:?}");
    assert_eq!(r.undelivered, 0, "kill leg dropped callbacks: {r:?}");

    // The replacement agent actually took over the dead site: its elastic
    // module submitted at least one block for the stranded backlog.
    assert!(
        r.replacement_blocks > 0,
        "replacement agent never re-provisioned via elastic: {r:?}"
    );
}

#[test]
fn service_restart_mid_run_resumes_cursors_gap_free() {
    let _g = SCN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = ScenarioConfig::quick();
    cfg.batches = 3;
    cfg.batch = 4;
    cfg.restart_service_mid_run = true;
    cfg.deadline_s = deadline(60.0, 120.0);
    let r = run(&cfg).expect("scenario run");

    assert_eq!(r.restarts, 1, "restart fault never fired: {r:?}");
    assert_eq!(r.jobs_per_mode, 24);
    assert_eq!(r.lost, 0, "restart leg lost jobs: {r:?}");
    assert_eq!(r.duplicates, 0, "restart leg duplicated results: {r:?}");
    assert_eq!(r.undelivered, 0, "restart leg dropped callbacks: {r:?}");

    // Gap-free recovery: WAL replay preserves the global event sequence,
    // so client cursors pick up exactly where they left off — no
    // truncation signal, no reconciling-list fallback needed.
    assert_eq!(r.truncations, 0, "cursor saw truncation across restart: {r:?}");
    assert_eq!(r.reconciles, 0, "client needed a reconciling list: {r:?}");
}
