//! Integration: the real-time transport path — service behind the HTTP
//! gateway, a site agent driving real platform backends, everything over
//! sockets. (The heavier PJRT variant lives in integration_runtime.rs.)

use std::collections::BTreeMap;
use std::sync::Arc;

use balsam::runtime::local::{LocalResources, LoopbackTransfer};
use balsam::service::api::{ApiConn, ApiRequest, JobCreate};
use balsam::service::http_gw::{serve, HttpConn};
use balsam::service::models::{BatchJobId, JobState};
use balsam::service::{ServiceCore, Wire};
use balsam::site::agent::SiteAgent;
use balsam::site::config::SiteConfig;
use balsam::site::launcher::Launcher;
use balsam::site::platform::{ExecBackend, RunId, RunStatus};
use balsam::site::transfer::TransferModule;
use balsam::site::watch::EventWatcher;

/// Deterministic fake executor for the HTTP test (real PJRT is covered by
/// integration_runtime.rs; here we isolate the transport).
struct FastExec {
    runs: BTreeMap<RunId, f64>,
    next: u64,
}

impl ExecBackend for FastExec {
    fn start(&mut self, now: f64, _fac: &str, _workload: &str, _n: u32) -> RunId {
        self.next += 1;
        self.runs.insert(RunId(self.next), now + 0.3);
        RunId(self.next)
    }
    fn poll(&mut self, now: f64, id: RunId) -> RunStatus {
        match self.runs.get(&id) {
            Some(&t) if now >= t => RunStatus::Done { ok: true },
            Some(_) => RunStatus::Running,
            None => RunStatus::Done { ok: false },
        }
    }
    fn kill(&mut self, _now: f64, id: RunId) {
        self.runs.remove(&id);
    }
}

#[test]
fn full_round_trip_over_http_with_real_file_staging() {
    let svc = Arc::new(ServiceCore::new(b"http-int"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();

    let mut conn = HttpConn::new(server.addr.clone());
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "local".into(),
            hostname: "localhost".into(),
            path: "/tmp/balsam-http-int".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();

    // Jobs with small real payloads.
    let jobs: Vec<JobCreate> = (0..5)
        .map(|_| {
            let mut jc = JobCreate::simple(site, "MD", "md_small");
            jc.transfers_in = vec![("APS".into(), 300_000)];
            jc.transfers_out = vec![("APS".into(), 10_000)];
            jc
        })
        .collect();
    let ids = conn.api(&token, ApiRequest::BulkCreateJobs { jobs }).unwrap().job_ids();

    // Site agent over HTTP with real file staging. The agent's connection
    // speaks binary frames while the admin connection above stays JSON —
    // mixed-codec peers on one gateway is the compatibility surface the
    // codec layer guarantees.
    let mut cfg = SiteConfig::defaults("local", site, token.clone());
    cfg.wire = Wire::Binary;
    cfg.transfer.poll_period = 0.1;
    cfg.scheduler_poll = 0.1;
    cfg.elastic.poll_period = 0.1;
    cfg.elastic.block_nodes = 2;
    cfg.elastic.max_nodes = 4;
    cfg.launcher.acquire_period = 0.05;
    let mut agent_conn = cfg.dial(server.addr.clone());
    let mut agent = SiteAgent::new(cfg);
    let dir = std::env::temp_dir().join(format!("balsam-http-int-{}", std::process::id()));
    let mut xfer = LoopbackTransfer::new(&dir, None);
    let mut sched = LocalResources::new(4);
    let mut exec = FastExec { runs: BTreeMap::new(), next: 0 };

    let t0 = std::time::Instant::now();
    loop {
        let now = t0.elapsed().as_secs_f64();
        let next_wake = agent.step(now, &mut agent_conn, &mut xfer, &mut sched, &mut exec);
        let done = svc.store.count_in_state(site, JobState::JobFinished);
        if done == ids.len() {
            break;
        }
        assert!(now < 60.0, "round trips did not complete over HTTP");
        // The real-time drive pattern: instead of sleeping a fixed slice,
        // long-poll the site's event stream until the next module wake —
        // an event (stage-in done, job runnable) ends the wait early and
        // the next step acts on it immediately. The site's
        // `subscribe_timeout_ms` knob caps how long each watch may hang.
        let headroom = ((next_wake - t0.elapsed().as_secs_f64()).max(0.0) * 1e3) as u64;
        let now = t0.elapsed().as_secs_f64();
        agent.pump_events(&mut agent_conn, now, headroom.min(agent.cfg.subscribe_timeout_ms));
    }

    // The event log shows the full lifecycle for each job, with wall-clock
    // timestamps assigned by the HTTP gateway.
    let evs = svc.store.events();
    for &id in &ids {
        let path: Vec<JobState> =
            evs.iter().filter(|e| e.job_id == id).map(|e| e.to).collect();
        assert_eq!(*path.last().unwrap(), JobState::JobFinished, "job {id}: {path:?}");
        assert!(path.contains(&JobState::StagedIn));
        assert!(path.contains(&JobState::Running));
    }
    assert!(svc.calls() > 50, "expected many HTTP API calls, saw {}", svc.calls());
    assert_eq!(agent_conn.wire(), Wire::Binary, "binary-capable server must not force a fallback");

    // Observability piggyback: after a real workload the gateway's
    // unauthenticated scrape surfaces are live and populated.
    let (status, body) =
        balsam::util::httpd::request(&server.addr, "GET", "/healthz", &[], &[]).unwrap();
    assert_eq!(status, 200);
    assert_eq!(String::from_utf8_lossy(&body).trim(), "ok");
    let (status, body) =
        balsam::util::httpd::request(&server.addr, "GET", "/metrics", &[], &[]).unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8_lossy(&body);
    assert!(text.contains("balsam_api_requests_total{endpoint=\"BulkCreateJobs\"}"), "{text}");
    assert!(text.contains("# TYPE balsam_api_request_seconds histogram"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
    server.stop();
}

#[test]
fn concurrent_http_clients_share_one_service() {
    let svc = Arc::new(ServiceCore::new(b"http-conc"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
    let mut conn = HttpConn::new(server.addr.clone());
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "s".into(),
            hostname: "h".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();
    let threads: Vec<_> = (0..6)
        .map(|_| {
            let addr = server.addr.clone();
            let tok = token.clone();
            std::thread::spawn(move || {
                let mut c = HttpConn::new(addr);
                for _ in 0..10 {
                    c.api(&tok, ApiRequest::BulkCreateJobs {
                        jobs: vec![JobCreate::simple(site, "MD", "md_small")],
                    })
                    .unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(svc.store.job_count(), 60);
    svc.store.check_indexes().unwrap();
    server.stop();
}

/// Tentpole acceptance: with every site-side service poll DISABLED
/// (transfer poll period and launcher acquire period at 1e9 s), a job
/// still flows submission -> stage-in -> run -> stage-out -> finished over
/// the HTTP gateway, driven purely by push-mode `WatchEvents` wakeups — a
/// transfer-task completion propagates to job state in one event round
/// trip instead of up to one poll period. Under poll-only scheduling this
/// loop could not finish inside the wall-clock bound.
#[test]
fn push_mode_completes_roundtrip_with_poll_fallback_disabled() {
    let svc = Arc::new(ServiceCore::new(b"push-int"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
    let mut conn = HttpConn::new(server.addr.clone());
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "local".into(),
            hostname: "localhost".into(),
            path: "/tmp/balsam-push-int".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();

    let mut cfg = SiteConfig::defaults("local", site, token.clone());
    // Poll fallbacks disabled: only events may schedule service work.
    cfg.transfer.poll_period = 1e9;
    cfg.launcher.acquire_period = 1e9;
    // Local backend status polls (not service traffic) stay fast.
    cfg.transfer.task_poll_period = 0.02;

    let mut jc = JobCreate::simple(site, "MD", "md_small");
    jc.transfers_in = vec![("APS".into(), 200_000)];
    jc.transfers_out = vec![("APS".into(), 5_000)];
    let job = conn.api(&token, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0];

    let dir = std::env::temp_dir().join(format!("balsam-push-int-{}", std::process::id()));
    let mut xfer = LoopbackTransfer::new(&dir, None);
    let mut exec = FastExec { runs: BTreeMap::new(), next: 0 };
    let mut tm = TransferModule::new();
    let mut launcher = Launcher::new(BatchJobId(1), 1, 4, 0.0, 1e9);
    let mut watcher = EventWatcher::new();

    let t0 = std::time::Instant::now();
    loop {
        // While backend work is in flight the watch stays short so the
        // local task/run polls keep cadence; otherwise hang in the
        // gateway until the next event.
        let busy = tm.active_tasks() > 0 || launcher.running_jobs() > 0;
        let timeout_ms = if busy { 20 } else { 1_000 };
        let now = t0.elapsed().as_secs_f64();
        let evs = watcher.watch(&mut conn, &token, Some(site), timeout_ms, now).unwrap();
        tm.notify_events(&evs);
        launcher.notify_events(&evs);
        let now = t0.elapsed().as_secs_f64();
        tm.tick(now, &cfg, &mut conn, &mut xfer);
        assert!(launcher.tick(now, &cfg, &mut conn, &mut exec), "launcher must stay alive");
        let state = svc.store.job(job).unwrap().state;
        if state == JobState::JobFinished {
            break;
        }
        assert!(
            now < 30.0,
            "push-mode pipeline stalled at {state:?} after {now:.1}s (polls are disabled: \
             only event wakeups can drive progress)"
        );
    }
    // The whole round trip completed at event speed, far inside a single
    // (disabled) poll period — and the cursor saw every hop.
    assert!(watcher.cursor > 0);
    std::fs::remove_dir_all(&dir).ok();
    server.stop();
}

// ---------------------------------------------------------------------------
// Keep-alive protocol fault injection: misbehaving clients must never wedge
// a gateway worker slot or desynchronize other connections.
// ---------------------------------------------------------------------------

mod fault_injection {
    use super::*;
    use balsam::service::http_gw::serve_with;
    use balsam::util::httpd::{post_json, HttpConfig};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{Shutdown, TcpStream};
    use std::time::Duration;

    fn service() -> (Arc<ServiceCore>, String) {
        let svc = Arc::new(ServiceCore::new(b"fault"));
        let tok = svc.admin_token();
        (svc, tok)
    }

    /// Read everything until the server closes; returns the raw text.
    fn read_all(s: TcpStream) -> String {
        let mut text = String::new();
        let mut r = BufReader::new(s);
        let _ = r.read_to_string(&mut text);
        text
    }

    /// A good request must succeed — proves the (single) worker slot was
    /// freed by whatever fault preceded this call.
    fn assert_slot_free(addr: &str, tok: &str) {
        let (status, _) = post_json(addr, "/api", tok, "{\"type\":\"ListEvents\",\"since\":0}")
            .expect("worker slot not freed: good request failed");
        assert_eq!(status, 200);
    }

    /// Client half-closes mid-body: Content-Length promises 100 bytes but
    /// the write side shuts down after 7. The server must answer a framed
    /// 400 on the still-open read side, close, and free the worker slot.
    #[test]
    fn half_close_mid_body_gets_400_and_frees_slot() {
        let (svc, tok) = service();
        let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc, "127.0.0.1:0", 1, cfg).unwrap();

        let mut s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "POST /api HTTP/1.1\r\ncontent-length: 100\r\n\r\npartial").unwrap();
        s.shutdown(Shutdown::Write).unwrap();
        let text = read_all(s);
        assert!(text.starts_with("HTTP/1.1 400"), "want 400 for truncated body, got {text:?}");
        assert!(text.to_ascii_lowercase().contains("content-length:"), "unframed 400: {text:?}");
        assert!(text.to_ascii_lowercase().contains("connection: close"), "{text:?}");

        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// Client opens a connection and goes silent past the idle timeout:
    /// the server must reap it (worker slot freed) and keep serving other
    /// connections. Run with ONE worker so a leaked slot would deadlock
    /// the follow-up request.
    #[test]
    fn silent_connection_reaped_after_idle_timeout() {
        let (svc, tok) = service();
        let cfg = HttpConfig {
            keep_alive: true,
            idle_timeout: Duration::from_millis(200),
            ..HttpConfig::default()
        };
        let server = serve_with(svc, "127.0.0.1:0", 1, cfg).unwrap();

        let s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // Say nothing. The server's idle reaper must close us...
        let text = read_all(s);
        assert!(text.is_empty(), "idle close must not produce a response, got {text:?}");
        // ...and the single worker slot serves the next client.
        assert_slot_free(&server.addr, &tok);

        // Same, but going silent AFTER a completed request (mid-keep-alive
        // idle, the common launcher-crash shape).
        let mut s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "GET /api HTTP/1.1\r\n\r\n").unwrap();
        let text = read_all(s); // response, then reaper-close at idle timeout
        assert!(text.starts_with("HTTP/1.1 404"), "GET /api is 404, got {text:?}");
        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// After the server replies `Connection: close` (request budget
    /// exhausted), a second request pipelined onto the same socket must
    /// NOT be served: the connection just closes, and fresh connections
    /// keep working.
    #[test]
    fn request_after_connection_close_is_ignored() {
        let (svc, tok) = service();
        let cfg = HttpConfig {
            keep_alive: true,
            max_requests_per_conn: 1,
            ..HttpConfig::default()
        };
        let server = serve_with(svc, "127.0.0.1:0", 1, cfg).unwrap();

        let mut s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let body = "{\"type\":\"ListEvents\",\"since\":0}";
        let auth = format!("authorization: Bearer {tok}\r\n");
        let req = format!("POST /api HTTP/1.1\r\n{auth}content-length: {}\r\n\r\n{body}", body.len());
        // First request: served, with connection: close announced.
        s.write_all(req.as_bytes()).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "{line:?}");
        let mut clen = 0usize;
        let mut saw_close = false;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.trim_end().is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                clen = v.trim().parse().unwrap();
            }
            if lower.starts_with("connection:") && lower.contains("close") {
                saw_close = true;
            }
        }
        assert!(saw_close, "budget-exhausted response must announce connection: close");
        let mut resp_body = vec![0u8; clen];
        reader.read_exact(&mut resp_body).unwrap();
        // Second request on the same socket: must never be answered (the
        // write itself may fail with EPIPE if the server already closed —
        // also a pass).
        let _ = s.write_all(req.as_bytes());
        let mut leftover = String::new();
        let n = reader.read_to_string(&mut leftover).unwrap_or(0);
        assert_eq!(n, 0, "server served a request after connection: close: {leftover:?}");

        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// A subscriber that disconnects mid-watch must not leak its worker
    /// slot: the armed watch runs to its (short) timeout, the response
    /// write fails on the dead socket, and the slot serves the next
    /// client. Run with ONE worker so a leaked slot would deadlock the
    /// follow-up request.
    #[test]
    fn watch_client_disconnect_frees_worker_slot() {
        let (svc, tok) = service();
        let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 1, cfg).unwrap();
        // With one worker the gateway disables parking (slots = 0);
        // grant one slot explicitly so the watch genuinely arms and the
        // test exercises a pinned-then-reclaimed worker.
        svc.set_subscribe_slots(1);

        let body = "{\"type\":\"WatchEvents\",\"since\":0,\"timeout_ms\":400}";
        let mut s = TcpStream::connect(&server.addr).unwrap();
        write!(
            s,
            "POST /api HTTP/1.1\r\nauthorization: Bearer {tok}\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        // Give the worker time to arm the watch, then vanish entirely.
        std::thread::sleep(Duration::from_millis(100));
        s.shutdown(Shutdown::Both).unwrap();
        drop(s);
        // The slot must come back once the armed watch expires (well
        // before assert_slot_free's transport timeout).
        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// `Server::stop` with an armed watcher must wake it (via the stop
    /// hook closing the store's watchers) and terminate promptly — a
    /// hanging subscription must never wedge shutdown until its timeout.
    #[test]
    fn server_stop_with_armed_watcher_terminates_cleanly() {
        let (svc, tok) = service();
        let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc, "127.0.0.1:0", 2, cfg).unwrap();
        let addr = server.addr.clone();
        let watcher = std::thread::spawn(move || {
            // 20 s watch: far longer than the shutdown bound below, so a
            // pass proves stop() woke it rather than waited it out. The
            // result does not matter (empty page or torn connection).
            let body = "{\"type\":\"WatchEvents\",\"since\":0,\"timeout_ms\":20000}";
            let _ = post_json(&addr, "/api", &tok, body);
        });
        std::thread::sleep(Duration::from_millis(150)); // let the watch arm
        let t0 = std::time::Instant::now();
        server.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop() must wake armed watchers, took {:?}",
            t0.elapsed()
        );
        watcher.join().unwrap();
    }

    /// A watcher whose cursor predates event-log retention gets an
    /// immediate `truncated_before` page instead of hanging forever
    /// waiting for sequence numbers that can never be served again.
    #[test]
    fn watch_with_pre_retention_cursor_gets_truncated_before() {
        use balsam::service::{EventLogConfig, FsyncPolicy, PersistMode};
        let dir = std::env::temp_dir()
            .join(format!("balsam-watch-trunc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mode = PersistMode::Wal {
            dir: dir.clone(),
            // Rotate constantly, seal tiny segments, retain almost
            // nothing: early events are guaranteed to be dropped.
            snapshot_every: 4,
            fsync: FsyncPolicy::Never,
            events: EventLogConfig { segment_bytes: 512, retain_bytes: 1, retain_age_s: 0 },
        };
        let svc = Arc::new(ServiceCore::with_persist(b"watch-trunc", mode).unwrap());
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "s".into(),
                hostname: "h".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        // Generate events (2 per no-transfer job) until retention has
        // verifiably dropped history.
        for i in 0..200 {
            svc.handle(i as f64, &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(site, "MD", "md_small")],
            })
            .unwrap();
            if svc.store.events_page(0).unwrap().truncated_before.is_some() {
                break;
            }
        }
        assert!(
            svc.store.events_page(0).unwrap().truncated_before.is_some(),
            "retention never kicked in — test setup is wrong"
        );

        let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, cfg.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), cfg);
        let t0 = std::time::Instant::now();
        // Cursor 0 predates retained history; the long timeout must be
        // irrelevant — the marker answers immediately.
        let page = conn
            .api(&tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: 0,
                timeout_ms: 20_000,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        assert!(t0.elapsed() < Duration::from_secs(5), "truncated watch must not hang");
        let t = page.truncated_before.expect("must report the retention marker");
        assert!(t > 0);
        assert_eq!(page.events.first().unwrap().seq, t, "complete from the marker on");
        // An EventWatcher consuming that page jumps its cursor and counts
        // the gap; the next watch is a clean tail re-arm.
        let mut w = EventWatcher::new();
        let evs = w.watch(&mut conn, &tok, Some(site), 0, 0.0).unwrap();
        assert!(!evs.is_empty());
        assert_eq!(w.truncations, 1);
        assert_eq!(w.cursor, evs.last().unwrap().seq + 1);
        server.stop();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A client `ResultSubscription` whose cursor predates event-log
    /// retention must fall back to exactly ONE reconciling list (catching
    /// the terminal state whose event was truncated away) and then resume
    /// push delivery — later completions arrive as real pushed events with
    /// no further list traffic.
    #[test]
    fn client_subscription_survives_retention_truncation() {
        use balsam::client::ResultSubscription;
        use balsam::service::models::JobId;
        use balsam::service::{EventLogConfig, FsyncPolicy, PersistMode};
        use std::sync::Mutex;

        let dir = std::env::temp_dir()
            .join(format!("balsam-sub-trunc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mode = PersistMode::Wal {
            dir: dir.clone(),
            snapshot_every: 4,
            fsync: FsyncPolicy::Never,
            events: EventLogConfig { segment_bytes: 512, retain_bytes: 1, retain_age_s: 0 },
        };
        let svc = Arc::new(ServiceCore::with_persist(b"sub-trunc", mode).unwrap());
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "s".into(),
                hostname: "h".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        // finish() walks a no-transfer job (created in Preprocessed) to
        // Postprocessed; the store auto-finishes it (no stage-out items).
        let finish = |job: JobId, t: f64| {
            for to in [JobState::Running, JobState::RunDone, JobState::Postprocessed] {
                svc.handle(t, &tok, ApiRequest::UpdateJobState { job, to, data: String::new() })
                    .unwrap();
            }
        };

        // Job A completes first; churn then pushes its JobFinished event
        // past the retention horizon.
        let ja = svc
            .handle(0.0, &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(site, "MD", "md_small")],
            })
            .unwrap()
            .job_ids()[0];
        finish(ja, 0.5);
        let a_fin_seq = svc
            .store
            .events_page(0)
            .unwrap()
            .events
            .iter()
            .find(|e| e.job_id == ja && e.to == JobState::JobFinished)
            .expect("job A finished")
            .seq;
        for i in 0..400 {
            svc.handle(1.0 + i as f64, &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(site, "MD", "md_small")],
            })
            .unwrap();
            let trunc = svc.store.events_page(0).unwrap().truncated_before;
            if trunc.map(|t| t > a_fin_seq).unwrap_or(false) {
                break;
            }
        }
        let trunc = svc.store.events_page(0).unwrap().truncated_before;
        assert!(
            trunc.map(|t| t > a_fin_seq).unwrap_or(false),
            "retention never passed job A's terminal event — setup is wrong"
        );
        // Job B is still pending when the client attaches.
        let jb = svc
            .handle(500.0, &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(site, "MD", "md_small")],
            })
            .unwrap()
            .job_ids()[0];

        let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, cfg.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), cfg);

        // Push-mode subscription, fallback poll effectively disabled: the
        // reconcile below is triggered by the truncation signal, not time.
        let mut sub = ResultSubscription::new(tok.clone(), Some(site), 1e9);
        let got: Arc<Mutex<Vec<(JobId, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        for j in [ja, jb] {
            let got = got.clone();
            sub.subscribe(j, Box::new(move |id, ev| got.lock().unwrap().push((id, ev.seq))));
        }

        // First pump: cursor 0 -> truncated_before -> cursor jump + one
        // reconciling list, which recovers job A's (truncated) completion
        // as a synthetic seq-0 event.
        let n = sub.pump(&mut conn, 0.0, 50);
        assert_eq!(n, 1, "reconcile must deliver exactly job A");
        assert_eq!(sub.watcher.truncations, 1);
        assert_eq!(sub.reconciles, 1);
        {
            let g = got.lock().unwrap();
            assert_eq!(g.as_slice(), &[(ja, 0)], "A recovered via list, not a pushed event");
        }
        assert_eq!(sub.pending_jobs(), 1);

        // Quiet pump: no new events, and crucially no second list.
        let n = sub.pump(&mut conn, 1.0, 10);
        assert_eq!(n, 0);
        assert_eq!(sub.reconciles, 1, "reconcile must fire exactly once per truncation");

        // Job B finishes after the cursor re-anchored: delivered by push,
        // as a real event with a live sequence number.
        finish(jb, 600.0);
        let mut delivered = 0;
        for _ in 0..50 {
            delivered += sub.pump(&mut conn, 2.0, 100);
            if delivered > 0 {
                break;
            }
        }
        assert_eq!(delivered, 1, "B must arrive via push after the reconcile");
        {
            let g = got.lock().unwrap();
            assert_eq!(g.len(), 2);
            assert_eq!(g[1].0, jb);
            assert!(g[1].1 > 0, "B's completion must be a pushed event, got synthetic seq 0");
        }
        assert_eq!(sub.reconciles, 1);
        assert_eq!(sub.watcher.truncations, 1);
        assert_eq!(sub.pending_jobs(), 0);
        server.stop();
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Error-response framing: a keep-alive ApiConn that hits app-level
    /// errors (bad JSON -> 400, bad route -> 404) must be able to keep
    /// using the same connection — wrong Content-Length on an error reply
    /// would desynchronize every call after it.
    #[test]
    fn keepalive_client_continues_after_error_responses() {
        let (svc, tok) = service();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc, "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), ka);

        let site = conn
            .api(&tok, ApiRequest::CreateSite {
                name: "s".into(),
                hostname: "h".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        for i in 0..10 {
            // Alternate an error call with a good call on one connection.
            if i % 2 == 0 {
                conn.api("not-a-token", ApiRequest::SiteBacklog { site }).unwrap_err();
            } else {
                conn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
            }
        }
        assert_eq!(conn.connects(), 1, "errors must not cost the persistent connection");
        server.stop();
    }
}
