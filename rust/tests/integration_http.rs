//! Integration: the real-time transport path — service behind the HTTP
//! gateway, a site agent driving real platform backends, everything over
//! sockets. (The heavier PJRT variant lives in integration_runtime.rs.)

use std::collections::BTreeMap;
use std::sync::Arc;

use balsam::runtime::local::{LocalResources, LoopbackTransfer};
use balsam::service::api::{ApiConn, ApiRequest, JobCreate};
use balsam::service::http_gw::{serve, HttpConn};
use balsam::service::models::JobState;
use balsam::service::ServiceCore;
use balsam::site::agent::SiteAgent;
use balsam::site::config::SiteConfig;
use balsam::site::platform::{ExecBackend, RunId, RunStatus};

/// Deterministic fake executor for the HTTP test (real PJRT is covered by
/// integration_runtime.rs; here we isolate the transport).
struct FastExec {
    runs: BTreeMap<RunId, f64>,
    next: u64,
}

impl ExecBackend for FastExec {
    fn start(&mut self, now: f64, _fac: &str, _workload: &str, _n: u32) -> RunId {
        self.next += 1;
        self.runs.insert(RunId(self.next), now + 0.3);
        RunId(self.next)
    }
    fn poll(&mut self, now: f64, id: RunId) -> RunStatus {
        match self.runs.get(&id) {
            Some(&t) if now >= t => RunStatus::Done { ok: true },
            Some(_) => RunStatus::Running,
            None => RunStatus::Done { ok: false },
        }
    }
    fn kill(&mut self, _now: f64, id: RunId) {
        self.runs.remove(&id);
    }
}

#[test]
fn full_round_trip_over_http_with_real_file_staging() {
    let svc = Arc::new(ServiceCore::new(b"http-int"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();

    let mut conn = HttpConn::new(server.addr.clone());
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "local".into(),
            hostname: "localhost".into(),
            path: "/tmp/balsam-http-int".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();

    // Jobs with small real payloads.
    let jobs: Vec<JobCreate> = (0..5)
        .map(|_| {
            let mut jc = JobCreate::simple(site, "MD", "md_small");
            jc.transfers_in = vec![("APS".into(), 300_000)];
            jc.transfers_out = vec![("APS".into(), 10_000)];
            jc
        })
        .collect();
    let ids = conn.api(&token, ApiRequest::BulkCreateJobs { jobs }).unwrap().job_ids();

    // Site agent over HTTP with real file staging.
    let mut cfg = SiteConfig::defaults("local", site, token.clone());
    cfg.transfer.poll_period = 0.1;
    cfg.scheduler_poll = 0.1;
    cfg.elastic.poll_period = 0.1;
    cfg.elastic.block_nodes = 2;
    cfg.elastic.max_nodes = 4;
    cfg.launcher.acquire_period = 0.05;
    let mut agent = SiteAgent::new(cfg);
    let dir = std::env::temp_dir().join(format!("balsam-http-int-{}", std::process::id()));
    let mut xfer = LoopbackTransfer::new(&dir, None);
    let mut sched = LocalResources::new(4);
    let mut exec = FastExec { runs: BTreeMap::new(), next: 0 };
    let mut agent_conn = HttpConn::new(server.addr.clone());

    let t0 = std::time::Instant::now();
    loop {
        let now = t0.elapsed().as_secs_f64();
        agent.step(now, &mut agent_conn, &mut xfer, &mut sched, &mut exec);
        let done = svc.store.count_in_state(site, JobState::JobFinished);
        if done == ids.len() {
            break;
        }
        assert!(now < 60.0, "round trips did not complete over HTTP");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // The event log shows the full lifecycle for each job, with wall-clock
    // timestamps assigned by the HTTP gateway.
    let evs = svc.store.events();
    for &id in &ids {
        let path: Vec<JobState> =
            evs.iter().filter(|e| e.job_id == id).map(|e| e.to).collect();
        assert_eq!(*path.last().unwrap(), JobState::JobFinished, "job {id}: {path:?}");
        assert!(path.contains(&JobState::StagedIn));
        assert!(path.contains(&JobState::Running));
    }
    assert!(svc.calls() > 50, "expected many HTTP API calls, saw {}", svc.calls());
    std::fs::remove_dir_all(&dir).ok();
    server.stop();
}

#[test]
fn concurrent_http_clients_share_one_service() {
    let svc = Arc::new(ServiceCore::new(b"http-conc"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
    let mut conn = HttpConn::new(server.addr.clone());
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "s".into(),
            hostname: "h".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();
    let threads: Vec<_> = (0..6)
        .map(|_| {
            let addr = server.addr.clone();
            let tok = token.clone();
            std::thread::spawn(move || {
                let mut c = HttpConn::new(addr);
                for _ in 0..10 {
                    c.api(&tok, ApiRequest::BulkCreateJobs {
                        jobs: vec![JobCreate::simple(site, "MD", "md_small")],
                    })
                    .unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(svc.store.job_count(), 60);
    svc.store.check_indexes().unwrap();
    server.stop();
}

// ---------------------------------------------------------------------------
// Keep-alive protocol fault injection: misbehaving clients must never wedge
// a gateway worker slot or desynchronize other connections.
// ---------------------------------------------------------------------------

mod fault_injection {
    use super::*;
    use balsam::service::http_gw::serve_with;
    use balsam::util::httpd::{post_json, HttpConfig};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::{Shutdown, TcpStream};
    use std::time::Duration;

    fn service() -> (Arc<ServiceCore>, String) {
        let svc = Arc::new(ServiceCore::new(b"fault"));
        let tok = svc.admin_token();
        (svc, tok)
    }

    /// Read everything until the server closes; returns the raw text.
    fn read_all(s: TcpStream) -> String {
        let mut text = String::new();
        let mut r = BufReader::new(s);
        let _ = r.read_to_string(&mut text);
        text
    }

    /// A good request must succeed — proves the (single) worker slot was
    /// freed by whatever fault preceded this call.
    fn assert_slot_free(addr: &str, tok: &str) {
        let (status, _) = post_json(addr, "/api", tok, "{\"type\":\"ListEvents\",\"since\":0}")
            .expect("worker slot not freed: good request failed");
        assert_eq!(status, 200);
    }

    /// Client half-closes mid-body: Content-Length promises 100 bytes but
    /// the write side shuts down after 7. The server must answer a framed
    /// 400 on the still-open read side, close, and free the worker slot.
    #[test]
    fn half_close_mid_body_gets_400_and_frees_slot() {
        let (svc, tok) = service();
        let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc, "127.0.0.1:0", 1, cfg).unwrap();

        let mut s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "POST /api HTTP/1.1\r\ncontent-length: 100\r\n\r\npartial").unwrap();
        s.shutdown(Shutdown::Write).unwrap();
        let text = read_all(s);
        assert!(text.starts_with("HTTP/1.1 400"), "want 400 for truncated body, got {text:?}");
        assert!(text.to_ascii_lowercase().contains("content-length:"), "unframed 400: {text:?}");
        assert!(text.to_ascii_lowercase().contains("connection: close"), "{text:?}");

        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// Client opens a connection and goes silent past the idle timeout:
    /// the server must reap it (worker slot freed) and keep serving other
    /// connections. Run with ONE worker so a leaked slot would deadlock
    /// the follow-up request.
    #[test]
    fn silent_connection_reaped_after_idle_timeout() {
        let (svc, tok) = service();
        let cfg = HttpConfig {
            keep_alive: true,
            idle_timeout: Duration::from_millis(200),
            ..HttpConfig::default()
        };
        let server = serve_with(svc, "127.0.0.1:0", 1, cfg).unwrap();

        let s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // Say nothing. The server's idle reaper must close us...
        let text = read_all(s);
        assert!(text.is_empty(), "idle close must not produce a response, got {text:?}");
        // ...and the single worker slot serves the next client.
        assert_slot_free(&server.addr, &tok);

        // Same, but going silent AFTER a completed request (mid-keep-alive
        // idle, the common launcher-crash shape).
        let mut s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        write!(s, "GET /api HTTP/1.1\r\n\r\n").unwrap();
        let text = read_all(s); // response, then reaper-close at idle timeout
        assert!(text.starts_with("HTTP/1.1 404"), "GET /api is 404, got {text:?}");
        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// After the server replies `Connection: close` (request budget
    /// exhausted), a second request pipelined onto the same socket must
    /// NOT be served: the connection just closes, and fresh connections
    /// keep working.
    #[test]
    fn request_after_connection_close_is_ignored() {
        let (svc, tok) = service();
        let cfg = HttpConfig {
            keep_alive: true,
            max_requests_per_conn: 1,
            ..HttpConfig::default()
        };
        let server = serve_with(svc, "127.0.0.1:0", 1, cfg).unwrap();

        let mut s = TcpStream::connect(&server.addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let body = "{\"type\":\"ListEvents\",\"since\":0}";
        let auth = format!("authorization: Bearer {tok}\r\n");
        let req = format!("POST /api HTTP/1.1\r\n{auth}content-length: {}\r\n\r\n{body}", body.len());
        // First request: served, with connection: close announced.
        s.write_all(req.as_bytes()).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("HTTP/1.1 200"), "{line:?}");
        let mut clen = 0usize;
        let mut saw_close = false;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.trim_end().is_empty() {
                break;
            }
            let lower = h.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                clen = v.trim().parse().unwrap();
            }
            if lower.starts_with("connection:") && lower.contains("close") {
                saw_close = true;
            }
        }
        assert!(saw_close, "budget-exhausted response must announce connection: close");
        let mut resp_body = vec![0u8; clen];
        reader.read_exact(&mut resp_body).unwrap();
        // Second request on the same socket: must never be answered (the
        // write itself may fail with EPIPE if the server already closed —
        // also a pass).
        let _ = s.write_all(req.as_bytes());
        let mut leftover = String::new();
        let n = reader.read_to_string(&mut leftover).unwrap_or(0);
        assert_eq!(n, 0, "server served a request after connection: close: {leftover:?}");

        assert_slot_free(&server.addr, &tok);
        server.stop();
    }

    /// Error-response framing: a keep-alive ApiConn that hits app-level
    /// errors (bad JSON -> 400, bad route -> 404) must be able to keep
    /// using the same connection — wrong Content-Length on an error reply
    /// would desynchronize every call after it.
    #[test]
    fn keepalive_client_continues_after_error_responses() {
        let (svc, tok) = service();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc, "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), ka);

        let site = conn
            .api(&tok, ApiRequest::CreateSite {
                name: "s".into(),
                hostname: "h".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        for i in 0..10 {
            // Alternate an error call with a good call on one connection.
            if i % 2 == 0 {
                conn.api("not-a-token", ApiRequest::SiteBacklog { site }).unwrap_err();
            } else {
                conn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
            }
        }
        assert_eq!(conn.connects(), 1, "errors must not cost the persistent connection");
        server.stop();
    }
}
