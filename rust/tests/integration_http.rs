//! Integration: the real-time transport path — service behind the HTTP
//! gateway, a site agent driving real platform backends, everything over
//! sockets. (The heavier PJRT variant lives in integration_runtime.rs.)

use std::collections::BTreeMap;
use std::sync::Arc;

use balsam::runtime::local::{LocalResources, LoopbackTransfer};
use balsam::service::api::{ApiConn, ApiRequest, JobCreate};
use balsam::service::http_gw::{serve, HttpConn};
use balsam::service::models::JobState;
use balsam::service::ServiceCore;
use balsam::site::agent::SiteAgent;
use balsam::site::config::SiteConfig;
use balsam::site::platform::{ExecBackend, RunId, RunStatus};

/// Deterministic fake executor for the HTTP test (real PJRT is covered by
/// integration_runtime.rs; here we isolate the transport).
struct FastExec {
    runs: BTreeMap<RunId, f64>,
    next: u64,
}

impl ExecBackend for FastExec {
    fn start(&mut self, now: f64, _fac: &str, _workload: &str, _n: u32) -> RunId {
        self.next += 1;
        self.runs.insert(RunId(self.next), now + 0.3);
        RunId(self.next)
    }
    fn poll(&mut self, now: f64, id: RunId) -> RunStatus {
        match self.runs.get(&id) {
            Some(&t) if now >= t => RunStatus::Done { ok: true },
            Some(_) => RunStatus::Running,
            None => RunStatus::Done { ok: false },
        }
    }
    fn kill(&mut self, _now: f64, id: RunId) {
        self.runs.remove(&id);
    }
}

#[test]
fn full_round_trip_over_http_with_real_file_staging() {
    let svc = Arc::new(ServiceCore::new(b"http-int"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();

    let mut conn = HttpConn { addr: server.addr.clone() };
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "local".into(),
            hostname: "localhost".into(),
            path: "/tmp/balsam-http-int".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();

    // Jobs with small real payloads.
    let jobs: Vec<JobCreate> = (0..5)
        .map(|_| {
            let mut jc = JobCreate::simple(site, "MD", "md_small");
            jc.transfers_in = vec![("APS".into(), 300_000)];
            jc.transfers_out = vec![("APS".into(), 10_000)];
            jc
        })
        .collect();
    let ids = conn.api(&token, ApiRequest::BulkCreateJobs { jobs }).unwrap().job_ids();

    // Site agent over HTTP with real file staging.
    let mut cfg = SiteConfig::defaults("local", site, token.clone());
    cfg.transfer.poll_period = 0.1;
    cfg.scheduler_poll = 0.1;
    cfg.elastic.poll_period = 0.1;
    cfg.elastic.block_nodes = 2;
    cfg.elastic.max_nodes = 4;
    cfg.launcher.acquire_period = 0.05;
    let mut agent = SiteAgent::new(cfg);
    let dir = std::env::temp_dir().join(format!("balsam-http-int-{}", std::process::id()));
    let mut xfer = LoopbackTransfer::new(&dir, None);
    let mut sched = LocalResources::new(4);
    let mut exec = FastExec { runs: BTreeMap::new(), next: 0 };
    let mut agent_conn = HttpConn { addr: server.addr.clone() };

    let t0 = std::time::Instant::now();
    loop {
        let now = t0.elapsed().as_secs_f64();
        agent.step(now, &mut agent_conn, &mut xfer, &mut sched, &mut exec);
        let done = svc.store.count_in_state(site, JobState::JobFinished);
        if done == ids.len() {
            break;
        }
        assert!(now < 60.0, "round trips did not complete over HTTP");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // The event log shows the full lifecycle for each job, with wall-clock
    // timestamps assigned by the HTTP gateway.
    let evs = svc.store.events();
    for &id in &ids {
        let path: Vec<JobState> =
            evs.iter().filter(|e| e.job_id == id).map(|e| e.to).collect();
        assert_eq!(*path.last().unwrap(), JobState::JobFinished, "job {id}: {path:?}");
        assert!(path.contains(&JobState::StagedIn));
        assert!(path.contains(&JobState::Running));
    }
    assert!(svc.calls() > 50, "expected many HTTP API calls, saw {}", svc.calls());
    std::fs::remove_dir_all(&dir).ok();
    server.stop();
}

#[test]
fn concurrent_http_clients_share_one_service() {
    let svc = Arc::new(ServiceCore::new(b"http-conc"));
    let token = svc.admin_token();
    let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
    let mut conn = HttpConn { addr: server.addr.clone() };
    let site = conn
        .api(&token, ApiRequest::CreateSite {
            name: "s".into(),
            hostname: "h".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    conn.api(&token, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();
    let threads: Vec<_> = (0..6)
        .map(|_| {
            let addr = server.addr.clone();
            let tok = token.clone();
            std::thread::spawn(move || {
                let mut c = HttpConn { addr };
                for _ in 0..10 {
                    c.api(&tok, ApiRequest::BulkCreateJobs {
                        jobs: vec![JobCreate::simple(site, "MD", "md_small")],
                    })
                    .unwrap();
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(svc.store.job_count(), 60);
    svc.store.check_indexes().unwrap();
    server.stop();
}
