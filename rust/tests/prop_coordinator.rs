//! Property tests on coordinator invariants (seeded randomized sweeps via
//! util::check::forall — see DESIGN.md §7).

use balsam::client::{Strategy, Submission, WorkloadClient};
use balsam::experiments::common::{deploy, FaultInjector};
use balsam::service::api::{ApiRequest, JobCreate};
use balsam::service::models::{Direction, JobState, TransferState};
use balsam::service::state;
use balsam::service::ServiceCore;
use balsam::util::check::forall;
use balsam::util::rng::Pcg;

/// Invariant: event logs only ever record legal state-machine edges, and
/// per-job event sequences are contiguous (to of event k == from of k+1).
#[test]
fn prop_event_log_edges_are_legal_and_contiguous() {
    forall(
        "legal-event-edges",
        0xa11e,
        8,
        |r| (r.below(40) + 5, r.next_u64()),
        |&(jobs, seed)| {
            let mut d = deploy(seed, &["cori"], 16, |c| {
                c.elastic.block_nodes = 8;
                c.elastic.max_nodes = 16;
            });
            d.world.execs.get_mut("cori").unwrap().fail_prob = 0.2;
            let site = d.sites["cori"];
            let client = WorkloadClient::new(
                d.token.clone(),
                "APS",
                "MD",
                "md_small",
                Strategy::Single(site),
                Submission::Bursts { batch: jobs as usize, period: 1e9 },
                seed,
            )
            .with_max_jobs(jobs as usize);
            d.add_client(client);
            d.run_until(2500.0);
            let mut per_job: std::collections::BTreeMap<_, Vec<_>> = Default::default();
            for e in &d.svc().store.events() {
                if !state::legal(e.from, e.to) {
                    return Err(format!("illegal edge {} -> {}", e.from, e.to));
                }
                per_job.entry(e.job_id).or_default().push((e.from, e.to));
            }
            for (job, edges) in per_job {
                for w in edges.windows(2) {
                    if w[0].1 != w[1].0 {
                        return Err(format!("job {job}: discontinuous {:?} then {:?}", w[0], w[1]));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Invariant: no job is ever acquired by two live sessions at once, even
/// under fault injection and lease expiry.
#[test]
fn prop_session_lease_exclusivity_under_faults() {
    forall(
        "lease-exclusivity",
        0x5e55,
        6,
        |r| r.next_u64(),
        |&seed| {
            let mut d = deploy(seed, &["theta"], 32, |c| {
                c.elastic.block_nodes = 8;
                c.elastic.max_nodes = 32;
                c.launcher.heartbeat_period = 10.0;
            });
            let site = d.sites["theta"];
            let client = WorkloadClient::new(
                d.token.clone(),
                "APS",
                "MD",
                "md_small",
                Strategy::Single(site),
                Submission::Bursts { batch: 4, period: 4.0 },
                seed,
            )
            .with_max_jobs(120);
            d.add_client(client);
            d.add_actor(Box::new(FaultInjector::new("theta", 90.0, 120.0, 600.0, seed)));
            // Step the engine in chunks, checking the invariant throughout.
            for k in 1..=40 {
                d.run_until(k as f64 * 30.0);
                let svc = d.svc();
                let mut seen = std::collections::BTreeSet::new();
                for s in svc.store.sessions_snapshot().iter().filter(|s| !s.ended) {
                    for j in &s.acquired {
                        if !seen.insert(*j) {
                            return Err(format!("job {j} held by two live sessions at t={}", k * 30));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Invariant: jobs are never lost — every created job is always in
/// exactly one state, and with enough time every job reaches a terminal
/// state even under faults.
#[test]
fn prop_no_lost_jobs_under_faults() {
    forall(
        "no-lost-jobs",
        0x70b5,
        5,
        |r| r.next_u64(),
        |&seed| {
            let mut d = deploy(seed, &["theta"], 32, |c| {
                c.elastic.block_nodes = 8;
                c.elastic.max_nodes = 16;
            });
            let site = d.sites["theta"];
            let n = 60;
            let client = WorkloadClient::new(
                d.token.clone(),
                "APS",
                "MD",
                "md_small",
                Strategy::Single(site),
                Submission::Bursts { batch: 6, period: 6.0 },
                seed,
            )
            .with_max_jobs(n);
            d.add_client(client);
            d.add_actor(Box::new(FaultInjector::new("theta", 100.0, 60.0, 500.0, seed)));
            d.run_until(4000.0);
            let svc = d.svc();
            let terminal: usize =
                svc.store.jobs_snapshot().iter().filter(|j| j.state.is_terminal()).count();
            let total = svc.store.job_count();
            if total != n {
                return Err(format!("expected {n} jobs, found {total}"));
            }
            if terminal != total {
                let stuck: Vec<String> = svc
                    .store
                    .jobs_snapshot()
                    .iter()
                    .filter(|j| !j.state.is_terminal())
                    .map(|j| format!("{}:{}", j.id, j.state))
                    .collect();
                return Err(format!("non-terminal jobs after drain: {stuck:?}"));
            }
            svc.store.check_indexes()?;
            Ok(())
        },
    );
}

/// Invariant: store filter queries agree with a full scan, for random
/// job populations and random filters.
#[test]
fn prop_indexed_queries_equal_full_scan() {
    forall(
        "index-vs-scan",
        0x1dec5,
        40,
        |r: &mut Pcg| {
            let n = 1 + r.below(120) as usize;
            let states: Vec<JobState> =
                (0..1 + r.below(3)).map(|_| *r.choose(&JobState::ALL)).collect();
            (n, states, r.next_u64())
        },
        |(n, states, seed)| {
            let svc = ServiceCore::new(b"prop");
            let tok = svc.admin_token();
            let site = svc
                .handle(0.0, &tok, ApiRequest::CreateSite {
                    name: "cori".into(),
                    hostname: "h".into(),
                    path: "/p".into(),
                })
                .unwrap()
                .site_id();
            svc.handle(0.0, &tok, ApiRequest::RegisterApp {
                site,
                name: "MD".into(),
                command_template: "md".into(),
                parameters: vec![],
            })
            .unwrap();
            let mut rng = Pcg::seeded(*seed);
            // Create jobs and push them through random legal transitions.
            let jobs: Vec<JobCreate> = (0..*n)
                .map(|_| {
                    let mut jc = JobCreate::simple(site, "MD", "md_small");
                    if rng.chance(0.5) {
                        jc.transfers_in = vec![("APS".into(), 1000)];
                    }
                    jc
                })
                .collect();
            let ids = svc.handle(1.0, &tok, ApiRequest::BulkCreateJobs { jobs }).unwrap().job_ids();
            for (step, &id) in ids.iter().enumerate() {
                for _ in 0..rng.below(5) {
                    let cur = svc.store.job(id).unwrap().state;
                    let succ = state::successors(cur);
                    if succ.is_empty() {
                        break;
                    }
                    let to = *rng.choose(&succ);
                    // Transition via the store directly (service applies
                    // extra semantics; here we test pure index coherence).
                    svc.store.set_job_state(id, to, step as f64, "prop");
                }
            }
            svc.store.check_indexes()?;
            for &st in states {
                let via_index = svc.store.jobs_in_state(site, st).len();
                let via_scan =
                    svc.store.jobs_snapshot().iter().filter(|j| j.state == st).count();
                if via_index != via_scan {
                    return Err(format!("{st}: index {via_index} != scan {via_scan}"));
                }
                if svc.store.count_in_state(site, st) != via_scan {
                    return Err(format!("{st}: count mismatch"));
                }
            }
            Ok(())
        },
    );
}

/// Invariant: transfer items complete exactly once and only via
/// Pending -> Active -> Done/Error.
#[test]
fn prop_transfer_items_progress_monotonically() {
    forall(
        "titem-monotone",
        0x7f1e,
        6,
        |r| r.next_u64(),
        |&seed| {
            let mut d = deploy(seed, &["summit"], 16, |c| {
                c.transfer.batch_size = 1 + (seed % 32) as usize;
                c.elastic.block_nodes = 8;
                c.elastic.max_nodes = 16;
            });
            let site = d.sites["summit"];
            let client = WorkloadClient::new(
                d.token.clone(),
                "ALS",
                "EigenCorr",
                "xpcs",
                Strategy::Single(site),
                Submission::Bursts { batch: 20, period: 1e9 },
                seed,
            )
            .with_max_jobs(20);
            d.add_client(client);
            d.run_until(2500.0);
            let svc = d.svc();
            for t in svc.store.titems_snapshot() {
                if t.state != TransferState::Done {
                    return Err(format!(
                        "item {} ({:?}) finished in state {:?}",
                        t.id, t.direction, t.state
                    ));
                }
                if t.task_id.is_none() {
                    return Err(format!("item {} never assigned to a transfer task", t.id));
                }
            }
            // Out items at least as many as finished jobs (1 per job here).
            let done_jobs = svc.store.count_in_state(site, JobState::JobFinished);
            let out_items =
                svc.store.titems_snapshot().iter().filter(|t| t.direction == Direction::Out).count();
            if done_jobs != 20 || out_items != 20 {
                return Err(format!("jobs {done_jobs}, out items {out_items}"));
            }
            Ok(())
        },
    );
}
