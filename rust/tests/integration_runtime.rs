//! Integration: the PJRT runtime layer executing the real AOT artifacts.
//! Requires `make artifacts` (skips gracefully if artifacts are missing so
//! `cargo test` works on a fresh clone; CI/`make test` always builds them
//! first).

use std::collections::BTreeMap;

use balsam::runtime::{artifacts_dir, Runtime};
use balsam::runtime::real::RealExec;
use balsam::site::platform::{ExecBackend, RunStatus};

fn have_artifacts() -> bool {
    if !balsam::runtime::pjrt_available() {
        eprintln!("skipping: built without the `xla` feature (PJRT unavailable)");
        return false;
    }
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn md_model_artifact_produces_correct_eigenvalues() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let rt = Runtime::load(artifacts_dir(), &["md_64"]).unwrap();
    let model = rt.model("md_64").unwrap();
    // Diagonal matrix -> eigenvalues are the diagonal, sorted.
    let n = 64;
    let mut a = vec![0f32; n * n];
    for i in 0..n {
        a[i * n + i] = (n - i) as f32; // 64, 63, ..., 1
    }
    let outs = model.run_f32(&[a]).unwrap();
    assert_eq!(outs.len(), 1);
    let eig = &outs[0];
    assert_eq!(eig.len(), n);
    for (i, &v) in eig.iter().enumerate() {
        assert!((v - (i + 1) as f32).abs() < 1e-3, "eig[{i}]={v}");
    }
}

#[test]
fn md_model_matches_trace_invariant_on_random_input() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load(artifacts_dir(), &["md_64"]).unwrap();
    let model = rt.model("md_64").unwrap();
    let n = 64;
    // Symmetric random matrix (simple LCG for determinism).
    let mut x = 123456789u64;
    let mut a = vec![0f32; n * n];
    for i in 0..n {
        for j in 0..=i {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            let v = ((x >> 33) as f32 / (1u64 << 31) as f32) - 0.5;
            a[i * n + j] = v;
            a[j * n + i] = v;
        }
    }
    let trace: f32 = (0..n).map(|i| a[i * n + i]).sum();
    let eig = &model.run_f32(&[a]).unwrap()[0];
    let sum: f32 = eig.iter().sum();
    assert!((sum - trace).abs() < 0.05 * trace.abs().max(1.0), "sum {sum} vs trace {trace}");
    // Sorted ascending.
    assert!(eig.windows(2).all(|w| w[0] <= w[1] + 1e-5));
}

#[test]
fn xpcs_artifact_g2_decays_for_correlated_frames() {
    if !have_artifacts() {
        return;
    }
    let rt = Runtime::load(artifacts_dir(), &["xpcs_t64_p1024"]).unwrap();
    let model = rt.model("xpcs_t64_p1024").unwrap();
    let (t, p, ntau) = (64usize, 1024usize, 16usize);
    // AR(1)-correlated positive frames (tau_c ~ 6 frames).
    let rho = (-1.0f32 / 6.0).exp();
    let mut x = vec![0f32; p];
    let mut frames = vec![0f32; t * p];
    let mut seed = 42u64;
    let mut randn = move || {
        // Box-Muller-ish uniform sum approximation, deterministic.
        let mut s = 0.0f32;
        for _ in 0..12 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            s += (seed >> 33) as f32 / (1u64 << 31) as f32;
        }
        s - 6.0
    };
    for pix in x.iter_mut() {
        *pix = randn();
    }
    for ti in 0..t {
        for (pi, pix) in x.iter_mut().enumerate() {
            *pix = rho * *pix + (1.0 - rho * rho).sqrt() * randn();
            frames[ti * p + pi] = 1.0 + *pix * *pix;
        }
    }
    let outs = model.run_f32(&[frames]).unwrap();
    assert_eq!(outs.len(), 3); // g2, g2_mean, fidelity
    let g2_mean = &outs[1];
    assert_eq!(g2_mean.len(), ntau);
    assert!(g2_mean[0] > 1.05, "g2 at lag 1 should exceed 1: {}", g2_mean[0]);
    assert!(g2_mean[ntau - 1] < g2_mean[0], "g2 should decay");
    let fidelity = outs[2][0];
    assert!(fidelity > 0.0);
}

#[test]
fn real_exec_backend_runs_jobs_to_completion() {
    if !have_artifacts() {
        return;
    }
    let model_for: BTreeMap<String, String> =
        [("md_small".to_string(), "md_64".to_string())].into_iter().collect();
    let mut exec =
        RealExec::start_worker(artifacts_dir(), vec!["md_64".into()], model_for).unwrap();
    let ids: Vec<_> = (0..3).map(|i| exec.start(i as f64, "local", "md_small", 1)).collect();
    let t0 = std::time::Instant::now();
    loop {
        let done = ids
            .iter()
            .filter(|&&id| matches!(exec.poll(0.0, id), RunStatus::Done { .. }))
            .count();
        if done == ids.len() {
            break;
        }
        assert!(t0.elapsed().as_secs() < 120, "PJRT runs never finished");
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    for id in ids {
        let rec = exec.record(id).unwrap();
        assert!(rec.ok, "run failed: {rec:?}");
        assert!(rec.wall_s > 0.0);
    }
}
