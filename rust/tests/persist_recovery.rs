//! Crash-recovery integration tests for the WAL + snapshot store backend.
//!
//! The acceptance bar (ISSUE 2 + ISSUE 4): a `ServiceCore` opened in
//! `Wal` mode, killed after N mutations and reopened on the same dir
//! serves identical store snapshots and continues the global event
//! sequence with no gaps — including after a deliberately truncated
//! final WAL record (crash mid-append), after snapshot rotations that
//! archive events to the segmented event log, and under every
//! `FsyncPolicy` (the CI matrix sets `BALSAM_FSYNC=group` to run this
//! whole file through the group-commit pipeline).

use std::path::{Path, PathBuf};

use balsam::service::api::{ApiRequest, JobCreate};
use balsam::service::models::*;
use balsam::service::persist::{wal_path, EventLogConfig, FsyncPolicy, PersistMode};
use balsam::service::ServiceCore;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("balsam-recovery-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Fsync policy under test: `BALSAM_FSYNC` (never|always|group[:K,T]) —
/// the CI build-test matrix runs a `group` leg of this suite.
fn fsync_from_env() -> FsyncPolicy {
    match std::env::var("BALSAM_FSYNC") {
        Ok(s) => FsyncPolicy::parse(&s).unwrap_or_else(|| panic!("bad BALSAM_FSYNC '{s}'")),
        Err(_) => FsyncPolicy::Never,
    }
}

fn wal_mode(dir: &Path, snapshot_every: u64) -> PersistMode {
    PersistMode::Wal {
        dir: dir.to_path_buf(),
        snapshot_every,
        fsync: fsync_from_env(),
        events: EventLogConfig::default(),
    }
}

fn jobs_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.jobs_snapshot().iter().map(|j| j.to_json().to_string()).collect()
}

fn sessions_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.sessions_snapshot().iter().map(|s| s.to_json().to_string()).collect()
}

fn titems_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.titems_snapshot().iter().map(|t| t.to_json().to_string()).collect()
}

fn batches_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.batch_jobs_snapshot().iter().map(|b| b.to_json().to_string()).collect()
}

fn events_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.events().iter().map(|e| e.to_json().to_string()).collect()
}

/// Drive a representative workload: jobs with and without transfers, a
/// launcher session mid-flight, transfer completions and errors, a batch
/// job. Returns (site, session, acquired job ids).
fn drive_workload(svc: &ServiceCore, tok: &str) -> (SiteId, SessionId, Vec<JobId>) {
    let site = svc
        .handle(0.0, tok, ApiRequest::CreateSite {
            name: "theta".into(),
            hostname: "thetalogin1".into(),
            path: "/projects/x".into(),
        })
        .unwrap()
        .site_id();
    svc.handle(0.1, tok, ApiRequest::RegisterApp {
        site,
        name: "EigenCorr".into(),
        command_template: "corr {h5}".into(),
        parameters: vec!["h5".into()],
    })
    .unwrap();
    let mut jobs = Vec::new();
    for i in 0..3 {
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.tags = vec![("n".into(), format!("plain{i}"))];
        jobs.push(jc);
    }
    for i in 0..3 {
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.tags = vec![("n".into(), format!("xfer{i}"))];
        jc.transfers_in = vec![("APS".into(), 878_000_000)];
        jc.transfers_out = vec![("APS".into(), 55_000_000)];
        jobs.push(jc);
    }
    svc.handle(1.0, tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();

    // Stage-in: complete two items, error the third.
    let items = svc
        .handle(2.0, tok, ApiRequest::PendingTransferItems {
            site,
            direction: Direction::In,
            limit: 0,
        })
        .unwrap()
        .transfer_items();
    assert_eq!(items.len(), 3);
    svc.handle(3.0, tok, ApiRequest::UpdateTransferItems {
        ids: vec![items[0].id, items[1].id],
        state: TransferState::Done,
        task_id: Some(XferTaskId(41)),
    })
    .unwrap();
    svc.handle(3.5, tok, ApiRequest::SyncTransferItems {
        updates: vec![(items[2].id, TransferState::Error, Some(XferTaskId(42)))],
    })
    .unwrap();

    // Launcher session: acquire a few, run one to RUN_DONE, leave one RUNNING.
    let sid = svc
        .handle(4.0, tok, ApiRequest::CreateSession { site, batch_job: None })
        .unwrap()
        .session_id();
    let acquired = svc
        .handle(4.5, tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 100, max_jobs: 3 })
        .unwrap()
        .jobs();
    assert_eq!(acquired.len(), 3);
    let ids: Vec<JobId> = acquired.iter().map(|j| j.id).collect();
    svc.handle(5.0, tok, ApiRequest::BulkUpdateJobState {
        jobs: ids.clone(),
        to: JobState::Running,
        data: String::new(),
    })
    .unwrap();
    svc.handle(6.0, tok, ApiRequest::SessionSync {
        session: sid,
        updates: vec![
            (ids[0], JobState::RunDone, String::new()),
            (ids[0], JobState::Postprocessed, String::new()),
            (ids[1], JobState::RunDone, String::new()),
        ],
    })
    .unwrap();

    // A pilot allocation mid-flight.
    let bj = svc
        .handle(7.0, tok, ApiRequest::CreateBatchJob {
            site,
            num_nodes: 8,
            wall_time_s: 3600.0,
            mode: JobMode::Mpi,
            queue: "debug".into(),
            project: "xpcs".into(),
        })
        .unwrap()
        .batch_job_id();
    svc.handle(8.0, tok, ApiRequest::UpdateBatchJob {
        id: bj,
        state: BatchJobState::Running,
        local_id: Some(777),
    })
    .unwrap();
    (site, sid, ids)
}

#[test]
fn kill_and_reopen_serves_identical_snapshots() {
    let dir = tmpdir("roundtrip");
    // Small snapshot budget: the workload forces several compactions, so
    // recovery exercises snapshot + WAL tail, not just the WAL.
    let mode = wal_mode(&dir, 16);
    let (jobs0, sessions0, titems0, batches0, events0) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        drive_workload(&svc, &tok);
        (jobs_json(&svc), sessions_json(&svc), titems_json(&svc), batches_json(&svc), events_json(&svc))
        // svc dropped here: process-death equivalent (no shutdown hook).
    };
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(jobs_json(&svc2), jobs0);
    assert_eq!(sessions_json(&svc2), sessions0);
    assert_eq!(titems_json(&svc2), titems0);
    assert_eq!(batches_json(&svc2), batches0);
    assert_eq!(events_json(&svc2), events0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn event_sequence_continues_without_gaps() {
    let dir = tmpdir("seq");
    let mode = wal_mode(&dir, 16);
    let (last_seq, running) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        let (_site, _sid, ids) = drive_workload(&svc, &tok);
        let evs = svc.store.events();
        // Dense from zero during the first life.
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }
        (evs.last().unwrap().seq, ids[2])
    };
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    let tok = svc2.admin_token();
    // The still-RUNNING job finishes after the restart: the launcher
    // reconnects and syncs as if the service never went away.
    svc2.handle(10.0, &tok, ApiRequest::UpdateJobState {
        job: running,
        to: JobState::RunDone,
        data: String::new(),
    })
    .unwrap();
    let evs = svc2.store.events();
    for (i, e) in evs.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "recovered sequence stays dense");
    }
    assert!(evs.last().unwrap().seq > last_seq);
    // Fresh ids do not collide with recovered rows.
    let max_job = svc2.store.jobs_snapshot().iter().map(|j| j.id.0).max().unwrap();
    let newcomer = svc2
        .handle(11.0, &tok, ApiRequest::BulkCreateJobs {
            jobs: vec![JobCreate::simple(
                svc2.store.jobs_snapshot()[0].site_id,
                "EigenCorr",
                "xpcs",
            )],
        })
        .unwrap()
        .job_ids()[0];
    assert!(newcomer.0 > max_job);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_final_wal_record_is_dropped() {
    let dir = tmpdir("torn");
    // snapshot_every = 0: no compaction, the WAL holds full history.
    let mode = wal_mode(&dir, 0);
    let (site, state0) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        let (site, sid, _ids) = drive_workload(&svc, &tok);
        let state0 =
            (jobs_json(&svc), sessions_json(&svc), titems_json(&svc), events_json(&svc));
        // Final mutation: a lone heartbeat — exactly one WAL record.
        svc.handle(20.0, &tok, ApiRequest::SessionHeartbeat { session: sid }).unwrap();
        (site, state0)
    };
    // Crash mid-append: cut into the final record (the heartbeat).
    let wal = wal_path(&dir, Some(site));
    let bytes = std::fs::read(&wal).unwrap();
    assert!(!bytes.is_empty());
    std::fs::write(&wal, &bytes[..bytes.len() - 7]).unwrap();

    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(
        (jobs_json(&svc2), sessions_json(&svc2), titems_json(&svc2), events_json(&svc2)),
        state0,
        "torn heartbeat record rolled back; everything before it intact"
    );
    // And the reopened log keeps accepting appends: a second kill/reopen
    // still recovers (the torn tail was not re-persisted).
    let tok = svc2.admin_token();
    svc2.handle(21.0, &tok, ApiRequest::SessionHeartbeat {
        session: svc2.store.sessions_snapshot()[0].id,
    })
    .unwrap();
    drop(svc2);
    let svc3 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    assert_eq!(svc3.store.sessions_snapshot()[0].heartbeat_at, 21.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn launcher_reconnects_and_finishes_work_after_restart() {
    let dir = tmpdir("reconnect");
    let mode = wal_mode(&dir, 8);
    let (site, sid, ids) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        drive_workload(&svc, &tok)
    };
    let svc = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    let tok = svc.admin_token();
    // The recovered session still holds its jobs and accepts syncs.
    let failed = svc
        .handle(30.0, &tok, ApiRequest::SessionSync {
            session: sid,
            updates: vec![
                (ids[1], JobState::Postprocessed, String::new()),
                (ids[2], JobState::RunDone, String::new()),
                (ids[2], JobState::Postprocessed, String::new()),
            ],
        })
        .unwrap()
        .job_ids();
    assert!(failed.is_empty(), "rejected: {failed:?}");
    svc.handle(31.0, &tok, ApiRequest::SessionEnd { session: sid }).unwrap();
    // Jobs without stage-out finished; the one with stage-out awaits it.
    let done = svc
        .handle(32.0, &tok, ApiRequest::CountByState { site })
        .unwrap()
        .counts()
        .into_iter()
        .find(|(s, _)| *s == JobState::JobFinished)
        .map(|(_, n)| n)
        .unwrap_or(0);
    assert!(done >= 2, "expected finished jobs after reconnect, got {done}");
    svc.store.check_indexes().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Keep-alive gateway regression: mutations arriving over ONE long-lived
/// HTTP connection must persist exactly like in-process mutations — kill
/// the service (server + store dropped), reopen the same dir, and the
/// snapshots match. Guards the WAL append path against any transport-level
/// reordering/batching a persistent connection might introduce.
#[test]
fn keepalive_gateway_mutations_survive_kill_and_reopen() {
    use balsam::service::api::ApiConn;
    use balsam::service::http_gw::{serve_with, HttpConn};
    use balsam::util::httpd::HttpConfig;
    use std::sync::Arc;

    let dir = tmpdir("http-keepalive");
    let mode = wal_mode(&dir, 16);
    let state0 = {
        let svc = Arc::new(ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap());
        let tok = svc.admin_token();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), ka);

        // The same representative workload drive_workload() performs
        // in-process, but over the wire on one persistent connection.
        let site = conn
            .api(&tok, ApiRequest::CreateSite {
                name: "theta".into(),
                hostname: "t1".into(),
                path: "/projects/x".into(),
            })
            .unwrap()
            .site_id();
        conn.api(&tok, ApiRequest::RegisterApp {
            site,
            name: "EigenCorr".into(),
            command_template: "corr {h5}".into(),
            parameters: vec!["h5".into()],
        })
        .unwrap();
        let mut jobs = Vec::new();
        for i in 0..4 {
            let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
            jc.tags = vec![("n".into(), format!("ka{i}"))];
            if i % 2 == 0 {
                jc.transfers_in = vec![("APS".into(), 878_000_000)];
            }
            jobs.push(jc);
        }
        conn.api(&tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
        let items = conn
            .api(&tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(items.len(), 2);
        conn.api(&tok, ApiRequest::SyncTransferItems {
            updates: vec![
                (items[0].id, TransferState::Done, Some(XferTaskId(7))),
                (items[1].id, TransferState::Error, Some(XferTaskId(8))),
            ],
        })
        .unwrap();
        let sid = conn
            .api(&tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let acquired = conn
            .api(&tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 100, max_jobs: 2 })
            .unwrap()
            .jobs();
        assert_eq!(acquired.len(), 2);
        let ids: Vec<JobId> = acquired.iter().map(|j| j.id).collect();
        conn.api(&tok, ApiRequest::BulkUpdateJobState {
            jobs: ids.clone(),
            to: JobState::Running,
            data: String::new(),
        })
        .unwrap();
        conn.api(&tok, ApiRequest::SessionSync {
            session: sid,
            updates: vec![
                (ids[0], JobState::RunDone, String::new()),
                (ids[0], JobState::Postprocessed, String::new()),
            ],
        })
        .unwrap();
        assert_eq!(conn.connects(), 1, "all mutations must ride one persistent connection");

        let state = (jobs_json(&svc), sessions_json(&svc), titems_json(&svc), events_json(&svc));
        server.stop();
        state
        // svc (last Arc) dropped here: process-death equivalent.
    };
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(
        (jobs_json(&svc2), sessions_json(&svc2), titems_json(&svc2), events_json(&svc2)),
        state0,
        "keep-alive transport must not change what reaches the WAL"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 4 acceptance: snapshots hold live rows only — zero event
/// records — and the events survive via the segmented event log.
#[test]
fn snapshots_hold_zero_event_records() {
    let dir = tmpdir("rowsnap");
    // Tiny budget: the workload forces several rotations.
    let mode = wal_mode(&dir, 8);
    let events0 = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        drive_workload(&svc, &tok);
        events_json(&svc)
    };
    let mut snaps = 0;
    let mut segments = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.as_ref().unwrap().file_name().to_string_lossy().into_owned();
        if name.ends_with(".snap") {
            snaps += 1;
            let body = std::fs::read_to_string(entry.unwrap().path()).unwrap();
            assert!(!body.contains("\"t\":\"event\""), "{name} contains event records");
        } else if name.contains(".events.") {
            segments += 1;
        }
    }
    assert!(snaps > 0, "workload must have produced at least one snapshot");
    assert!(segments > 0, "rotation must have archived events to segments");
    // The full event log is still served (memory tail + cold segments),
    // identically after a reopen.
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(events_json(&svc2), events0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Event-log pagination spans the in-memory hot tail and the cold
/// segments, and a retention truncation is reported as an explicit
/// "truncated before seq N" marker rather than a silent gap.
#[test]
fn events_page_spans_segments_and_reports_truncation() {
    let drive = |dir: &Path, retain_bytes: u64| {
        let mode = PersistMode::Wal {
            dir: dir.to_path_buf(),
            // Rotate constantly so events move to segments quickly, and
            // keep segments tiny so several get sealed.
            snapshot_every: 4,
            fsync: fsync_from_env(),
            events: EventLogConfig { segment_bytes: 512, retain_bytes, retain_age_s: 0 },
        };
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "theta".into(),
                hostname: "t1".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.1, &tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        // Each no-transfer job emits 2 events (STAGED_IN, PREPROCESSED).
        for i in 0..40 {
            let jc = JobCreate::simple(site, "MD", "md_small");
            svc.handle(1.0 + i as f64, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] })
                .unwrap();
        }
        (svc, mode, site)
    };

    // Retention off: the full log pages back seamlessly across segments.
    let dir = tmpdir("page-segments");
    {
        let (svc, mode, site) = drive(&dir, 0);
        let all = svc.store.events();
        assert_eq!(all.len(), 80);
        for (i, e) in all.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "dense log");
        }
        let n_segments = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().file_name().to_string_lossy().contains(".events.")
            })
            .count();
        assert!(n_segments >= 2, "expected several sealed segments, got {n_segments}");
        let page = svc.store.events_page(0).unwrap();
        assert_eq!(page.truncated_before, None);
        assert_eq!(page.events.len(), 80);
        // A pager starting mid-archive gets everything from `since` on —
        // cold segments plus the memory tail, in order.
        let page = svc.store.events_page(25).unwrap();
        assert_eq!(page.truncated_before, None);
        assert_eq!(page.events.first().unwrap().seq, 25);
        assert_eq!(page.events.len(), 55);
        let tail = svc.store.events_page(79).unwrap();
        assert_eq!(tail.events.len(), 1);
        // Same answers after a kill/reopen.
        drop(svc);
        let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
        let page = svc2.store.events_page(25).unwrap();
        assert_eq!(page.truncated_before, None);
        assert_eq!(page.events.first().unwrap().seq, 25);
        assert_eq!(page.events.len(), 55);
        let _ = site;
    }
    let _ = std::fs::remove_dir_all(&dir);

    // Aggressive byte retention: old segments are dropped; a pager that
    // asks for them gets the truncation marker and a complete page from
    // the marker on.
    let dir = tmpdir("page-truncated");
    {
        let (svc, _mode, _site) = drive(&dir, 1);
        let page = svc.store.events_page(0).unwrap();
        let t = page.truncated_before.expect("retention must report truncation");
        assert!(t > 0);
        assert_eq!(page.events.first().unwrap().seq, t, "complete from the marker on");
        assert_eq!(page.events.last().unwrap().seq, 79);
        let seqs: Vec<u64> = page.events.iter().map(|e| e.seq).collect();
        let want: Vec<u64> = (t..=79).collect();
        assert_eq!(seqs, want, "gap-free from the truncation point");
        // A pager that starts at/after the marker sees no truncation.
        let page = svc.store.events_page(t).unwrap();
        assert_eq!(page.truncated_before, None);
        assert_eq!(page.events.len(), (80 - t) as usize);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// ISSUE 4 acceptance: under `FsyncPolicy::Group` a power loss (simulated
/// by truncating the WAL to its last-fsynced length) loses at most the
/// final un-fsynced group — every acknowledged mutation up to the
/// captured durability point survives, with a gap-free event sequence.
#[test]
fn group_commit_power_loss_loses_at_most_last_group() {
    let dir = tmpdir("group-loss");
    let mode = PersistMode::Wal {
        dir: dir.clone(),
        snapshot_every: 0, // no rotation: the WAL holds everything
        fsync: FsyncPolicy::Group { records: 4, interval_ms: 2 },
        events: EventLogConfig::default(),
    };
    let (site, durable_mid) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "theta".into(),
                hostname: "t1".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.1, &tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        let mut durable_mid = 0;
        for i in 0..20 {
            let jc = JobCreate::simple(site, "MD", "md_small");
            svc.handle(1.0 + i as f64, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] })
                .unwrap();
            if i == 9 {
                // The acknowledgement above blocked on its group fsync,
                // so the durable WAL prefix covers jobs 0..=9 right now.
                durable_mid = svc.store.wal_durable_len(Some(site)).unwrap();
            }
        }
        (site, durable_mid)
    };
    let wal = wal_path(&dir, Some(site));
    let full = std::fs::read(&wal).unwrap();
    assert!(durable_mid > 0 && (durable_mid as usize) <= full.len());
    // Power loss at the instant the 10th ack returned: everything past
    // the last fsync vanishes.
    std::fs::write(&wal, &full[..durable_mid as usize]).unwrap();
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
    svc2.store.check_indexes().unwrap();
    let jobs = svc2.store.jobs_snapshot();
    assert!(jobs.len() >= 10, "acknowledged mutations lost: {} < 10", jobs.len());
    assert!(jobs.len() <= 20);
    let evs = svc2.store.events();
    for (i, e) in evs.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "gap-free event sequence up to the recovery point");
    }
    // Every acknowledged mutation is fsynced before its ack returns (the
    // ack's waiter leads the group fsync itself when none is running):
    // truncating to the durable length after the fact loses nothing.
    let dir1 = tmpdir("group-loss-r1");
    let mode1 = PersistMode::Wal {
        dir: dir1.clone(),
        snapshot_every: 0,
        fsync: FsyncPolicy::Group { records: 1, interval_ms: 2 },
        events: EventLogConfig::default(),
    };
    let (site1, jobs1) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode1.clone()).unwrap();
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "cori".into(),
                hostname: "c1".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.1, &tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        for i in 0..5 {
            let jc = JobCreate::simple(site, "MD", "md_small");
            svc.handle(1.0 + i as f64, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] })
                .unwrap();
        }
        let durable = svc.store.wal_durable_len(Some(site)).unwrap();
        let len = std::fs::metadata(wal_path(&dir1, Some(site))).unwrap().len();
        assert_eq!(durable, len, "records=1: every ack is fsynced");
        (site, svc.store.jobs_snapshot().len())
    };
    let svc3 = ServiceCore::with_persist(b"recovery-secret", mode1).unwrap();
    assert_eq!(svc3.store.jobs_snapshot().len(), jobs1);
    let _ = site1;
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir1);
}

/// Satellite (ISSUE 4): a WAL I/O failure must not panic a gateway
/// worker mid-request — the request gets a framed 500 on the live
/// keep-alive connection, and every subsequent request fails fast while
/// the persist handle stays poisoned.
#[test]
fn poisoned_persist_serves_framed_500s() {
    use balsam::service::api::{ApiConn, ApiError};
    use balsam::service::http_gw::{serve_with, HttpConn};
    use balsam::util::httpd::HttpConfig;
    use std::sync::Arc;

    let dir = tmpdir("poisoned");
    let svc = Arc::new(ServiceCore::with_persist(b"recovery-secret", wal_mode(&dir, 0)).unwrap());
    let tok = svc.admin_token();
    let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let server = serve_with(svc.clone(), "127.0.0.1:0", 2, ka.clone()).unwrap();
    let mut conn = HttpConn::with_config(server.addr.clone(), ka);
    let site = conn
        .api(&tok, ApiRequest::CreateSite {
            name: "theta".into(),
            hostname: "t1".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    // Inject the I/O failure a real disk would have produced mid-append.
    svc.store.poison_persist("injected: disk gone");
    let err = conn.api(&tok, ApiRequest::CreateSession { site, batch_job: None }).unwrap_err();
    assert!(matches!(err, ApiError::Internal(_)), "expected framed 500, got {err:?}");
    // Fail-fast persists across requests — reads included: memory may be
    // ahead of the log, so the service refuses to serve until restarted.
    let err = conn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap_err();
    assert!(matches!(err, ApiError::Internal(_)), "{err:?}");
    // The framed error kept the keep-alive connection usable throughout.
    assert_eq!(conn.connects(), 1, "500s must be framed, not connection drops");
    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}
