//! Crash-recovery integration tests for the WAL + snapshot store backend.
//!
//! The acceptance bar (ISSUE 2): a `ServiceCore` opened in `Wal` mode,
//! killed after N mutations and reopened on the same dir serves identical
//! store snapshots and continues the global event sequence with no gaps —
//! including after a deliberately truncated final WAL record (crash
//! mid-append).

use std::path::PathBuf;

use balsam::service::api::{ApiRequest, JobCreate};
use balsam::service::models::*;
use balsam::service::persist::{wal_path, PersistMode};
use balsam::service::ServiceCore;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("balsam-recovery-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn jobs_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.jobs_snapshot().iter().map(|j| j.to_json().to_string()).collect()
}

fn sessions_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.sessions_snapshot().iter().map(|s| s.to_json().to_string()).collect()
}

fn titems_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.titems_snapshot().iter().map(|t| t.to_json().to_string()).collect()
}

fn batches_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.batch_jobs_snapshot().iter().map(|b| b.to_json().to_string()).collect()
}

fn events_json(svc: &ServiceCore) -> Vec<String> {
    svc.store.events().iter().map(|e| e.to_json().to_string()).collect()
}

/// Drive a representative workload: jobs with and without transfers, a
/// launcher session mid-flight, transfer completions and errors, a batch
/// job. Returns (site, session, acquired job ids).
fn drive_workload(svc: &ServiceCore, tok: &str) -> (SiteId, SessionId, Vec<JobId>) {
    let site = svc
        .handle(0.0, tok, ApiRequest::CreateSite {
            name: "theta".into(),
            hostname: "thetalogin1".into(),
            path: "/projects/x".into(),
        })
        .unwrap()
        .site_id();
    svc.handle(0.1, tok, ApiRequest::RegisterApp {
        site,
        name: "EigenCorr".into(),
        command_template: "corr {h5}".into(),
        parameters: vec!["h5".into()],
    })
    .unwrap();
    let mut jobs = Vec::new();
    for i in 0..3 {
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.tags = vec![("n".into(), format!("plain{i}"))];
        jobs.push(jc);
    }
    for i in 0..3 {
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.tags = vec![("n".into(), format!("xfer{i}"))];
        jc.transfers_in = vec![("APS".into(), 878_000_000)];
        jc.transfers_out = vec![("APS".into(), 55_000_000)];
        jobs.push(jc);
    }
    svc.handle(1.0, tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();

    // Stage-in: complete two items, error the third.
    let items = svc
        .handle(2.0, tok, ApiRequest::PendingTransferItems {
            site,
            direction: Direction::In,
            limit: 0,
        })
        .unwrap()
        .transfer_items();
    assert_eq!(items.len(), 3);
    svc.handle(3.0, tok, ApiRequest::UpdateTransferItems {
        ids: vec![items[0].id, items[1].id],
        state: TransferState::Done,
        task_id: Some(XferTaskId(41)),
    })
    .unwrap();
    svc.handle(3.5, tok, ApiRequest::SyncTransferItems {
        updates: vec![(items[2].id, TransferState::Error, Some(XferTaskId(42)))],
    })
    .unwrap();

    // Launcher session: acquire a few, run one to RUN_DONE, leave one RUNNING.
    let sid = svc
        .handle(4.0, tok, ApiRequest::CreateSession { site, batch_job: None })
        .unwrap()
        .session_id();
    let acquired = svc
        .handle(4.5, tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 100, max_jobs: 3 })
        .unwrap()
        .jobs();
    assert_eq!(acquired.len(), 3);
    let ids: Vec<JobId> = acquired.iter().map(|j| j.id).collect();
    svc.handle(5.0, tok, ApiRequest::BulkUpdateJobState {
        jobs: ids.clone(),
        to: JobState::Running,
        data: String::new(),
    })
    .unwrap();
    svc.handle(6.0, tok, ApiRequest::SessionSync {
        session: sid,
        updates: vec![
            (ids[0], JobState::RunDone, String::new()),
            (ids[0], JobState::Postprocessed, String::new()),
            (ids[1], JobState::RunDone, String::new()),
        ],
    })
    .unwrap();

    // A pilot allocation mid-flight.
    let bj = svc
        .handle(7.0, tok, ApiRequest::CreateBatchJob {
            site,
            num_nodes: 8,
            wall_time_s: 3600.0,
            mode: JobMode::Mpi,
            queue: "debug".into(),
            project: "xpcs".into(),
        })
        .unwrap()
        .batch_job_id();
    svc.handle(8.0, tok, ApiRequest::UpdateBatchJob {
        id: bj,
        state: BatchJobState::Running,
        local_id: Some(777),
    })
    .unwrap();
    (site, sid, ids)
}

#[test]
fn kill_and_reopen_serves_identical_snapshots() {
    let dir = tmpdir("roundtrip");
    // Small snapshot budget: the workload forces several compactions, so
    // recovery exercises snapshot + WAL tail, not just the WAL.
    let mode = PersistMode::Wal { dir: dir.clone(), snapshot_every: 16 };
    let (jobs0, sessions0, titems0, batches0, events0) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        drive_workload(&svc, &tok);
        (jobs_json(&svc), sessions_json(&svc), titems_json(&svc), batches_json(&svc), events_json(&svc))
        // svc dropped here: process-death equivalent (no shutdown hook).
    };
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(jobs_json(&svc2), jobs0);
    assert_eq!(sessions_json(&svc2), sessions0);
    assert_eq!(titems_json(&svc2), titems0);
    assert_eq!(batches_json(&svc2), batches0);
    assert_eq!(events_json(&svc2), events0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn event_sequence_continues_without_gaps() {
    let dir = tmpdir("seq");
    let mode = PersistMode::Wal { dir: dir.clone(), snapshot_every: 16 };
    let (last_seq, running) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        let (_site, _sid, ids) = drive_workload(&svc, &tok);
        let evs = svc.store.events();
        // Dense from zero during the first life.
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }
        (evs.last().unwrap().seq, ids[2])
    };
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    let tok = svc2.admin_token();
    // The still-RUNNING job finishes after the restart: the launcher
    // reconnects and syncs as if the service never went away.
    svc2.handle(10.0, &tok, ApiRequest::UpdateJobState {
        job: running,
        to: JobState::RunDone,
        data: String::new(),
    })
    .unwrap();
    let evs = svc2.store.events();
    for (i, e) in evs.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "recovered sequence stays dense");
    }
    assert!(evs.last().unwrap().seq > last_seq);
    // Fresh ids do not collide with recovered rows.
    let max_job = svc2.store.jobs_snapshot().iter().map(|j| j.id.0).max().unwrap();
    let newcomer = svc2
        .handle(11.0, &tok, ApiRequest::BulkCreateJobs {
            jobs: vec![JobCreate::simple(
                svc2.store.jobs_snapshot()[0].site_id,
                "EigenCorr",
                "xpcs",
            )],
        })
        .unwrap()
        .job_ids()[0];
    assert!(newcomer.0 > max_job);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_final_wal_record_is_dropped() {
    let dir = tmpdir("torn");
    // snapshot_every = 0: no compaction, the WAL holds full history.
    let mode = PersistMode::Wal { dir: dir.clone(), snapshot_every: 0 };
    let (site, state0) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        let (site, sid, _ids) = drive_workload(&svc, &tok);
        let state0 =
            (jobs_json(&svc), sessions_json(&svc), titems_json(&svc), events_json(&svc));
        // Final mutation: a lone heartbeat — exactly one WAL record.
        svc.handle(20.0, &tok, ApiRequest::SessionHeartbeat { session: sid }).unwrap();
        (site, state0)
    };
    // Crash mid-append: cut into the final record (the heartbeat).
    let wal = wal_path(&dir, Some(site));
    let bytes = std::fs::read(&wal).unwrap();
    assert!(!bytes.is_empty());
    std::fs::write(&wal, &bytes[..bytes.len() - 7]).unwrap();

    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(
        (jobs_json(&svc2), sessions_json(&svc2), titems_json(&svc2), events_json(&svc2)),
        state0,
        "torn heartbeat record rolled back; everything before it intact"
    );
    // And the reopened log keeps accepting appends: a second kill/reopen
    // still recovers (the torn tail was not re-persisted).
    let tok = svc2.admin_token();
    svc2.handle(21.0, &tok, ApiRequest::SessionHeartbeat {
        session: svc2.store.sessions_snapshot()[0].id,
    })
    .unwrap();
    drop(svc2);
    let svc3 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    assert_eq!(svc3.store.sessions_snapshot()[0].heartbeat_at, 21.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn launcher_reconnects_and_finishes_work_after_restart() {
    let dir = tmpdir("reconnect");
    let mode = PersistMode::Wal { dir: dir.clone(), snapshot_every: 8 };
    let (site, sid, ids) = {
        let svc = ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap();
        let tok = svc.admin_token();
        drive_workload(&svc, &tok)
    };
    let svc = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    let tok = svc.admin_token();
    // The recovered session still holds its jobs and accepts syncs.
    let failed = svc
        .handle(30.0, &tok, ApiRequest::SessionSync {
            session: sid,
            updates: vec![
                (ids[1], JobState::Postprocessed, String::new()),
                (ids[2], JobState::RunDone, String::new()),
                (ids[2], JobState::Postprocessed, String::new()),
            ],
        })
        .unwrap()
        .job_ids();
    assert!(failed.is_empty(), "rejected: {failed:?}");
    svc.handle(31.0, &tok, ApiRequest::SessionEnd { session: sid }).unwrap();
    // Jobs without stage-out finished; the one with stage-out awaits it.
    let done = svc
        .handle(32.0, &tok, ApiRequest::CountByState { site })
        .unwrap()
        .counts()
        .into_iter()
        .find(|(s, _)| *s == JobState::JobFinished)
        .map(|(_, n)| n)
        .unwrap_or(0);
    assert!(done >= 2, "expected finished jobs after reconnect, got {done}");
    svc.store.check_indexes().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Keep-alive gateway regression: mutations arriving over ONE long-lived
/// HTTP connection must persist exactly like in-process mutations — kill
/// the service (server + store dropped), reopen the same dir, and the
/// snapshots match. Guards the WAL append path against any transport-level
/// reordering/batching a persistent connection might introduce.
#[test]
fn keepalive_gateway_mutations_survive_kill_and_reopen() {
    use balsam::service::api::ApiConn;
    use balsam::service::http_gw::{serve_with, HttpConn};
    use balsam::util::httpd::HttpConfig;
    use std::sync::Arc;

    let dir = tmpdir("http-keepalive");
    let mode = PersistMode::Wal { dir: dir.clone(), snapshot_every: 16 };
    let state0 = {
        let svc = Arc::new(ServiceCore::with_persist(b"recovery-secret", mode.clone()).unwrap());
        let tok = svc.admin_token();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), ka);

        // The same representative workload drive_workload() performs
        // in-process, but over the wire on one persistent connection.
        let site = conn
            .api(&tok, ApiRequest::CreateSite {
                name: "theta".into(),
                hostname: "t1".into(),
                path: "/projects/x".into(),
            })
            .unwrap()
            .site_id();
        conn.api(&tok, ApiRequest::RegisterApp {
            site,
            name: "EigenCorr".into(),
            command_template: "corr {h5}".into(),
            parameters: vec!["h5".into()],
        })
        .unwrap();
        let mut jobs = Vec::new();
        for i in 0..4 {
            let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
            jc.tags = vec![("n".into(), format!("ka{i}"))];
            if i % 2 == 0 {
                jc.transfers_in = vec![("APS".into(), 878_000_000)];
            }
            jobs.push(jc);
        }
        conn.api(&tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
        let items = conn
            .api(&tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(items.len(), 2);
        conn.api(&tok, ApiRequest::SyncTransferItems {
            updates: vec![
                (items[0].id, TransferState::Done, Some(XferTaskId(7))),
                (items[1].id, TransferState::Error, Some(XferTaskId(8))),
            ],
        })
        .unwrap();
        let sid = conn
            .api(&tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let acquired = conn
            .api(&tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 100, max_jobs: 2 })
            .unwrap()
            .jobs();
        assert_eq!(acquired.len(), 2);
        let ids: Vec<JobId> = acquired.iter().map(|j| j.id).collect();
        conn.api(&tok, ApiRequest::BulkUpdateJobState {
            jobs: ids.clone(),
            to: JobState::Running,
            data: String::new(),
        })
        .unwrap();
        conn.api(&tok, ApiRequest::SessionSync {
            session: sid,
            updates: vec![
                (ids[0], JobState::RunDone, String::new()),
                (ids[0], JobState::Postprocessed, String::new()),
            ],
        })
        .unwrap();
        assert_eq!(conn.connects(), 1, "all mutations must ride one persistent connection");

        let state = (jobs_json(&svc), sessions_json(&svc), titems_json(&svc), events_json(&svc));
        server.stop();
        state
        // svc (last Arc) dropped here: process-death equivalent.
    };
    let svc2 = ServiceCore::with_persist(b"recovery-secret", mode).unwrap();
    svc2.store.check_indexes().unwrap();
    assert_eq!(
        (jobs_json(&svc2), sessions_json(&svc2), titems_json(&svc2), events_json(&svc2)),
        state0,
        "keep-alive transport must not change what reaches the WAL"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
