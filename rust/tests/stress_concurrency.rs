//! Concurrency stress on the sharded service core (tentpole coverage).
//!
//! ≥8 client threads hammer bulk job updates across ≥4 sites through
//! `ServiceCore::handle(&self)` — two launcher sessions per site racing on
//! the same shard — then the test asserts zero lost transitions: every
//! job finished exactly once, every event path is legal and contiguous,
//! no job was ever held by two sessions, and the store indexes are
//! coherent afterward.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use balsam::service::api::{ApiConn, ApiRequest, JobCreate, JobFilter};
use balsam::service::http_gw::{serve_with, HttpConn};
use balsam::service::models::{JobId, JobState, SiteId};
use balsam::service::state;
use balsam::service::ServiceCore;
use balsam::util::httpd::HttpConfig;

const SITES: usize = 4;
const THREADS: usize = 8; // two launcher sessions per site
const JOBS_PER_SITE: usize = 80;

fn setup_sites(svc: &ServiceCore, tok: &str) -> Vec<SiteId> {
    (0..SITES)
        .map(|i| {
            let site = svc
                .handle(0.0, tok, ApiRequest::CreateSite {
                    name: format!("site{i}"),
                    hostname: format!("host{i}"),
                    path: "/p".into(),
                })
                .unwrap()
                .site_id();
            svc.handle(0.0, tok, ApiRequest::RegisterApp {
                site,
                name: "MD".into(),
                command_template: "md".into(),
                parameters: vec![],
            })
            .unwrap();
            site
        })
        .collect()
}

#[test]
fn concurrent_bulk_updates_lose_no_transitions() {
    let svc = Arc::new(ServiceCore::new(b"stress"));
    let tok = svc.admin_token();
    let sites = setup_sites(&svc, &tok);
    for &site in &sites {
        let jobs: Vec<JobCreate> =
            (0..JOBS_PER_SITE).map(|_| JobCreate::simple(site, "MD", "md_small")).collect();
        svc.handle(0.5, &tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
    }

    // Every acquisition ever made, across all threads, for the
    // exclusivity check.
    let all_acquired: Arc<Mutex<Vec<JobId>>> = Arc::default();
    let finished = Arc::new(AtomicUsize::new(0));
    let total = SITES * JOBS_PER_SITE;

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let svc = svc.clone();
            let tok = tok.clone();
            let site = sites[t % SITES];
            let all_acquired = all_acquired.clone();
            let finished = finished.clone();
            std::thread::spawn(move || {
                let sid = svc
                    .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
                    .unwrap()
                    .session_id();
                let mut round = 0u64;
                loop {
                    if finished.load(Ordering::Relaxed) >= total {
                        break;
                    }
                    round += 1;
                    assert!(round < 100_000, "stress test did not converge");
                    // Clamp `now` well below the 60 s lease so a
                    // fast-spinning thread can never expire a sibling's
                    // live session.
                    let now = 1.0 + (round as f64 * 1e-3).min(30.0);
                    let got = svc
                        .handle(now, &tok, ApiRequest::SessionAcquire {
                            session: sid,
                            max_nodes: 1_000_000,
                            max_jobs: 8,
                        })
                        .unwrap()
                        .jobs();
                    if got.is_empty() {
                        // The sibling thread may still be draining the site.
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        continue;
                    }
                    let ids: Vec<JobId> = got.iter().map(|j| j.id).collect();
                    all_acquired.lock().unwrap().extend(ids.iter().copied());
                    // Bulk transition to RUNNING, then one SessionSync
                    // round trip for RUN_DONE + POSTPROCESSED.
                    svc.handle(now, &tok, ApiRequest::BulkUpdateJobState {
                        jobs: ids.clone(),
                        to: JobState::Running,
                        data: String::new(),
                    })
                    .unwrap();
                    let updates = ids
                        .iter()
                        .flat_map(|&j| {
                            [
                                (j, JobState::RunDone, String::new()),
                                (j, JobState::Postprocessed, String::new()),
                            ]
                        })
                        .collect();
                    let failed = svc
                        .handle(now, &tok, ApiRequest::SessionSync { session: sid, updates })
                        .unwrap()
                        .job_ids();
                    assert!(failed.is_empty(), "transitions rejected under contention: {failed:?}");
                    finished.fetch_add(ids.len(), Ordering::Relaxed);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // No lost transitions: every job completed the full round trip.
    for &site in &sites {
        assert_eq!(
            svc.store.count_in_state(site, JobState::JobFinished),
            JOBS_PER_SITE,
            "site {site} lost jobs"
        );
    }
    assert_eq!(svc.store.job_count(), total);

    // Session exclusivity: each job was acquired exactly once (it was
    // driven straight to a terminal state after acquisition).
    let mut acquired = all_acquired.lock().unwrap().clone();
    let n = acquired.len();
    acquired.sort();
    acquired.dedup();
    assert_eq!(acquired.len(), n, "a job was handed to two sessions");
    assert_eq!(n, total);

    // Event log: legal, contiguous, per-job complete; seq is a dense
    // total order even though shards were written concurrently.
    let events = svc.store.events();
    for (i, e) in events.iter().enumerate() {
        assert_eq!(e.seq, i as u64, "event seq must be dense and ordered");
        assert!(state::legal(e.from, e.to), "illegal edge {} -> {}", e.from, e.to);
    }
    let mut per_job: std::collections::BTreeMap<JobId, Vec<(JobState, JobState)>> = Default::default();
    for e in &events {
        per_job.entry(e.job_id).or_default().push((e.from, e.to));
    }
    assert_eq!(per_job.len(), total);
    for (job, edges) in per_job {
        for w in edges.windows(2) {
            assert_eq!(w[0].1, w[1].0, "job {job}: discontinuous {:?} then {:?}", w[0], w[1]);
        }
        assert_eq!(edges.last().unwrap().1, JobState::JobFinished, "job {job} not finished");
    }

    // Store indexes stayed coherent under concurrent mutation.
    svc.store.check_indexes().unwrap();
}

/// The same traffic shape through the real HTTP gateway worker pool:
/// concurrent clients over sockets, multi-site, bulk updates.
#[test]
fn concurrent_clients_through_gateway_pool() {
    let svc = Arc::new(ServiceCore::new(b"stress-http"));
    let tok = svc.admin_token();
    let sites = setup_sites(&svc, &tok);
    let server = serve_with(svc.clone(), "127.0.0.1:0", 4, HttpConfig::default()).unwrap();

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let addr = server.addr.clone();
            let tok = tok.clone();
            let site = sites[t % SITES];
            std::thread::spawn(move || {
                let mut conn = HttpConn::new(addr);
                let sid = conn
                    .api(&tok, ApiRequest::CreateSession { site, batch_job: None })
                    .unwrap()
                    .session_id();
                for _ in 0..5 {
                    let jobs: Vec<JobCreate> =
                        (0..4).map(|_| JobCreate::simple(site, "MD", "md_small")).collect();
                    conn.api(&tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
                    let got = conn
                        .api(&tok, ApiRequest::SessionAcquire {
                            session: sid,
                            max_nodes: 1_000_000,
                            max_jobs: 4,
                        })
                        .unwrap()
                        .jobs();
                    if got.is_empty() {
                        continue;
                    }
                    let ids: Vec<JobId> = got.iter().map(|j| j.id).collect();
                    conn.api(&tok, ApiRequest::BulkUpdateJobState {
                        jobs: ids.clone(),
                        to: JobState::Running,
                        data: String::new(),
                    })
                    .unwrap();
                    let updates = ids
                        .iter()
                        .flat_map(|&j| {
                            [
                                (j, JobState::RunDone, String::new()),
                                (j, JobState::Postprocessed, String::new()),
                            ]
                        })
                        .collect();
                    let failed = conn
                        .api(&tok, ApiRequest::SessionSync { session: sid, updates })
                        .unwrap()
                        .job_ids();
                    assert!(failed.is_empty());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    // Two sessions share each site, so a thread may exit with jobs it
    // created still runnable (acquired counts race); drain them now.
    let mut drain = HttpConn::new(server.addr.clone());
    for &site in &sites {
        let sid = drain
            .api(&tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        loop {
            let got = drain
                .api(&tok, ApiRequest::SessionAcquire {
                    session: sid,
                    max_nodes: 1_000_000,
                    max_jobs: 1_000,
                })
                .unwrap()
                .jobs();
            if got.is_empty() {
                break;
            }
            let ids: Vec<JobId> = got.iter().map(|j| j.id).collect();
            drain
                .api(&tok, ApiRequest::BulkUpdateJobState {
                    jobs: ids.clone(),
                    to: JobState::Running,
                    data: String::new(),
                })
                .unwrap();
            let updates = ids
                .iter()
                .flat_map(|&j| {
                    [
                        (j, JobState::RunDone, String::new()),
                        (j, JobState::Postprocessed, String::new()),
                    ]
                })
                .collect();
            drain.api(&tok, ApiRequest::SessionSync { session: sid, updates }).unwrap();
        }
    }

    // Everything submitted over HTTP completed; indexes coherent.
    assert_eq!(svc.store.job_count(), THREADS * 5 * 4);
    let done: usize =
        sites.iter().map(|&s| svc.store.count_in_state(s, JobState::JobFinished)).sum();
    assert_eq!(done, THREADS * 5 * 4);
    svc.store.check_indexes().unwrap();
    server.stop();
}

/// Connection-reuse correctness (keep-alive tentpole): one launcher
/// session issues 100 sequential SessionSync calls over a single pooled
/// connection — every response must pair with its request (the failed-id
/// list echoes exactly the update that was illegal) — while a second
/// pooled client hammers the same gateway concurrently on another site.
/// Any cross-talk between the two streams (a response delivered to the
/// wrong client, or out of order within one connection) shows up as a
/// wrong failed-list, a foreign site id in a ListJobs reply, or a job
/// count mismatch at the end.
#[test]
fn sequential_syncs_share_one_connection_without_crosstalk() {
    const SYNCS: usize = 100;
    let svc = Arc::new(ServiceCore::new(b"stress-keepalive"));
    let tok = svc.admin_token();
    let sites = setup_sites(&svc, &tok);
    let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let server = serve_with(svc.clone(), "127.0.0.1:0", 4, ka.clone()).unwrap();

    let handles: Vec<_> = (0..2)
        .map(|t| {
            let addr = server.addr.clone();
            let tok = tok.clone();
            let site = sites[t];
            let ka = ka.clone();
            std::thread::spawn(move || {
                let mut conn = HttpConn::with_config(addr, ka);
                let jobs: Vec<JobCreate> =
                    (0..SYNCS).map(|_| JobCreate::simple(site, "MD", "md_small")).collect();
                let ids = conn
                    .api(&tok, ApiRequest::BulkCreateJobs { jobs })
                    .unwrap()
                    .job_ids();
                let sid = conn
                    .api(&tok, ApiRequest::CreateSession { site, batch_job: None })
                    .unwrap()
                    .session_id();
                let got = conn
                    .api(&tok, ApiRequest::SessionAcquire {
                        session: sid,
                        max_nodes: 1_000_000,
                        max_jobs: SYNCS,
                    })
                    .unwrap()
                    .jobs();
                assert_eq!(got.len(), SYNCS);
                conn.api(&tok, ApiRequest::BulkUpdateJobState {
                    jobs: ids.clone(),
                    to: JobState::Running,
                    data: String::new(),
                })
                .unwrap();
                // 100 sequential SessionSync calls, one job per call, plus
                // one deliberately-illegal update every 10th call: the
                // response to call i must reference call i's own job.
                for (i, &job) in ids.iter().enumerate() {
                    let mut updates = vec![
                        (job, JobState::RunDone, String::new()),
                        (job, JobState::Postprocessed, String::new()),
                    ];
                    let expect_failed = if i % 10 == 9 {
                        // Already POSTPROCESSED after the two updates above;
                        // a second RUN_DONE for the same job is illegal and
                        // must come back in THIS response's failed list.
                        updates.push((job, JobState::RunDone, String::new()));
                        vec![job]
                    } else {
                        vec![]
                    };
                    let failed = conn
                        .api(&tok, ApiRequest::SessionSync { session: sid, updates })
                        .unwrap()
                        .job_ids();
                    assert_eq!(failed, expect_failed, "sync #{i} paired with wrong response");
                    // Periodic read-back: every job this client can see on
                    // its site must be one of its own.
                    if i % 25 == 24 {
                        let mine = conn
                            .api(&tok, ApiRequest::ListJobs {
                                filter: JobFilter { site: Some(site), ..Default::default() },
                            })
                            .unwrap()
                            .jobs();
                        assert_eq!(mine.len(), SYNCS);
                        for j in &mine {
                            assert_eq!(j.site_id, site, "foreign job leaked into response");
                        }
                    }
                }
                assert_eq!(
                    conn.connects(),
                    1,
                    "all {} calls must ride one persistent connection",
                    SYNCS + 4
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    for &site in &sites[..2] {
        assert_eq!(svc.store.count_in_state(site, JobState::JobFinished), SYNCS);
    }
    svc.store.check_indexes().unwrap();
    server.stop();
}
