//! Codec equivalence suite: every [`ApiRequest`]/[`ApiResponse`] variant,
//! with Pcg-randomized payloads (empty collections, unicode and
//! astral-plane strings, max-size strings, ids up to 2^53), must satisfy
//!
//! ```text
//! binary_decode(binary_encode(x)) == x == json_decode(json_encode(x))
//! ```
//!
//! Equality is via canonical JSON re-serialization (the API enums carry
//! no `PartialEq` by design — the wire shapes are the contract). The
//! malformed-frame half: every proper prefix of every valid frame, random
//! byte noise, and forged collection counts must decode to an error
//! without panicking and without reserving memory past the frame length.

use balsam::service::api::{
    ApiError, ApiRequest, ApiResponse, Backlog, EventsPage, JobCreate, JobFilter,
};
use balsam::service::codec::json::{request_to_json, response_to_json};
use balsam::service::codec::{frame, json, Wire, WireCodec};
use balsam::service::models::*;
use balsam::util::rng::Pcg;

// ---------------------------------------------------------------------------
// Randomized payload generators (deterministic: seeded Pcg)
// ---------------------------------------------------------------------------

/// Random string from adversarial pieces: empties, JSON-escape-heavy
/// text, multi-byte UTF-8, astral-plane (surrogate-pair) code points.
fn rstr(g: &mut Pcg) -> String {
    const PIECES: &[&str] = &[
        "",
        "a",
        "loadgen-app",
        "π≈3.14159",
        "\"quoted\"",
        "back\\slash",
        "line\nbreak\ttab",
        "𝛿𓀀 astral",
        "emoji 🚀🔬",
        "ctrl \u{1}\u{1f}\u{7f}",
        "日本語",
    ];
    let n = g.below(4) as usize;
    (0..n).map(|_| *g.choose(PIECES)).collect()
}

/// Random id in [0, 2^53): bit-exact through the JSON number path.
fn id(g: &mut Pcg) -> u64 {
    g.next_u64() >> 11
}

/// Random f64 exactly representable in decimal AND binary (0.5 steps),
/// so the JSON text roundtrip is lossless by construction.
fn rf(g: &mut Pcg) -> f64 {
    g.next_u32() as f64 + if g.chance(0.5) { 0.5 } else { 0.0 }
}

fn kv(g: &mut Pcg) -> Vec<(String, String)> {
    (0..g.below(3)).map(|_| (rstr(g), rstr(g))).collect()
}

fn xfers(g: &mut Pcg) -> Vec<(String, u64)> {
    (0..g.below(3)).map(|_| (rstr(g), id(g))).collect()
}

fn jstate(g: &mut Pcg) -> JobState {
    *g.choose(&JobState::ALL)
}

fn tstate(g: &mut Pcg) -> TransferState {
    *g.choose(&[
        TransferState::Pending,
        TransferState::Active,
        TransferState::Done,
        TransferState::Error,
    ])
}

fn bstate(g: &mut Pcg) -> BatchJobState {
    *g.choose(&[
        BatchJobState::Pending,
        BatchJobState::Queued,
        BatchJobState::Running,
        BatchJobState::Finished,
        BatchJobState::Deleted,
    ])
}

fn job_create(g: &mut Pcg) -> JobCreate {
    JobCreate {
        site_id: SiteId(id(g)),
        app: rstr(g),
        workload: rstr(g),
        num_nodes: g.next_u32(),
        params: kv(g),
        tags: kv(g),
        transfers_in: xfers(g),
        transfers_out: xfers(g),
        parents: (0..g.below(3)).map(|_| JobId(id(g))).collect(),
    }
}

fn job(g: &mut Pcg) -> Job {
    Job {
        id: JobId(id(g)),
        site_id: SiteId(id(g)),
        app_id: AppId(id(g)),
        state: jstate(g),
        params: kv(g),
        tags: kv(g),
        num_nodes: g.next_u32(),
        workload: rstr(g),
        parents: (0..g.below(3)).map(|_| JobId(id(g))).collect(),
        attempts: g.next_u32(),
        max_attempts: g.next_u32(),
        session: g.chance(0.5).then(|| SessionId(id(g))),
        created_at: rf(g),
    }
}

fn batch_job(g: &mut Pcg) -> BatchJob {
    BatchJob {
        id: BatchJobId(id(g)),
        site_id: SiteId(id(g)),
        num_nodes: g.next_u32(),
        wall_time_s: rf(g),
        mode: if g.chance(0.5) { JobMode::Mpi } else { JobMode::Serial },
        queue: rstr(g),
        project: rstr(g),
        state: bstate(g),
        local_id: g.chance(0.5).then(|| id(g)),
        created_at: rf(g),
        started_at: g.chance(0.5).then(|| rf(g)),
        ended_at: g.chance(0.5).then(|| rf(g)),
    }
}

fn transfer_item(g: &mut Pcg) -> TransferItem {
    TransferItem {
        id: TransferItemId(id(g)),
        job_id: JobId(id(g)),
        site_id: SiteId(id(g)),
        direction: if g.chance(0.5) { Direction::In } else { Direction::Out },
        remote: rstr(g),
        size_bytes: id(g),
        state: tstate(g),
        task_id: g.chance(0.5).then(|| XferTaskId(id(g))),
    }
}

fn event(g: &mut Pcg) -> Event {
    Event {
        seq: id(g),
        job_id: JobId(id(g)),
        site_id: SiteId(id(g)),
        ts: rf(g),
        from: jstate(g),
        to: jstate(g),
        data: rstr(g),
    }
}

/// One randomized instance of every request variant (all 22).
fn all_requests(g: &mut Pcg) -> Vec<ApiRequest> {
    vec![
        ApiRequest::CreateUser { name: rstr(g) },
        ApiRequest::CreateSite { name: rstr(g), hostname: rstr(g), path: rstr(g) },
        ApiRequest::RegisterApp {
            site: SiteId(id(g)),
            name: rstr(g),
            command_template: rstr(g),
            parameters: (0..g.below(4)).map(|_| rstr(g)).collect(),
        },
        ApiRequest::BulkCreateJobs { jobs: (0..g.below(4)).map(|_| job_create(g)).collect() },
        ApiRequest::ListJobs {
            filter: JobFilter {
                site: g.chance(0.5).then(|| SiteId(id(g))),
                states: (0..g.below(3)).map(|_| jstate(g)).collect(),
                tags: kv(g),
                limit: g.next_u32() as usize,
            },
        },
        ApiRequest::CountByState { site: SiteId(id(g)) },
        ApiRequest::UpdateJobState { job: JobId(id(g)), to: jstate(g), data: rstr(g) },
        ApiRequest::BulkUpdateJobState {
            jobs: (0..g.below(4)).map(|_| JobId(id(g))).collect(),
            to: jstate(g),
            data: rstr(g),
        },
        ApiRequest::CreateSession {
            site: SiteId(id(g)),
            batch_job: g.chance(0.5).then(|| BatchJobId(id(g))),
        },
        ApiRequest::SessionAcquire {
            session: SessionId(id(g)),
            max_nodes: g.next_u32(),
            max_jobs: g.next_u32() as usize,
        },
        ApiRequest::SessionHeartbeat { session: SessionId(id(g)) },
        ApiRequest::SessionSync {
            session: SessionId(id(g)),
            updates: (0..g.below(4)).map(|_| (JobId(id(g)), jstate(g), rstr(g))).collect(),
        },
        ApiRequest::SessionEnd { session: SessionId(id(g)) },
        ApiRequest::CreateBatchJob {
            site: SiteId(id(g)),
            num_nodes: g.next_u32(),
            wall_time_s: rf(g),
            mode: if g.chance(0.5) { JobMode::Mpi } else { JobMode::Serial },
            queue: rstr(g),
            project: rstr(g),
        },
        ApiRequest::ListBatchJobs { site: SiteId(id(g)), active_only: g.chance(0.5) },
        ApiRequest::UpdateBatchJob {
            id: BatchJobId(id(g)),
            state: bstate(g),
            local_id: g.chance(0.5).then(|| id(g)),
        },
        ApiRequest::PendingTransferItems {
            site: SiteId(id(g)),
            direction: if g.chance(0.5) { Direction::In } else { Direction::Out },
            limit: g.next_u32() as usize,
        },
        ApiRequest::UpdateTransferItems {
            ids: (0..g.below(4)).map(|_| TransferItemId(id(g))).collect(),
            state: tstate(g),
            task_id: g.chance(0.5).then(|| XferTaskId(id(g))),
        },
        ApiRequest::SyncTransferItems {
            updates: (0..g.below(4))
                .map(|_| {
                    (TransferItemId(id(g)), tstate(g), g.chance(0.5).then(|| XferTaskId(id(g))))
                })
                .collect(),
        },
        ApiRequest::SiteBacklog { site: SiteId(id(g)) },
        ApiRequest::ListEvents { since: g.next_u32() as usize },
        ApiRequest::WatchEvents {
            site: g.chance(0.5).then(|| SiteId(id(g))),
            since: g.next_u32() as usize,
            timeout_ms: id(g),
            max_events: g.next_u32() as usize,
        },
    ]
}

/// One randomized instance of every response variant (all 13).
fn all_responses(g: &mut Pcg) -> Vec<ApiResponse> {
    vec![
        ApiResponse::Unit,
        ApiResponse::UserId(UserId(id(g))),
        ApiResponse::SiteId(SiteId(id(g))),
        ApiResponse::AppId(AppId(id(g))),
        ApiResponse::JobIds((0..g.below(4)).map(|_| JobId(id(g))).collect()),
        ApiResponse::Jobs((0..g.below(3)).map(|_| job(g)).collect()),
        ApiResponse::Counts((0..g.below(3)).map(|_| (jstate(g), g.next_u32() as usize)).collect()),
        ApiResponse::SessionId(SessionId(id(g))),
        ApiResponse::BatchJobId(BatchJobId(id(g))),
        ApiResponse::BatchJobs((0..g.below(3)).map(|_| batch_job(g)).collect()),
        ApiResponse::TransferItems((0..g.below(3)).map(|_| transfer_item(g)).collect()),
        ApiResponse::Backlog(Backlog {
            backlog_jobs: g.next_u32() as usize,
            runnable_nodes: g.next_u32(),
            inflight_nodes: g.next_u32(),
            batch_nodes: g.next_u32(),
        }),
        ApiResponse::Events(EventsPage {
            truncated_before: g.chance(0.5).then(|| id(g)),
            events: (0..g.below(3)).map(|_| event(g)).collect(),
        }),
    ]
}

// ---------------------------------------------------------------------------
// Triple-equality roundtrips
// ---------------------------------------------------------------------------

/// `binary_decode(binary_encode(x)) == x == json_decode(json_encode(x))`,
/// judged by canonical JSON re-serialization.
fn assert_request_roundtrips(req: &ApiRequest) {
    let canon = request_to_json(req).to_string();
    for wire in [Wire::Json, Wire::Binary] {
        let c = wire.codec();
        let mut buf = Vec::new();
        c.encode_request(req, &mut buf);
        let dec = c
            .decode_request(&buf)
            .unwrap_or_else(|e| panic!("{} decode of {}: {e}", wire.label(), req.name()));
        assert_eq!(
            request_to_json(&dec).to_string(),
            canon,
            "{} roundtrip of {} diverged",
            wire.label(),
            req.name()
        );
    }
}

fn assert_response_roundtrips(resp: &ApiResponse) {
    let canon = response_to_json(resp).to_string();
    for wire in [Wire::Json, Wire::Binary] {
        let c = wire.codec();
        let mut buf = Vec::new();
        c.encode_ok(resp, &mut buf);
        let dec = c
            .decode_ok(&buf)
            .unwrap_or_else(|e| panic!("{} decode_ok of {canon}: {e}", wire.label()));
        assert_eq!(
            response_to_json(&dec).to_string(),
            canon,
            "{} roundtrip diverged",
            wire.label()
        );
    }
}

#[test]
fn every_request_variant_roundtrips_through_both_codecs() {
    for seed in 0..16u64 {
        let mut g = Pcg::seeded(0xC0DEC ^ seed);
        let reqs = all_requests(&mut g);
        assert_eq!(reqs.len(), 22, "a new ApiRequest variant is missing from this suite");
        for req in &reqs {
            assert_request_roundtrips(req);
        }
    }
}

#[test]
fn every_response_variant_roundtrips_through_both_codecs() {
    for seed in 0..16u64 {
        let mut g = Pcg::seeded(0xD0DEC ^ seed);
        let resps = all_responses(&mut g);
        assert_eq!(resps.len(), 13, "a new ApiResponse variant is missing from this suite");
        for resp in &resps {
            assert_response_roundtrips(resp);
        }
    }
}

#[test]
fn max_size_strings_and_empty_collections_roundtrip() {
    // 256 KiB of escape-heavy text: far past any inline-buffer fast path.
    let big: String = "x\"\\\n𝛿".repeat(32 * 1024);
    assert_request_roundtrips(&ApiRequest::CreateUser { name: big.clone() });
    assert_request_roundtrips(&ApiRequest::SessionSync {
        session: SessionId(u64::MAX >> 11),
        updates: vec![(JobId(0), JobState::RunDone, big.clone())],
    });
    // Explicit empties everywhere a collection can be empty.
    assert_request_roundtrips(&ApiRequest::BulkCreateJobs { jobs: vec![] });
    assert_request_roundtrips(&ApiRequest::BulkUpdateJobState {
        jobs: vec![],
        to: JobState::Created,
        data: String::new(),
    });
    assert_request_roundtrips(&ApiRequest::SyncTransferItems { updates: vec![] });
    assert_request_roundtrips(&ApiRequest::ListJobs { filter: JobFilter::default() });
    assert_response_roundtrips(&ApiResponse::JobIds(vec![]));
    assert_response_roundtrips(&ApiResponse::Jobs(vec![]));
    assert_response_roundtrips(&ApiResponse::Events(EventsPage::default()));
    let mut err = Vec::new();
    frame::FrameCodec.encode_err(&big, &mut err);
    assert_eq!(frame::FrameCodec.decode_err(&err), big);
}

#[test]
fn error_envelopes_roundtrip_in_both_codecs() {
    for msg in ["", "not found: site 9", "π 🚀 \"quoted\\path\"\n"] {
        for wire in [Wire::Json, Wire::Binary] {
            let c = wire.codec();
            let mut buf = Vec::new();
            c.encode_err(msg, &mut buf);
            assert_eq!(c.decode_err(&buf), msg, "{} error envelope", wire.label());
            match c.decode_ok(&buf) {
                Err(ApiError::Transport(m)) => assert_eq!(m, msg),
                other => panic!("error envelope must decode_ok to Transport, got {other:?}"),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Malformed frames: errors, never panics, never allocation blowup
// ---------------------------------------------------------------------------

#[test]
fn every_prefix_of_every_frame_errors_cleanly() {
    let mut g = Pcg::seeded(0xBADF);
    for req in all_requests(&mut g) {
        let mut buf = Vec::new();
        frame::encode_request(&req, &mut buf);
        for cut in 0..buf.len() {
            assert!(
                frame::decode_request(&buf[..cut]).is_err(),
                "{}: prefix {cut}/{} decoded",
                req.name(),
                buf.len()
            );
        }
        buf.push(0xff);
        assert_eq!(frame::decode_request(&buf).unwrap_err(), "trailing bytes in frame");
    }
    for resp in all_responses(&mut g) {
        let mut buf = Vec::new();
        frame::encode_ok(&resp, &mut buf);
        for cut in 0..buf.len() {
            assert!(frame::decode_response(&buf[..cut]).is_err(), "prefix {cut} decoded");
        }
        buf.push(0xff);
        assert_eq!(frame::decode_response(&buf).unwrap_err(), "trailing bytes in frame");
    }
}

#[test]
fn random_byte_noise_never_panics_either_decoder() {
    let mut g = Pcg::seeded(0xF422);
    for _ in 0..2_000 {
        let n = g.below(64) as usize;
        let mut noise: Vec<u8> = (0..n).map(|_| g.next_u32() as u8).collect();
        let _ = frame::decode_request(&noise);
        let _ = frame::decode_response(&noise);
        let _ = json::JsonCodec.decode_request(&noise);
        let _ = json::JsonCodec.decode_ok(&noise);
        // Same noise behind a valid-looking frame header: exercises the
        // per-variant field decoders instead of dying at the kind byte.
        noise.insert(0, (g.next_u32() % 24) as u8);
        noise.insert(0, 0x01);
        let _ = frame::decode_request(&noise);
        noise[0] = 0x02;
        let _ = frame::decode_response(&noise);
    }
}

#[test]
fn forged_counts_cannot_reserve_past_frame_length() {
    // A tiny frame claiming a huge collection must fail the count check
    // (one byte minimum per element) before any Vec reservation. A forged
    // string length must likewise fail its bounds check.
    let huge = u64::MAX >> 1;
    // BulkCreateJobs (tag 3) with a forged job count.
    let mut f = vec![0x01, 3];
    put_varint(&mut f, huge);
    assert!(frame::decode_request(&f).is_err());
    // Jobs response (tag 5) with a forged row count.
    let mut f = vec![0x02, 5];
    put_varint(&mut f, huge);
    assert!(frame::decode_response(&f).is_err());
    // CreateUser (tag 0) with a forged string length.
    let mut f = vec![0x01, 0];
    put_varint(&mut f, huge);
    f.extend_from_slice(b"tiny");
    assert_eq!(frame::decode_request(&f).unwrap_err(), "truncated frame");
}

/// Local LEB128 writer so forged-frame tests don't depend on encoder
/// internals.
fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}
