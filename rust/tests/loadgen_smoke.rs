//! End-to-end smoke of the open-loop load harness: a tiny self-hosted
//! sweep must complete, declare a verdict per combo, measure server-side
//! latency from `/metrics`, and serialize the report the `loadgen` axis
//! of `BENCH_service.json` expects. Rates and rungs are kept small — this
//! pins the machinery (open-loop accounting, scrape deltas, stop rule,
//! JSON shape), not the capacity of the CI runner.

use balsam::loadgen::mix::Mix;
use balsam::loadgen::{run, run_fairness, FairnessConfig, LoadgenConfig};
use balsam::util::json::Json;

fn smoke_config() -> LoadgenConfig {
    LoadgenConfig {
        mixes: vec![Mix::SyncHeavy],
        sites_list: vec![1],
        sessions_list: vec![2],
        rps_start: 40.0,
        rps_factor: 4.0,
        rps_steps: 2,
        step_secs: 0.4,
        workers: 4,
        log: false,
        ..LoadgenConfig::default()
    }
}

#[test]
fn sweep_measures_and_declares() {
    let report = run(&smoke_config()).expect("loadgen sweep");
    assert_eq!(report.combos.len(), 1);
    let combo = &report.combos[0];
    assert_eq!((combo.mix, combo.sites, combo.sessions), (Mix::SyncHeavy, 1, 2));
    assert!(!combo.steps.is_empty() && combo.steps.len() <= 2);
    assert!(
        ["failure-rate", "median-latency", "ladder-exhausted"].contains(&combo.declared_by),
        "unknown verdict {}",
        combo.declared_by
    );

    let first = &combo.steps[0];
    assert_eq!(first.offered_rps, 40.0);
    // 40 rps over 0.4 s = 16 planned ticks, every one accounted for.
    assert_eq!(first.planned, 16);
    assert_eq!(first.issued + first.skipped, first.planned);
    assert_eq!(first.ok + first.errors + first.rejected, first.issued);
    // No rate limiter and no saturation at 40 rps: nothing rejected.
    assert_eq!(first.rejected, 0);
    assert!(first.elapsed_s > 0.0);
    assert!((0.0..=1.0).contains(&first.failure_rate));

    // 40 rps of the sync lifecycle is trivially sustainable: the first
    // rung must pass, mostly succeed, and carry server-side latency read
    // back from /metrics.
    assert!(first.ok > first.planned / 2, "only {}/{} ok", first.ok, first.planned);
    let p50 = first.p50_ms.expect("server-side p50 from /metrics");
    let p95 = first.p95_ms.expect("server-side p95 from /metrics");
    let p99 = first.p99_ms.expect("server-side p99 from /metrics");
    assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "quantiles out of order: {p50} {p95} {p99}");
    // Ephemeral self-host: nothing fsyncs.
    assert!(first.fsync_p95_ms.is_none());
    if combo.declared_by == "ladder-exhausted" {
        assert!(combo.max_sustainable_rps >= first.achieved_rps);
        assert!(combo.stopped_at_rps.is_none());
    } else {
        assert!(combo.stopped_at_rps.is_some());
    }

    // The report round-trips through the JSON codec with the axis shape
    // bench_trend.py keys on.
    let j = Json::parse(&report.to_json().to_string()).expect("report JSON parses");
    let c = j.get("combos").and_then(|c| c.idx(0)).expect("combos[0]");
    for field in ["mix", "sites", "sessions", "max_sustainable_rps", "declared_by", "steps"] {
        assert!(c.get(field).is_some(), "combo missing {field}");
    }
    let s0 = c.get("steps").and_then(|s| s.idx(0)).expect("steps[0]");
    assert_eq!(s0.get("offered_rps").and_then(Json::as_f64), Some(40.0));
    assert_eq!(s0.get("planned").and_then(Json::as_f64), Some(16.0));
}

/// An unsustainable offered rate must trip the failure-rate stop rule:
/// two senders cannot honor a 200k rps schedule, so overdue ticks are
/// skipped and counted as failures, the ladder halts, and the combo still
/// reports a (possibly zero) declared capacity instead of hanging.
#[test]
fn overload_trips_the_stop_rule() {
    let cfg = LoadgenConfig {
        mixes: vec![Mix::SubmitHeavy],
        rps_start: 200_000.0,
        rps_factor: 4.0,
        rps_steps: 3,
        step_secs: 0.3,
        ..smoke_config()
    };
    let report = run(&cfg).expect("loadgen sweep");
    let combo = &report.combos[0];
    assert_eq!(combo.declared_by, "failure-rate");
    assert_eq!(combo.steps.len(), 1, "ladder must halt at the tripped rung");
    assert_eq!(combo.stopped_at_rps, Some(200_000.0));
    assert_eq!(combo.max_sustainable_rps, 0.0, "no rung passed");
    let step = &combo.steps[0];
    assert!(step.skipped > 0, "an impossible schedule must shed ticks");
    assert!(step.failure_rate > cfg.stop_failure_rate);
}

/// Tentpole scenario: one greedy tenant hammering far past its
/// per-principal quota must be the one absorbing the 429s, while N
/// polite tenants under quota keep being served within the latency SLO.
/// Latency ratios are asserted loosely (CI machines are noisy); the
/// strict 2x gate runs in the CI fairness leg over longer phases.
#[test]
fn greedy_tenant_is_throttled_polite_tenants_are_served() {
    let cfg = FairnessConfig {
        polite: 2,
        greedy: 1,
        polite_rps: 10.0,
        greedy_rps: 200.0,
        duration_s: 0.6,
        rate_limit: (25, 25),
        workers: 4,
        log: false,
        ..FairnessConfig::default()
    };
    let report = run_fairness(&cfg).expect("fairness probe");
    // The greedy tenant offered ~8x its quota: most answers are 429s,
    // and they land on the greedy principal only.
    assert!(report.greedy.issued > 0);
    assert!(
        report.greedy.rejected > report.greedy.issued / 2,
        "greedy tenant must be mostly throttled: {}/{} rejected",
        report.greedy.rejected,
        report.greedy.issued
    );
    assert_eq!(report.polite.rejected, 0, "polite tenants must never absorb the throttle");
    assert_eq!(report.baseline.rejected, 0);
    // Polite tenants keep being served under contention, within the
    // declared 300 ms SLO (loopback: normally well under 10 ms).
    assert!(report.polite.ok > 0);
    let p50 = report.polite.p50_ms.expect("polite latency measured under contention");
    assert!(p50 < 300.0, "polite p50 {p50} ms breaches the SLO under a greedy tenant");

    // Report shape: the whole thing survives a JSON round trip with the
    // fields fairness_summary.py gates on.
    let j = Json::parse(&report.to_json().to_string()).expect("fairness JSON parses");
    for field in ["baseline", "polite", "greedy", "degradation_p99", "rate_limit_rps"] {
        assert!(j.get(field).is_some(), "fairness report missing {field}");
    }
    let greedy = j.get("greedy").unwrap();
    assert!(greedy.get("rejected").and_then(Json::as_f64).unwrap() > 0.0);
}
