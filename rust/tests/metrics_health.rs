//! Observability integration: the gateway's unauthenticated `/metrics`
//! and `/healthz` endpoints against a live service — health flips on
//! persist poisoning, scrapes never consume a watch-parking permit, and
//! an HTTP round trip under the group-commit WAL populates every
//! subsystem's series. The registry is process-global, so every value
//! assertion here is monotone (`>= 1`, `contains`) — never exact.

use std::sync::Arc;
use std::time::{Duration, Instant};

use balsam::service::api::{ApiConn, ApiRequest, JobCreate};
use balsam::service::http_gw::{serve_with, HttpConn};
use balsam::service::models::SiteId;
use balsam::service::{EventLogConfig, FsyncPolicy, PersistMode, ServiceCore};
use balsam::util::httpd::{post_json, request, HttpConfig};
use balsam::util::metrics;

fn wal_service(tag: &str, fsync: FsyncPolicy) -> (Arc<ServiceCore>, String, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!("balsam-metrics-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mode = PersistMode::Wal {
        dir: dir.clone(),
        snapshot_every: 4096,
        fsync,
        events: EventLogConfig::default(),
    };
    let svc = Arc::new(ServiceCore::with_persist(b"metrics-int", mode).unwrap());
    let tok = svc.admin_token();
    (svc, tok, dir)
}

fn create_site(svc: &ServiceCore, tok: &str) -> SiteId {
    let site = svc
        .handle(0.0, tok, ApiRequest::CreateSite {
            name: "obs".into(),
            hostname: "h".into(),
            path: "/p".into(),
        })
        .unwrap()
        .site_id();
    svc.handle(0.0, tok, ApiRequest::RegisterApp {
        site,
        name: "MD".into(),
        command_template: "md".into(),
        parameters: vec![],
    })
    .unwrap();
    site
}

/// GET an operational endpoint (no auth header, dedicated connection).
fn get(addr: &str, path: &str) -> (u16, String) {
    let (status, body) = request(addr, "GET", path, &[], &[]).unwrap();
    (status, String::from_utf8_lossy(&body).into_owned())
}

/// Value of one exposition series (exact name including any labels).
fn series_value(text: &str, series: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        l.strip_prefix(series)
            .and_then(|rest| rest.strip_prefix(' '))
            .and_then(|v| v.trim().parse().ok())
    })
}

/// `/healthz` is 200 on a healthy durable store and flips to 503 the
/// moment a WAL I/O failure poisons the persist handle — with the
/// `balsam_persist_poisoned` gauge latching to 1 on the same event.
#[test]
fn healthz_flips_503_when_persist_poisons() {
    metrics::set_enabled(true);
    let (svc, _tok, dir) = wal_service("health", FsyncPolicy::Never);
    let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let server = serve_with(svc.clone(), "127.0.0.1:0", 2, cfg).unwrap();

    let (status, body) = get(&server.addr, "/healthz");
    assert_eq!(status, 200, "healthy store must probe 200: {body}");
    assert_eq!(body.trim(), "ok");

    // Inject the WAL fault (same hook persist_recovery.rs uses).
    svc.store.poison_persist("injected: disk gone");

    let (status, body) = get(&server.addr, "/healthz");
    assert_eq!(status, 503, "poisoned store must probe 503: {body}");
    assert!(body.contains("persist poisoned"), "{body}");
    assert!(body.contains("injected: disk gone"), "{body}");

    // The scrape surface agrees (and needs no auth either).
    let (status, text) = get(&server.addr, "/metrics");
    assert_eq!(status, 200, "scrapes must keep working while poisoned");
    assert_eq!(series_value(&text, "balsam_persist_poisoned"), Some(1.0), "{text}");

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `/metrics` and `/healthz` never occupy a `WatchEvents` parking permit:
/// with 2 workers the gateway grants exactly 1 permit, a subscriber holds
/// it parked, and scrapes still answer immediately on the remaining
/// worker — then the parked subscriber is woken by a real event, proving
/// the scrape did not displace it.
#[test]
fn metrics_scrape_never_occupies_a_parking_slot() {
    metrics::set_enabled(true);
    let svc = Arc::new(ServiceCore::new(b"metrics-park"));
    let tok = svc.admin_token();
    let site = create_site(&svc, &tok);
    let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let server = serve_with(svc.clone(), "127.0.0.1:0", 2, cfg).unwrap();

    let since = svc.store.event_horizon();
    let addr = server.addr.clone();
    let wtok = tok.clone();
    let watcher = std::thread::spawn(move || {
        let body = format!("{{\"type\":\"WatchEvents\",\"since\":{since},\"timeout_ms\":10000}}");
        post_json(&addr, "/api", &wtok, &body).unwrap()
    });
    // Let the watch arm and park (it holds the single permit and pins one
    // of the two workers for up to 10 s).
    std::thread::sleep(Duration::from_millis(200));

    // Scrapes must answer promptly on the remaining worker: if either
    // endpoint needed a parking permit (or a worker beyond the one left),
    // these would stall toward the 10 s watch timeout.
    for path in ["/metrics", "/healthz"] {
        let t0 = Instant::now();
        let (status, _) = get(&server.addr, path);
        assert_eq!(status, 200);
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "{path} stalled {:?} behind a parked watcher",
            t0.elapsed()
        );
    }

    // The subscriber still holds its slot: a real event wakes it well
    // before its 10 s timeout.
    let t_wake = Instant::now();
    svc.handle(0.0, &tok, ApiRequest::BulkCreateJobs {
        jobs: vec![JobCreate::simple(site, "MD", "md_small")],
    })
    .unwrap();
    let (status, body) = watcher.join().unwrap();
    assert_eq!(status, 200);
    assert!(
        t_wake.elapsed() < Duration::from_secs(5),
        "watch must wake on the event, not time out ({:?})",
        t_wake.elapsed()
    );
    assert!(body.contains("events"), "woken watch must carry an events page: {body}");

    // And the park itself was recorded.
    let (_, text) = get(&server.addr, "/metrics");
    assert!(series_value(&text, "balsam_watch_park_total").unwrap_or(0.0) >= 1.0, "{text}");
    server.stop();
}

/// One durable HTTP round trip (group-commit WAL) populates every
/// subsystem's families: per-endpoint request counts and latency
/// histograms, WAL append/fsync latency, group-commit batch sizes,
/// watcher park counters, connection gauges, and the store's per-shard
/// hot-depth series.
#[test]
fn metrics_populated_after_durable_round_trip() {
    metrics::set_enabled(true);
    let group = FsyncPolicy::Group { records: 64, interval_ms: 2 };
    let (svc, tok, dir) = wal_service("populate", group);
    let site = create_site(&svc, &tok);
    let cfg = HttpConfig { keep_alive: true, ..HttpConfig::default() };
    let server = serve_with(svc.clone(), "127.0.0.1:0", 2, cfg.clone()).unwrap();
    let mut conn = HttpConn::with_config(server.addr.clone(), cfg);

    // Durable mutations (each BulkCreateJobs awaits a group commit) plus
    // a read and a short watch that genuinely parks (nothing newer than
    // the horizon exists, so it waits out its timeout).
    for _ in 0..3 {
        conn.api(&tok, ApiRequest::BulkCreateJobs {
            jobs: vec![JobCreate::simple(site, "MD", "md_small")],
        })
        .unwrap();
    }
    conn.api(&tok, ApiRequest::ListEvents { since: 0 }).unwrap();
    let horizon = svc.store.event_horizon();
    conn.api(&tok, ApiRequest::WatchEvents {
        site: Some(site),
        since: horizon,
        timeout_ms: 150,
        max_events: 0,
    })
        .unwrap();

    let (status, text) = get(&server.addr, "/metrics");
    assert_eq!(status, 200);

    // Per-endpoint series carry the wire discriminator as the label.
    for series in [
        "balsam_api_requests_total{endpoint=\"BulkCreateJobs\"}",
        "balsam_api_requests_total{endpoint=\"ListEvents\"}",
        "balsam_api_requests_total{endpoint=\"WatchEvents\"}",
        "balsam_api_request_seconds_count{endpoint=\"BulkCreateJobs\"}",
    ] {
        assert!(series_value(&text, series).unwrap_or(0.0) >= 1.0, "{series} missing:\n{text}");
    }
    // WAL instrumentation: appends, group-leader fsyncs, batch sizes.
    for series in [
        "balsam_wal_append_seconds_count",
        "balsam_wal_fsync_seconds_count",
        "balsam_wal_group_commit_records_count",
        "balsam_watch_park_total",
        "balsam_http_connections_total",
    ] {
        assert!(series_value(&text, series).unwrap_or(0.0) >= 1.0, "{series} missing:\n{text}");
    }
    // The WatchEvents histogram saw the park: its recorded wall time is
    // at least the 150 ms hang, so the +Inf bucket is populated while the
    // smallest bucket stays behind it (sanity of the le layout).
    let inf = "balsam_api_request_seconds_bucket{endpoint=\"WatchEvents\",le=\"+Inf\"}";
    assert!(series_value(&text, inf).unwrap_or(0.0) >= 1.0, "{text}");
    // Store-side scrape-time series: one gauge per live site shard.
    assert!(
        series_value(&text, &format!("balsam_events_hot_depth{{site=\"{}\"}}", site.0))
            .unwrap_or(0.0)
            >= 1.0,
        "{text}"
    );
    // Worker-pool gauge reflects the serve_with sizing.
    assert!(series_value(&text, "balsam_http_worker_pool_size").unwrap_or(0.0) >= 1.0);

    server.stop();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite pin: the operational endpoints bypass load shedding. While
/// a flood keeps the 8-deep accept queue saturated and the gateway is
/// actively answering 503s on `/api`, `/metrics` and `/healthz` keep
/// answering 200 — and the shed counter proves the overload was real,
/// not a quiet server.
#[test]
fn scrapes_succeed_while_the_gateway_sheds() {
    use std::sync::atomic::{AtomicBool, Ordering};
    metrics::set_enabled(true);
    let svc = Arc::new(ServiceCore::new(b"metrics-shed"));
    let tok = svc.admin_token();
    // One worker + a shallow queue: a dozen concurrent dialers keep the
    // backlog pinned past the limit for the whole test window, while
    // staying far below the 4x blind-shed tier (which is path-unaware
    // and would shed scrapes too).
    let cfg = HttpConfig { accept_queue_limit: 8, ..HttpConfig::default() };
    let server = serve_with(svc.clone(), "127.0.0.1:0", 1, cfg).unwrap();

    let stop = Arc::new(AtomicBool::new(false));
    let floods: Vec<_> = (0..12)
        .map(|_| {
            let addr = server.addr.clone();
            let tok = tok.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut sheds = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    if let Ok((status, _)) =
                        post_json(&addr, "/api", &tok, "{\"type\":\"ListEvents\",\"since\":0}")
                    {
                        if status == 503 || status == 429 {
                            sheds += 1;
                        }
                    }
                }
                sheds
            })
        })
        .collect();

    // Scrape in the middle of the flood: both operational endpoints must
    // answer 200 even as /api connections are shed around them.
    std::thread::sleep(Duration::from_millis(300));
    for path in ["/healthz", "/metrics"] {
        let t0 = Instant::now();
        let (status, body) = get(&server.addr, path);
        assert_eq!(status, 200, "{path} must bypass shedding: {body}");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "{path} took {:?} under flood",
            t0.elapsed()
        );
    }
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Ordering::Relaxed);
    let shed_seen: u64 = floods.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(shed_seen > 0, "flood never tripped the 8-deep accept queue");
    let (_, text) = get(&server.addr, "/metrics");
    assert!(series_value(&text, "balsam_http_shed_total").unwrap_or(0.0) >= 1.0, "{text}");
    server.stop();
}

/// Doc-check: `docs/OPERATIONS.md` catalogs every family the registry
/// exports — a metric added without documentation fails here.
#[test]
fn operations_doc_catalogs_every_exported_metric() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("docs")
        .join("OPERATIONS.md");
    let doc =
        std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    for name in metrics::family_names() {
        assert!(
            doc.contains(name),
            "metric family `{name}` is exported but not cataloged in docs/OPERATIONS.md"
        );
    }
}
