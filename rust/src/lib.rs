//! # balsam-rs
//!
//! A ground-up reproduction of **Balsam** — "Toward Real-time Analysis of
//! Experimental Science Workloads on Geographically Distributed
//! Supercomputers" (Salim, Uram, Childers, Vishwanath, Papka; 2021) — as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! Layer 3 (this crate) implements the paper's contribution: the central
//! multi-tenant workflow **service** ([`service`]), the user-space **site
//! agents** ([`site`]) with their Transfer / Scheduler / Elastic-Queue /
//! Launcher modules, and the light-source **clients** ([`client`]) — plus
//! every substrate the evaluation depends on ([`substrates`]): the ESNet
//! WAN + GridFTP transfer fabric, the Globus transfer-task service, and
//! the Cobalt/Slurm/LSF batch schedulers.
//!
//! Layers 2/1 (JAX model + Pallas kernels, `python/compile/`) are AOT
//! compiled to HLO-text artifacts which [`runtime`] loads and executes
//! through the PJRT CPU client (vendored `xla` crate behind the
//! off-by-default `xla` cargo feature). Python is never on the request
//! path.
//!
//! The same coordinator logic runs in two modes:
//! * **Simulated time** — a discrete-event engine ([`sim`]) regenerates the
//!   paper's 19–80 minute experiments (§4, [`experiments`]) in seconds.
//! * **Real time** — threads, a hand-rolled HTTP/1.1 transport
//!   ([`util::httpd`]), and real PJRT numerics (examples `quickstart`,
//!   `e2e_xpcs`).

pub mod util;
pub mod sim;
pub mod service;
pub mod loadgen;
pub mod substrates;
pub mod site;
pub mod client;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod experiments;
pub mod world;

/// Crate-wide result alias (boxed dynamic error; see [`util::error`]).
pub type Result<T> = util::error::Result<T>;
