//! Evaluation metrics over the Balsam event log (paper §4.1.4).
//!
//! Everything the figures/tables plot is derived here: per-stage latency
//! distributions (Table 1, Fig. 4, Fig. 8), throughput timelines
//! (Figs. 3/7/9), node-utilization traces and the Little's-law check
//! (Fig. 10).

use std::collections::BTreeMap;

use crate::service::models::{Event, Job, JobId, JobState, SiteId};
use crate::util::stats::{Summary, Timeline};

/// Per-job stage latencies (seconds), the paper's Table-1 decomposition.
#[derive(Debug, Clone, Default)]
pub struct StageDurations {
    pub stage_in: Option<f64>,
    /// Data arrival -> application start (paper "Run Delay").
    pub run_delay: Option<f64>,
    pub run: Option<f64>,
    pub stage_out: Option<f64>,
    pub time_to_solution: Option<f64>,
}

/// Extract per-job stage durations from the event log.
///
/// Uses the *first* occurrence of each transition (retries are charged to
/// run delay, as the paper's pipeline view does).
pub fn stage_durations(events: &[Event], jobs: &BTreeMap<JobId, Job>) -> BTreeMap<JobId, StageDurations> {
    let mut ts: BTreeMap<JobId, BTreeMap<JobState, f64>> = BTreeMap::new();
    for e in events {
        ts.entry(e.job_id).or_default().entry(e.to).or_insert(e.ts);
    }
    let mut out = BTreeMap::new();
    for (job_id, m) in ts {
        let get = |s: JobState| m.get(&s).copied();
        let mut d = StageDurations::default();
        if let (Some(a), Some(b)) = (get(JobState::Ready), get(JobState::StagedIn)) {
            d.stage_in = Some(b - a);
        }
        if let (Some(a), Some(b)) = (get(JobState::StagedIn), get(JobState::Running)) {
            d.run_delay = Some(b - a);
        }
        if let (Some(a), Some(b)) = (get(JobState::Running), get(JobState::RunDone)) {
            d.run = Some(b - a);
        }
        if let (Some(a), Some(b)) = (get(JobState::Postprocessed), get(JobState::JobFinished)) {
            d.stage_out = Some(b - a);
        }
        if let Some(end) = get(JobState::JobFinished) {
            if let Some(job) = jobs.get(&job_id) {
                d.time_to_solution = Some(end - job.created_at);
            }
        }
        out.insert(job_id, d);
    }
    out
}

/// Aggregate a stage across jobs into a [`Summary`] (Table-1 cells).
pub fn summarize_stage<F: Fn(&StageDurations) -> Option<f64>>(
    durs: &BTreeMap<JobId, StageDurations>,
    pick: F,
) -> Summary {
    let mut s = Summary::new();
    for d in durs.values() {
        if let Some(x) = pick(d) {
            s.add(x);
        }
    }
    s
}

/// Timeline of jobs entering `state` at `site` (cumulative curves in
/// Figs. 3/7/9).
pub fn state_timeline(events: &[Event], site: SiteId, state: JobState) -> Timeline {
    let mut tl = Timeline::new();
    for e in events {
        if e.site_id == site && e.to == state {
            tl.record(e.ts);
        }
    }
    tl
}

/// Completed-job throughput (jobs/s) at `site` over `[t0, t1]`.
pub fn completion_rate(events: &[Event], site: SiteId, t0: f64, t1: f64) -> f64 {
    state_timeline(events, site, JobState::JobFinished).rate(t0, t1)
}

/// Number of concurrently RUNNING tasks at `site`, sampled on a grid of
/// `n` points over `[0, end]` (Fig. 7 bottom / Fig. 10 utilization).
pub fn running_tasks_curve(events: &[Event], site: SiteId, end: f64, n: usize) -> Vec<(f64, usize)> {
    // Build +1/-1 deltas at Running entry/exit.
    let mut deltas: Vec<(f64, i64)> = Vec::new();
    for e in events {
        if e.site_id != site {
            continue;
        }
        if e.to == JobState::Running {
            deltas.push((e.ts, 1));
        }
        if e.from == JobState::Running {
            deltas.push((e.ts, -1));
        }
    }
    deltas.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut out = Vec::with_capacity(n + 1);
    let mut level = 0i64;
    let mut di = 0usize;
    for i in 0..=n {
        let t = end * i as f64 / n as f64;
        while di < deltas.len() && deltas[di].0 <= t {
            level += deltas[di].1;
            di += 1;
        }
        out.push((t, level.max(0) as usize));
    }
    out
}

/// Little's-law check (Fig. 10): expected number of running tasks
/// L = λW from the measured arrival rate λ (staged-in datasets/s over the
/// window) and mean run time W; returned with the measured time-average
/// running count for comparison.
pub struct LittleCheck {
    pub lambda: f64,
    pub mean_runtime: f64,
    /// λW — expected concurrently running tasks.
    pub expected_l: f64,
    /// Time-averaged measured running tasks.
    pub measured_l: f64,
}

pub fn littles_law(events: &[Event], site: SiteId, t0: f64, t1: f64) -> LittleCheck {
    let lambda = state_timeline(events, site, JobState::StagedIn).rate(t0, t1);
    // Mean runtime over completed runs in the window.
    let mut started: BTreeMap<JobId, f64> = BTreeMap::new();
    let mut runtime = Summary::new();
    for e in events {
        if e.site_id != site {
            continue;
        }
        if e.to == JobState::Running {
            started.insert(e.job_id, e.ts);
        }
        if e.from == JobState::Running && e.to == JobState::RunDone {
            if let Some(s) = started.get(&e.job_id) {
                if *s >= t0 && e.ts <= t1 {
                    runtime.add(e.ts - s);
                }
            }
        }
    }
    let w = runtime.mean();
    let curve = running_tasks_curve(events, site, t1, 200);
    let in_window: Vec<f64> = curve
        .iter()
        .filter(|(t, _)| *t >= t0 && *t <= t1)
        .map(|(_, l)| *l as f64)
        .collect();
    let measured = if in_window.is_empty() {
        0.0
    } else {
        in_window.iter().sum::<f64>() / in_window.len() as f64
    };
    LittleCheck { lambda, mean_runtime: w, expected_l: lambda * w, measured_l: measured }
}

/// Snapshot of all jobs keyed by id (input to [`stage_durations`]).
pub fn job_table(svc: &crate::service::ServiceCore) -> BTreeMap<JobId, Job> {
    svc.store.jobs_snapshot().into_iter().map(|j| (j.id, j)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(job: u64, site: u64, ts: f64, from: JobState, to: JobState) -> Event {
        Event { seq: 0, job_id: JobId(job), site_id: SiteId(site), ts, from, to, data: String::new() }
    }

    fn lifecycle_events(job: u64, site: u64, t0: f64, run_s: f64) -> Vec<Event> {
        use JobState::*;
        vec![
            ev(job, site, t0, Created, Ready),
            ev(job, site, t0 + 10.0, Ready, StagedIn),
            ev(job, site, t0 + 10.0, StagedIn, Preprocessed),
            ev(job, site, t0 + 12.0, Preprocessed, Running),
            ev(job, site, t0 + 12.0 + run_s, Running, RunDone),
            ev(job, site, t0 + 12.0 + run_s, RunDone, Postprocessed),
            ev(job, site, t0 + 20.0 + run_s, Postprocessed, JobFinished),
        ]
    }

    fn job(id: u64, created: f64) -> Job {
        Job {
            id: JobId(id),
            site_id: SiteId(1),
            app_id: crate::service::models::AppId(1),
            state: JobState::JobFinished,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "xpcs".into(),
            parents: vec![],
            attempts: 1,
            max_attempts: 3,
            session: None,
            created_at: created,
        }
    }

    #[test]
    fn stage_durations_decompose_lifecycle() {
        let events = lifecycle_events(1, 1, 100.0, 50.0);
        let jobs = [(JobId(1), job(1, 99.0))].into_iter().collect();
        let durs = stage_durations(&events, &jobs);
        let d = &durs[&JobId(1)];
        assert_eq!(d.stage_in, Some(10.0));
        assert_eq!(d.run_delay, Some(2.0));
        assert_eq!(d.run, Some(50.0));
        assert_eq!(d.stage_out, Some(8.0));
        assert_eq!(d.time_to_solution, Some(100.0 + 20.0 + 50.0 - 99.0));
    }

    #[test]
    fn summaries_aggregate_across_jobs() {
        let mut events = Vec::new();
        let mut jobs = BTreeMap::new();
        for i in 0..10 {
            events.extend(lifecycle_events(i, 1, i as f64 * 30.0, 40.0 + i as f64));
            jobs.insert(JobId(i), job(i, i as f64 * 30.0));
        }
        let durs = stage_durations(&events, &jobs);
        let runs = summarize_stage(&durs, |d| d.run);
        assert_eq!(runs.count(), 10);
        assert!((runs.mean() - 44.5).abs() < 1e-9);
    }

    #[test]
    fn running_curve_tracks_concurrency() {
        let mut events = Vec::new();
        for i in 0..4 {
            events.extend(lifecycle_events(i, 1, 0.0, 100.0));
        }
        let curve = running_tasks_curve(&events, SiteId(1), 200.0, 200);
        let peak = curve.iter().map(|(_, l)| *l).max().unwrap();
        assert_eq!(peak, 4);
        // After completion all runs drained.
        assert_eq!(curve.last().unwrap().1, 0);
    }

    #[test]
    fn littles_law_consistency_on_synthetic_steady_state() {
        // 1 job staged in per 10 s, each running 50 s -> L = 5.
        let mut events = Vec::new();
        let mut jobs = BTreeMap::new();
        for i in 0..60 {
            events.extend(lifecycle_events(i, 1, i as f64 * 10.0, 50.0));
            jobs.insert(JobId(i), job(i, i as f64 * 10.0));
        }
        let chk = littles_law(&events, SiteId(1), 100.0, 500.0);
        assert!((chk.lambda - 0.1).abs() < 0.02, "lambda={}", chk.lambda);
        assert!((chk.mean_runtime - 50.0).abs() < 1e-6);
        assert!((chk.expected_l - chk.measured_l).abs() < 1.0,
            "L={} vs λW={}", chk.measured_l, chk.expected_l);
    }

    #[test]
    fn timelines_filter_by_site_and_state() {
        let mut events = lifecycle_events(1, 1, 0.0, 10.0);
        events.extend(lifecycle_events(2, 2, 0.0, 10.0));
        let tl = state_timeline(&events, SiteId(1), JobState::JobFinished);
        assert_eq!(tl.count(), 1);
        assert!(completion_rate(&events, SiteId(1), 0.0, 100.0) > 0.0);
    }
}
