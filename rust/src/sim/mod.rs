//! Discrete-event simulation engine.
//!
//! The paper's experiments span 19–80 wall-clock minutes across five
//! facilities; the engine replays them in milliseconds by advancing a
//! virtual clock between actor wake-ups. Actors (site agents, clients,
//! fault injectors) are polled state machines: `wake(now, world)` performs
//! one synchronization step and returns the absolute time of the next one.
//!
//! The same actor code runs against wall-clock time in the real-mode
//! examples (see [`Engine::run_realtime`]), which is what makes the
//! simulated results credible: nothing in the coordinator logic knows
//! which clock is driving it.

use crate::world::World;

/// A polled coordinator component (site agent, client, fault injector...).
pub trait Actor {
    /// Short name for traces.
    fn name(&self) -> String;

    /// Perform one step at `now`; return the absolute next wake time
    /// (`f64::INFINITY` to sleep forever).
    fn wake(&mut self, now: f64, world: &mut World) -> f64;
}

/// Cooperative scheduler over actors and a [`World`].
pub struct Engine {
    actors: Vec<(f64, Box<dyn Actor>)>,
    pub now: f64,
    /// Wake-call counter (exposed for the §Perf benches).
    pub wakes: u64,
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Engine {
        Engine { actors: Vec::new(), now: 0.0, wakes: 0 }
    }

    /// Register an actor; it gets its first wake at the current time.
    pub fn add(&mut self, actor: Box<dyn Actor>) {
        self.actors.push((self.now, actor));
    }

    /// Next scheduled wake time across all actors.
    pub fn next_wake(&self) -> f64 {
        self.actors.iter().map(|(t, _)| *t).fold(f64::INFINITY, f64::min)
    }

    /// Advance simulated time until `t_end`, waking actors in time order.
    /// Actors scheduled for the same instant run in registration order
    /// (deterministic).
    pub fn run_until(&mut self, world: &mut World, t_end: f64) {
        loop {
            let t = self.next_wake();
            if !t.is_finite() || t > t_end {
                self.now = t_end;
                world.now = t_end;
                return;
            }
            self.now = t;
            world.now = t;
            for i in 0..self.actors.len() {
                if self.actors[i].0 <= t {
                    self.wakes += 1;
                    let (_, actor) = &mut self.actors[i];
                    let next = actor.wake(t, world);
                    debug_assert!(next > t || !next.is_finite(), "actor {} did not advance", actor.name());
                    self.actors[i].0 = next.max(t + 1e-9);
                }
            }
        }
    }

    /// Drive the same actors against the wall clock (real-time mode). Used
    /// by the end-to-end examples where execution is real PJRT compute.
    /// `speedup` > 1 compresses idle waits (sleeps) without reordering.
    pub fn run_realtime(&mut self, world: &mut World, duration_s: f64, speedup: f64) {
        let start = std::time::Instant::now();
        loop {
            let elapsed = start.elapsed().as_secs_f64() * speedup;
            if elapsed >= duration_s {
                return;
            }
            let t = self.next_wake();
            if t.is_finite() && t > elapsed {
                let wait = ((t - elapsed) / speedup).min(0.05);
                std::thread::sleep(std::time::Duration::from_secs_f64(wait.max(0.001)));
                continue;
            }
            let now = start.elapsed().as_secs_f64() * speedup;
            self.now = now;
            world.now = now;
            for i in 0..self.actors.len() {
                if self.actors[i].0 <= now {
                    self.wakes += 1;
                    let next = {
                        let (_, actor) = &mut self.actors[i];
                        actor.wake(now, world)
                    };
                    self.actors[i].0 = next.max(now + 1e-9);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::world::World;

    struct Ticker {
        period: f64,
        fired: std::rc::Rc<std::cell::RefCell<Vec<f64>>>,
    }

    impl Actor for Ticker {
        fn name(&self) -> String {
            "ticker".into()
        }
        fn wake(&mut self, now: f64, _world: &mut World) -> f64 {
            self.fired.borrow_mut().push(now);
            now + self.period
        }
    }

    #[test]
    fn actors_fire_in_time_order() {
        let mut eng = Engine::new();
        let mut world = World::for_tests();
        let a = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let b = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        eng.add(Box::new(Ticker { period: 3.0, fired: a.clone() }));
        eng.add(Box::new(Ticker { period: 5.0, fired: b.clone() }));
        eng.run_until(&mut world, 12.0);
        assert_eq!(*a.borrow(), vec![0.0, 3.0, 6.0, 9.0, 12.0]);
        assert_eq!(*b.borrow(), vec![0.0, 5.0, 10.0]);
        assert_eq!(eng.now, 12.0);
    }

    #[test]
    fn infinite_sleep_ends_run() {
        struct Once;
        impl Actor for Once {
            fn name(&self) -> String {
                "once".into()
            }
            fn wake(&mut self, _now: f64, _world: &mut World) -> f64 {
                f64::INFINITY
            }
        }
        let mut eng = Engine::new();
        let mut world = World::for_tests();
        eng.add(Box::new(Once));
        eng.run_until(&mut world, 1e9);
        assert_eq!(eng.wakes, 1);
        assert_eq!(eng.now, 1e9);
    }

    #[test]
    fn same_instant_runs_in_registration_order() {
        struct Tag {
            id: u32,
            log: std::rc::Rc<std::cell::RefCell<Vec<u32>>>,
        }
        impl Actor for Tag {
            fn name(&self) -> String {
                format!("tag{}", self.id)
            }
            fn wake(&mut self, _now: f64, _world: &mut World) -> f64 {
                self.log.borrow_mut().push(self.id);
                f64::INFINITY
            }
        }
        let log = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let mut eng = Engine::new();
        let mut world = World::for_tests();
        for id in 0..4 {
            eng.add(Box::new(Tag { id, log: log.clone() }));
        }
        eng.run_until(&mut world, 1.0);
        assert_eq!(*log.borrow(), vec![0, 1, 2, 3]);
    }
}
