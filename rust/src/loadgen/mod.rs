//! `balsam loadgen` — open-loop load harness with SLO stop rules.
//!
//! The benches measure closed-loop req/s (each worker fires its next
//! request when the previous one answers), which systematically hides
//! queueing delay: a slow server slows the *offered* load down, so the
//! measured latency stays flattering ("coordinated omission"). This
//! module is the paper-grade instrument instead: an **open-loop** driver
//! fires requests on a fixed-rate schedule regardless of completion
//! ([`schedule::OpenLoopPlan`]), sweeps a geometric ladder of target rps
//! across combos of payload mix × sites × launcher sessions
//! ([`mix::Mix`]), and reads the resulting latency distributions from the
//! service's own `/metrics` endpoint
//! (`balsam_api_request_seconds{endpoint=...}`,
//! `balsam_wal_fsync_seconds`) via the [`prom`] scraper — the same
//! histograms production alerting consumes.
//!
//! Each ladder rung records offered vs achieved rps, failure rate, and
//! server-side p50/p95/p99; a **stop-and-declare** rule — failure rate or
//! median latency over threshold, after the IC scalability harness's
//! `STOP_FAILURE_RATE` / `ALLOWABLE_LATENCY` — halts the ladder and
//! declares the max sustainable rps (the best rung that passed). Results
//! land under the `loadgen` axis of `BENCH_service.json` so
//! `.github/scripts/bench_trend.py` gates capacity regressions cross-run,
//! and `balsam loadgen` prints one `DECLARE` line per combo for humans.

pub mod mix;
pub mod prom;
pub mod schedule;

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::service::{
    http_gw, wire_from_env, ApiConn, ApiRequest, FsyncPolicy, PersistMode, ServiceCore, SessionId,
    SiteId, Wire,
};
use crate::util::httpd;
use crate::util::json::Json;
use crate::util::rng::Pcg;
use mix::{Mix, MixDriver};
use prom::{Hist, Scrape};
use schedule::OpenLoopPlan;

/// App name the harness registers at every site it creates.
const LOADGEN_APP: &str = "loadgen-app";

/// What to sweep and when to stop.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Attach to a running service: `(addr, bearer token)`. `None`
    /// self-hosts a fresh in-process service per combo (hermetic: every
    /// combo starts from an empty store).
    pub target: Option<(String, String)>,
    /// Payload mixes to sweep.
    pub mixes: Vec<Mix>,
    /// Site counts to sweep.
    pub sites_list: Vec<usize>,
    /// Sender (launcher-session) counts to sweep. Each sender is one
    /// thread with one keep-alive connection and its own session.
    pub sessions_list: Vec<usize>,
    /// First ladder rung, requests/second.
    pub rps_start: f64,
    /// Geometric ladder step factor (> 1).
    pub rps_factor: f64,
    /// Max ladder rungs per combo.
    pub rps_steps: usize,
    /// Seconds each rung offers load for.
    pub step_secs: f64,
    /// Stop rule: halt the ladder when `(errors + skipped) / planned`
    /// exceeds this (the IC harness's `STOP_FAILURE_RATE`).
    pub stop_failure_rate: f64,
    /// Stop rule: halt when server-side median latency exceeds this many
    /// milliseconds (the IC harness's median-latency stop).
    pub stop_median_ms: f64,
    /// A sender this far behind schedule *skips* overdue ticks (counted
    /// as failures) instead of firing a burst of stale requests.
    pub max_lag_s: f64,
    /// Gateway worker threads when self-hosting.
    pub workers: usize,
    /// Self-host with WAL persistence under this dir (per-combo subdirs)
    /// instead of ephemeral — exercises `balsam_wal_fsync_seconds`.
    pub wal: Option<(PathBuf, FsyncPolicy)>,
    /// Wire codec every sender (and the setup connection) speaks —
    /// `balsam loadgen --wire binary` sweeps the same ladder over binary
    /// frames. Defaults from the `BALSAM_WIRE` env var (JSON when unset).
    pub wire: Wire,
    /// PRNG seed for the probabilistic mix choices.
    pub seed: u64,
    /// Print per-rung and DECLARE lines to stderr.
    pub log: bool,
}

impl Default for LoadgenConfig {
    /// The full capacity sweep: 12 combos, ladder 100 → ~51k rps.
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            target: None,
            mixes: Mix::all().to_vec(),
            sites_list: vec![1, 4],
            sessions_list: vec![2, 8],
            rps_start: 100.0,
            rps_factor: 2.0,
            rps_steps: 10,
            step_secs: 3.0,
            stop_failure_rate: 0.4,
            stop_median_ms: 300.0,
            max_lag_s: 0.25,
            workers: httpd::default_workers(),
            wal: None,
            wire: wire_from_env(),
            seed: 0x10adCE4,
            log: true,
        }
    }
}

impl LoadgenConfig {
    /// CI smoke sweep: 3 combos, short rungs, a ladder steep enough
    /// (×4 up to ~13M rps) that the stop rule is guaranteed to fire on
    /// any real machine — the declare path runs on every PR.
    pub fn quick() -> LoadgenConfig {
        LoadgenConfig {
            sites_list: vec![1],
            sessions_list: vec![2],
            rps_start: 200.0,
            rps_factor: 4.0,
            rps_steps: 9,
            step_secs: 0.5,
            ..LoadgenConfig::default()
        }
    }
}

/// One ladder rung's measurements.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Target rate this rung offered.
    pub offered_rps: f64,
    /// Ticks the open-loop schedule defined.
    pub planned: u64,
    /// Requests actually sent (`ok + errors + rejected`).
    pub issued: u64,
    /// Requests answered successfully.
    pub ok: u64,
    /// Requests answered with an error (transport or non-backpressure
    /// 4xx/5xx).
    pub errors: u64,
    /// Requests the service *refused with backpressure* (a framed
    /// 429/503 + `Retry-After`). Deliberate admission control, not a
    /// malfunction: kept out of `errors` and out of the failure-rate
    /// stop rule, so a shedding-but-healthy gateway reads as reduced
    /// capacity (lower `achieved_rps`), never as a broken one.
    pub rejected: u64,
    /// Overdue ticks dropped by senders that fell behind schedule.
    pub skipped: u64,
    /// Wall time the rung took.
    pub elapsed_s: f64,
    /// `ok / elapsed_s`.
    pub achieved_rps: f64,
    /// `(errors + skipped) / planned` — skipped ticks are load the
    /// system failed to absorb, not a reprieve.
    pub failure_rate: f64,
    /// Server-side latency quantiles over the mix's SLO endpoints
    /// (scrape delta), milliseconds. `None` when no observation landed.
    pub p50_ms: Option<f64>,
    /// 95th percentile, ms.
    pub p95_ms: Option<f64>,
    /// 99th percentile, ms.
    pub p99_ms: Option<f64>,
    /// WAL fsync p95 over the rung, ms (`None` when not persisting).
    pub fsync_p95_ms: Option<f64>,
}

/// One (mix, sites, sessions) combo: its ladder and verdict.
#[derive(Debug, Clone)]
pub struct ComboReport {
    /// Payload mix offered.
    pub mix: Mix,
    /// Sites traffic was spread over.
    pub sites: usize,
    /// Concurrent senders.
    pub sessions: usize,
    /// Ladder rungs actually run (stops at the first rule trip).
    pub steps: Vec<StepReport>,
    /// Best achieved rps among rungs that passed the stop rules; 0 when
    /// the very first rung failed.
    pub max_sustainable_rps: f64,
    /// `"failure-rate"`, `"median-latency"`, or `"ladder-exhausted"`
    /// (every rung passed — the declared max is a lower bound).
    pub declared_by: &'static str,
    /// The offered rate of the rung that tripped the rule, if any.
    pub stopped_at_rps: Option<f64>,
}

/// A full sweep.
#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    /// One entry per combo, sweep order.
    pub combos: Vec<ComboReport>,
}

/// Which stop rule (if any) a rung trips. The declare decision is pure
/// so the SLO math is unit-testable without a server.
pub fn stop_reason(cfg: &LoadgenConfig, step: &StepReport) -> Option<&'static str> {
    if step.failure_rate > cfg.stop_failure_rate {
        Some("failure-rate")
    } else if step.p50_ms.is_some_and(|p| p > cfg.stop_median_ms) {
        Some("median-latency")
    } else {
        None
    }
}

/// Run the configured sweep. Combos run sequentially (they share the
/// machine — parallel combos would measure each other).
pub fn run(cfg: &LoadgenConfig) -> crate::Result<LoadgenReport> {
    let mut combos = Vec::new();
    for &sites in &cfg.sites_list {
        for &sessions in &cfg.sessions_list {
            for &m in &cfg.mixes {
                combos.push(run_combo(cfg, m, sites, sessions, combos.len() as u64)?);
            }
        }
    }
    Ok(LoadgenReport { combos })
}

/// The service a combo drives: either a fresh self-hosted one (with its
/// gateway handle, stopped after the combo) or an external attach.
struct Target {
    addr: String,
    token: String,
    server: Option<httpd::Server>,
}

fn open_target(cfg: &LoadgenConfig, combo_idx: u64) -> crate::Result<Target> {
    if let Some((addr, token)) = &cfg.target {
        return Ok(Target { addr: addr.clone(), token: token.clone(), server: None });
    }
    let secret = format!("loadgen-secret-{}-{combo_idx}", cfg.seed);
    let mode = match &cfg.wal {
        None => PersistMode::Ephemeral,
        Some((dir, fsync)) => {
            let mut m = PersistMode::wal(dir.join(format!("combo-{combo_idx}")));
            if let PersistMode::Wal { fsync: f, .. } = &mut m {
                *f = *fsync;
            }
            m
        }
    };
    let svc = Arc::new(ServiceCore::with_persist(secret.as_bytes(), mode)?);
    let token = svc.admin_token();
    let server = http_gw::serve_with(
        svc,
        "127.0.0.1:0",
        cfg.workers,
        httpd::HttpConfig::default(),
    )?;
    Ok(Target { addr: server.addr.clone(), token, server: Some(server) })
}

fn run_combo(
    cfg: &LoadgenConfig,
    m: Mix,
    sites: usize,
    sessions: usize,
    combo_idx: u64,
) -> crate::Result<ComboReport> {
    let target = open_target(cfg, combo_idx)?;
    let sites = sites.max(1);
    let sessions = sessions.max(1);

    // Topology setup (not measured: it precedes the baseline scrape).
    let mut admin =
        http_gw::HttpConn::with_wire(target.addr.clone(), httpd::HttpConfig::default(), cfg.wire);
    let mut site_ids: Vec<SiteId> = Vec::with_capacity(sites);
    for i in 0..sites {
        let site = admin
            .api(
                &target.token,
                ApiRequest::CreateSite {
                    name: format!("loadgen-{combo_idx}-{i}"),
                    hostname: "loadgen".into(),
                    path: format!("/loadgen/{combo_idx}/{i}"),
                },
            )
            .map_err(|e| crate::util::error::err_msg(format!("loadgen setup: CreateSite: {e}")))?
            .site_id();
        admin
            .api(
                &target.token,
                ApiRequest::RegisterApp {
                    site,
                    name: LOADGEN_APP.into(),
                    command_template: "echo {n}".into(),
                    parameters: vec!["n".into()],
                },
            )
            .map_err(|e| crate::util::error::err_msg(format!("loadgen setup: RegisterApp: {e}")))?;
        site_ids.push(site);
    }
    let mut sender_sessions: Vec<(SiteId, SessionId)> = Vec::with_capacity(sessions);
    for s in 0..sessions {
        let site = site_ids[s % site_ids.len()];
        let sid = admin
            .api(&target.token, ApiRequest::CreateSession { site, batch_job: None })
            .map_err(|e| crate::util::error::err_msg(format!("loadgen setup: CreateSession: {e}")))?
            .session_id();
        sender_sessions.push((site, sid));
    }

    let mut steps: Vec<StepReport> = Vec::new();
    let mut max_sustainable = 0.0f64;
    let mut declared_by: &'static str = "ladder-exhausted";
    let mut stopped_at: Option<f64> = None;
    let mut offered = cfg.rps_start;
    for rung in 0..cfg.rps_steps {
        let plan = OpenLoopPlan { rps: offered, senders: sessions, duration_s: cfg.step_secs };
        let step = run_step(cfg, m, &target, &sender_sessions, plan, combo_idx, rung as u64)?;
        if cfg.log {
            eprintln!(
                "loadgen mix={} sites={} sessions={}: offered {:.0} rps -> achieved {:.0} rps, \
                 failures {:.1}% ({} err, {} skipped of {}), p50 {} p95 {} p99 {} ms",
                m.label(),
                sites,
                sessions,
                step.offered_rps,
                step.achieved_rps,
                step.failure_rate * 100.0,
                step.errors,
                step.skipped,
                step.planned,
                fmt_ms(step.p50_ms),
                fmt_ms(step.p95_ms),
                fmt_ms(step.p99_ms),
            );
        }
        let reason = stop_reason(cfg, &step);
        let failure_rate = step.failure_rate;
        let p50 = step.p50_ms;
        steps.push(step);
        if let Some(r) = reason {
            declared_by = r;
            stopped_at = Some(offered);
            if cfg.log {
                let detail = match r {
                    "failure-rate" => format!(
                        "failure rate {:.1}% > {:.1}%",
                        failure_rate * 100.0,
                        cfg.stop_failure_rate * 100.0
                    ),
                    _ => format!(
                        "median latency {} ms > {:.0} ms",
                        fmt_ms(p50),
                        cfg.stop_median_ms
                    ),
                };
                eprintln!(
                    "DECLARE loadgen mix={} sites={} sessions={}: max sustainable {:.0} rps \
                     (stop rule: {detail} at offered {:.0} rps)",
                    m.label(),
                    sites,
                    sessions,
                    max_sustainable,
                    offered,
                );
            }
            break;
        }
        max_sustainable = max_sustainable.max(steps.last().map_or(0.0, |s| s.achieved_rps));
        offered *= cfg.rps_factor;
    }
    if declared_by == "ladder-exhausted" && cfg.log {
        eprintln!(
            "DECLARE loadgen mix={} sites={} sessions={}: max sustainable {:.0} rps \
             (ladder exhausted at offered {:.0} rps — a lower bound)",
            m.label(),
            sites,
            sessions,
            max_sustainable,
            offered / cfg.rps_factor,
        );
    }

    if let Some(server) = target.server {
        server.stop();
    }
    Ok(ComboReport {
        mix: m,
        sites,
        sessions,
        steps,
        max_sustainable_rps: max_sustainable,
        declared_by,
        stopped_at_rps: stopped_at,
    })
}

/// Per-sender tallies for one rung.
#[derive(Debug, Default, Clone, Copy)]
struct SenderStats {
    ok: u64,
    errors: u64,
    rejected: u64,
    skipped: u64,
}

fn run_step(
    cfg: &LoadgenConfig,
    m: Mix,
    target: &Target,
    sender_sessions: &[(SiteId, SessionId)],
    plan: OpenLoopPlan,
    combo_idx: u64,
    rung: u64,
) -> crate::Result<StepReport> {
    let before = scrape(&target.addr)?;
    let start = Instant::now();
    let stats: Vec<SenderStats> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..plan.senders)
            .map(|s| {
                let (site, session) = sender_sessions[s];
                let mut driver = MixDriver::new(m, site, session, LOADGEN_APP);
                let mut g = Pcg::new(cfg.seed ^ rung.wrapping_mul(0x9e37), combo_idx * 64 + s as u64);
                let mut conn = http_gw::HttpConn::with_wire(
                    target.addr.clone(),
                    httpd::HttpConfig::default(),
                    cfg.wire,
                );
                let token = target.token.clone();
                let max_lag = Duration::from_secs_f64(cfg.max_lag_s);
                scope.spawn(move || {
                    let mut st = SenderStats::default();
                    for tick in plan.sender_ticks(s) {
                        let deadline = plan.deadline(tick);
                        let now = start.elapsed();
                        if now < deadline {
                            std::thread::sleep(deadline - now);
                        } else if now - deadline > max_lag {
                            // Open-loop discipline: never fire a burst of
                            // stale requests to catch up — drop the tick
                            // and let it count against the failure rate.
                            st.skipped += 1;
                            continue;
                        }
                        let req = driver.next_request(&mut g);
                        match conn.api(&token, req.clone()) {
                            Ok(resp) => {
                                st.ok += 1;
                                driver.observe(&req, &resp);
                            }
                            // A framed 429/503 is the gateway doing its
                            // job, not a failure — and it never consumed
                            // the request, so the driver's lifecycle
                            // state is still valid (no on_error reset).
                            Err(crate::service::ApiError::Backpressure { .. }) => {
                                st.rejected += 1;
                            }
                            Err(_) => {
                                st.errors += 1;
                                driver.on_error();
                            }
                        }
                    }
                    st
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or_default()).collect()
    });
    let elapsed_s = start.elapsed().as_secs_f64().max(1e-9);
    let after = scrape(&target.addr)?;

    let planned = plan.planned_ticks();
    let (ok, errors, rejected, skipped) = stats.iter().fold((0, 0, 0, 0), |(o, e, r, k), s| {
        (o + s.ok, e + s.errors, r + s.rejected, k + s.skipped)
    });
    let (p50_ms, p95_ms, p99_ms) = latency_quantiles_ms(m, &before, &after);
    let fsync_p95_ms = fsync_p95_ms(&before, &after);
    Ok(StepReport {
        offered_rps: plan.rps,
        planned,
        issued: ok + errors + rejected,
        ok,
        errors,
        rejected,
        skipped,
        elapsed_s,
        achieved_rps: ok as f64 / elapsed_s,
        failure_rate: if planned == 0 { 0.0 } else { (errors + skipped) as f64 / planned as f64 },
        p50_ms,
        p95_ms,
        p99_ms,
        fsync_p95_ms,
    })
}

/// Fairness probe: does per-principal rate limiting actually protect
/// polite tenants from a greedy one? Two phases on identical topology —
/// a control with only the polite tenants offering load, then the same
/// sweep with the greedy tenants hammering far past their quota — and
/// the verdict is the polite class's client-observed p99 ratio between
/// them. CI gates on that ratio (see `fairness_summary.py`).
#[derive(Debug, Clone)]
pub struct FairnessConfig {
    /// Polite tenants: each offers `polite_rps` (below its per-principal
    /// quota) and honors `Retry-After` if it is ever throttled.
    pub polite: usize,
    /// Greedy tenants: each offers `greedy_rps` (far beyond the quota)
    /// and ignores every `Retry-After` hint.
    pub greedy: usize,
    /// Offered rate per polite tenant, rps.
    pub polite_rps: f64,
    /// Offered rate per greedy tenant, rps.
    pub greedy_rps: f64,
    /// Seconds each phase offers load for.
    pub duration_s: f64,
    /// Per-principal `(rps, burst)` the gateway enforces.
    pub rate_limit: (u64, u64),
    /// Gateway worker threads.
    pub workers: usize,
    /// PRNG seed (kept for config parity; the probe is deterministic).
    pub seed: u64,
    /// Print phase summaries to stderr.
    pub log: bool,
}

impl Default for FairnessConfig {
    fn default() -> FairnessConfig {
        FairnessConfig {
            polite: 3,
            greedy: 1,
            polite_rps: 20.0,
            greedy_rps: 400.0,
            duration_s: 3.0,
            rate_limit: (50, 100),
            workers: httpd::default_workers(),
            seed: 0xFA13,
            log: true,
        }
    }
}

impl FairnessConfig {
    /// CI smoke shape: short phases, same contention ratio.
    pub fn quick() -> FairnessConfig {
        FairnessConfig { duration_s: 1.0, ..FairnessConfig::default() }
    }
}

/// Aggregate client-side tallies for one tenant class in one phase.
#[derive(Debug, Clone, Default)]
pub struct TenantClassStats {
    /// Requests actually sent (`ok + rejected + errors`).
    pub issued: u64,
    /// Requests answered successfully.
    pub ok: u64,
    /// Requests refused with backpressure (framed 429/503).
    pub rejected: u64,
    /// Transport or non-backpressure 4xx/5xx answers.
    pub errors: u64,
    /// Ticks a polite sender dropped because a `Retry-After` window was
    /// open (greedy senders never defer).
    pub deferred: u64,
    /// Client-observed latency quantiles over successful requests, ms.
    pub p50_ms: Option<f64>,
    /// 99th percentile, ms.
    pub p99_ms: Option<f64>,
}

/// The fairness probe's verdict (the whole of `BENCH_fairness.json`).
#[derive(Debug, Clone)]
pub struct FairnessReport {
    /// Polite tenant count.
    pub polite_senders: usize,
    /// Greedy tenant count.
    pub greedy_senders: usize,
    /// Per-principal `(rps, burst)` that was enforced.
    pub rate_limit: (u64, u64),
    /// Polite class with NO greedy tenants running (control phase).
    pub baseline: TenantClassStats,
    /// Polite class with the greedy tenants running.
    pub polite: TenantClassStats,
    /// The greedy class itself (expected mostly rejected).
    pub greedy: TenantClassStats,
    /// `polite.p99_ms / baseline.p99_ms` — the number CI gates on.
    /// `None` when either phase produced no latency samples.
    pub degradation_p99: Option<f64>,
}

/// Run the two-phase fairness probe (control, then contended).
pub fn run_fairness(cfg: &FairnessConfig) -> crate::Result<FairnessReport> {
    let (baseline, _) = fairness_phase(cfg, false)?;
    let (polite, greedy) = fairness_phase(cfg, true)?;
    let degradation_p99 = match (baseline.p99_ms, polite.p99_ms) {
        (Some(b), Some(c)) if b > 0.0 => Some(c / b),
        _ => None,
    };
    if cfg.log {
        eprintln!(
            "fairness: baseline polite p99 {} ms; contended polite p99 {} ms \
             (x{} vs baseline); greedy {}/{} rejected",
            fmt_ms(baseline.p99_ms),
            fmt_ms(polite.p99_ms),
            degradation_p99.map_or_else(|| "-".into(), |d| format!("{d:.2}")),
            greedy.rejected,
            greedy.issued,
        );
    }
    Ok(FairnessReport {
        polite_senders: cfg.polite,
        greedy_senders: cfg.greedy,
        rate_limit: cfg.rate_limit,
        baseline,
        polite,
        greedy,
        degradation_p99,
    })
}

/// One phase: self-host a rate-limited gateway, one principal per
/// tenant, open-loop senders per tenant, client-observed latencies per
/// class. Greedy tenants exist in both phases (identical topology and
/// ids); they only *send* when `greedy_on`.
fn fairness_phase(
    cfg: &FairnessConfig,
    greedy_on: bool,
) -> crate::Result<(TenantClassStats, TenantClassStats)> {
    let secret = format!("fairness-{}-{greedy_on}", cfg.seed);
    let svc = Arc::new(ServiceCore::new(secret.as_bytes()));
    let admin_tok = svc.admin_token();
    let gw = http_gw::GatewayConfig {
        rate_limit: Some(cfg.rate_limit),
        admin_exempt: true,
        ..Default::default()
    };
    let server = http_gw::serve_with_limits(
        svc.clone(),
        "127.0.0.1:0",
        cfg.workers,
        httpd::HttpConfig::default(),
        gw,
    )?;
    let mut admin = http_gw::HttpConn::new(server.addr.clone());
    // (is_greedy, bearer token, owned site) per tenant principal.
    let mut tenants: Vec<(bool, String, SiteId)> = Vec::new();
    for i in 0..cfg.polite + cfg.greedy {
        let is_greedy = i >= cfg.polite;
        let user = admin
            .api(&admin_tok, ApiRequest::CreateUser { name: format!("tenant-{i}") })
            .map_err(|e| crate::util::error::err_msg(format!("fairness setup: CreateUser: {e}")))?
            .user_id();
        let token = svc.token_for(user);
        let mut conn = http_gw::HttpConn::new(server.addr.clone());
        let site = conn
            .api(
                &token,
                ApiRequest::CreateSite {
                    name: format!("fair-{i}"),
                    hostname: "fair".into(),
                    path: format!("/fair/{i}"),
                },
            )
            .map_err(|e| crate::util::error::err_msg(format!("fairness setup: CreateSite: {e}")))?
            .site_id();
        tenants.push((is_greedy, token, site));
    }

    let start = Instant::now();
    let results: Vec<(bool, TenantClassStats, Vec<f64>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = tenants
            .iter()
            .map(|(is_greedy, token, site)| {
                let is_greedy = *is_greedy;
                let site = *site;
                let token = token.clone();
                let addr = server.addr.clone();
                let rps = if is_greedy { cfg.greedy_rps } else { cfg.polite_rps };
                let active = !is_greedy || greedy_on;
                scope.spawn(move || {
                    let mut st = TenantClassStats::default();
                    let mut lat: Vec<f64> = Vec::new();
                    if !active {
                        return (is_greedy, st, lat);
                    }
                    let mut conn = http_gw::HttpConn::new(addr);
                    let plan = OpenLoopPlan { rps, senders: 1, duration_s: cfg.duration_s };
                    let mut pause_until: Option<Instant> = None;
                    for tick in plan.sender_ticks(0) {
                        let deadline = plan.deadline(tick);
                        let now = start.elapsed();
                        if now < deadline {
                            std::thread::sleep(deadline - now);
                        }
                        if let Some(p) = pause_until {
                            if Instant::now() < p {
                                st.deferred += 1;
                                continue;
                            }
                            pause_until = None;
                        }
                        let t0 = Instant::now();
                        st.issued += 1;
                        match conn.api(&token, ApiRequest::CountByState { site }) {
                            Ok(_) => {
                                st.ok += 1;
                                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                            }
                            Err(crate::service::ApiError::Backpressure { retry_after_s }) => {
                                st.rejected += 1;
                                if !is_greedy {
                                    pause_until =
                                        Some(Instant::now() + Duration::from_secs(retry_after_s));
                                }
                            }
                            Err(_) => st.errors += 1,
                        }
                    }
                    (is_greedy, st, lat)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap_or_default()).collect()
    });
    server.stop();

    let mut polite = TenantClassStats::default();
    let mut greedy = TenantClassStats::default();
    let mut polite_lat: Vec<f64> = Vec::new();
    let mut greedy_lat: Vec<f64> = Vec::new();
    for (is_greedy, st, lat) in results {
        let (acc, acc_lat) =
            if is_greedy { (&mut greedy, &mut greedy_lat) } else { (&mut polite, &mut polite_lat) };
        acc.issued += st.issued;
        acc.ok += st.ok;
        acc.rejected += st.rejected;
        acc.errors += st.errors;
        acc.deferred += st.deferred;
        acc_lat.extend(lat);
    }
    polite.p50_ms = quantile_ms(&mut polite_lat, 0.50);
    polite.p99_ms = quantile_ms(&mut polite_lat, 0.99);
    greedy.p50_ms = quantile_ms(&mut greedy_lat, 0.50);
    greedy.p99_ms = quantile_ms(&mut greedy_lat, 0.99);
    Ok((polite, greedy))
}

/// Nearest-rank quantile over client-observed latencies, ms.
fn quantile_ms(samples: &mut [f64], q: f64) -> Option<f64> {
    if samples.is_empty() {
        return None;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() - 1) as f64 * q).round() as usize;
    Some(samples[idx])
}

impl TenantClassStats {
    /// JSON record for one class in one phase.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("issued", Json::num(self.issued as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("deferred", Json::num(self.deferred as f64)),
            ("p50_ms", opt_num(self.p50_ms)),
            ("p99_ms", opt_num(self.p99_ms)),
        ])
    }
}

impl FairnessReport {
    /// The whole of `BENCH_fairness.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("polite_senders", Json::num(self.polite_senders as f64)),
            ("greedy_senders", Json::num(self.greedy_senders as f64)),
            ("rate_limit_rps", Json::num(self.rate_limit.0 as f64)),
            ("rate_limit_burst", Json::num(self.rate_limit.1 as f64)),
            ("baseline", self.baseline.to_json()),
            ("polite", self.polite.to_json()),
            ("greedy", self.greedy.to_json()),
            ("degradation_p99", opt_num(self.degradation_p99)),
        ])
    }
}

/// One `/metrics` scrape, parsed.
fn scrape(addr: &str) -> crate::Result<Scrape> {
    let (status, body) = httpd::request(addr, "GET", "/metrics", &[], &[])?;
    crate::ensure!(status == 200, "GET /metrics returned {status}");
    let text = String::from_utf8(body)
        .map_err(|e| crate::util::error::err_msg(format!("/metrics not UTF-8: {e}")))?;
    Scrape::parse(&text).map_err(crate::util::error::err_msg)
}

/// Merge the scrape-delta latency histograms of the mix's SLO endpoints
/// and report (p50, p95, p99) in milliseconds.
fn latency_quantiles_ms(
    m: Mix,
    before: &Scrape,
    after: &Scrape,
) -> (Option<f64>, Option<f64>, Option<f64>) {
    let mut acc = Hist::default();
    for ep in m.latency_endpoints() {
        let Some(a) = after.histogram("balsam_api_request_seconds", &[("endpoint", ep)]) else {
            continue;
        };
        let d = match before.histogram("balsam_api_request_seconds", &[("endpoint", ep)]) {
            // Counter reset (shouldn't happen within a run) falls back to
            // the absolute histogram rather than reporting nothing.
            Some(b) => a.delta(&b).unwrap_or(a),
            None => a,
        };
        acc.merge(&d);
    }
    let q = |p: f64| acc.quantile(p).map(|s| s * 1000.0);
    (q(0.50), q(0.95), q(0.99))
}

/// WAL fsync p95 over the rung, ms; `None` when nothing synced.
fn fsync_p95_ms(before: &Scrape, after: &Scrape) -> Option<f64> {
    let a = after.histogram("balsam_wal_fsync_seconds", &[])?;
    let d = match before.histogram("balsam_wal_fsync_seconds", &[]) {
        Some(b) => a.delta(&b).unwrap_or(a),
        None => a,
    };
    if d.is_empty() {
        return None;
    }
    d.quantile(0.95).map(|s| s * 1000.0)
}

fn fmt_ms(v: Option<f64>) -> String {
    match v {
        Some(v) => format!("{v:.2}"),
        None => "-".to_string(),
    }
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(v) => Json::num(v),
        None => Json::Null,
    }
}

impl StepReport {
    /// JSON record for one rung (the `steps` array of the report).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_rps", Json::num(self.offered_rps)),
            ("planned", Json::num(self.planned as f64)),
            ("issued", Json::num(self.issued as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("skipped", Json::num(self.skipped as f64)),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("achieved_rps", Json::num(self.achieved_rps)),
            ("failure_rate", Json::num(self.failure_rate)),
            ("p50_ms", opt_num(self.p50_ms)),
            ("p95_ms", opt_num(self.p95_ms)),
            ("p99_ms", opt_num(self.p99_ms)),
            ("fsync_p95_ms", opt_num(self.fsync_p95_ms)),
        ])
    }
}

impl ComboReport {
    /// JSON record for one combo (an entry of `loadgen.combos`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mix", Json::str(self.mix.label())),
            ("sites", Json::num(self.sites as f64)),
            ("sessions", Json::num(self.sessions as f64)),
            ("max_sustainable_rps", Json::num(self.max_sustainable_rps)),
            ("declared_by", Json::str(self.declared_by)),
            ("stopped_at_rps", opt_num(self.stopped_at_rps)),
            ("steps", Json::Arr(self.steps.iter().map(StepReport::to_json).collect())),
        ])
    }
}

impl LoadgenReport {
    /// The `loadgen` axis recorded in `BENCH_service.json` (and the whole
    /// of `BENCH_loadgen.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "combos",
            Json::Arr(self.combos.iter().map(ComboReport::to_json).collect()),
        )])
    }

    /// One human line per combo (the CI step-summary table rows).
    pub fn summary_rows(&self) -> Vec<String> {
        self.combos
            .iter()
            .map(|c| {
                format!(
                    "| {} | {} | {} | {:.0} | {} | {} |",
                    c.mix.label(),
                    c.sites,
                    c.sessions,
                    c.max_sustainable_rps,
                    c.declared_by,
                    c.stopped_at_rps.map_or_else(|| "-".into(), |r| format!("{r:.0}")),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(failure_rate: f64, p50_ms: Option<f64>) -> StepReport {
        StepReport {
            offered_rps: 100.0,
            planned: 100,
            issued: 100,
            ok: 100,
            errors: 0,
            rejected: 0,
            skipped: 0,
            elapsed_s: 1.0,
            achieved_rps: 100.0,
            failure_rate,
            p50_ms,
            p95_ms: p50_ms,
            p99_ms: p50_ms,
            fsync_p95_ms: None,
        }
    }

    #[test]
    fn stop_rules_match_the_exemplar_semantics() {
        let cfg = LoadgenConfig::default();
        // Healthy rung: under both thresholds.
        assert_eq!(stop_reason(&cfg, &step(0.0, Some(5.0))), None);
        // Failure rate dominates (checked first, like STOP_FAILURE_RATE).
        assert_eq!(stop_reason(&cfg, &step(0.5, Some(5.0))), Some("failure-rate"));
        assert_eq!(stop_reason(&cfg, &step(0.5, Some(9999.0))), Some("failure-rate"));
        // Median latency trips on its own.
        assert_eq!(stop_reason(&cfg, &step(0.0, Some(301.0))), Some("median-latency"));
        // No latency observed (e.g. every request errored before the SLO
        // endpoints): only the failure rate can trip.
        assert_eq!(stop_reason(&cfg, &step(0.0, None)), None);
        // Exactly at threshold passes ("over threshold" stops).
        assert_eq!(stop_reason(&cfg, &step(0.4, Some(300.0))), None);
    }

    #[test]
    fn report_json_shape_is_stable() {
        let report = LoadgenReport {
            combos: vec![ComboReport {
                mix: Mix::SyncHeavy,
                sites: 2,
                sessions: 4,
                steps: vec![step(0.1, Some(2.5))],
                max_sustainable_rps: 99.5,
                declared_by: "failure-rate",
                stopped_at_rps: Some(200.0),
            }],
        };
        let j = report.to_json();
        let combo = j.get("combos").and_then(|c| c.idx(0)).unwrap();
        assert_eq!(combo.get("mix").and_then(Json::as_str), Some("sync"));
        assert_eq!(combo.get("sites").and_then(Json::as_f64), Some(2.0));
        assert_eq!(combo.get("sessions").and_then(Json::as_f64), Some(4.0));
        assert_eq!(combo.get("max_sustainable_rps").and_then(Json::as_f64), Some(99.5));
        assert_eq!(combo.get("declared_by").and_then(Json::as_str), Some("failure-rate"));
        let s0 = combo.get("steps").and_then(|s| s.idx(0)).unwrap();
        assert_eq!(s0.get("p50_ms").and_then(Json::as_f64), Some(2.5));
        assert_eq!(s0.get("rejected").and_then(Json::as_f64), Some(0.0));
        assert!(matches!(s0.get("fsync_p95_ms"), Some(Json::Null)));
        // The whole thing survives a serialize/parse round trip.
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed.to_string(), j.to_string());
        // Summary rows: one per combo, pipe-table shaped.
        let rows = report.summary_rows();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].contains("| sync | 2 | 4 | 100 |") || rows[0].contains("| sync | 2 | 4 |"));
    }

    #[test]
    fn quick_config_ladder_is_guaranteed_to_trip() {
        let cfg = LoadgenConfig::quick();
        // The last rung's offered rate must exceed anything a real
        // machine sustains over HTTP (so CI always exercises the declare
        // path via a stop rule, not ladder exhaustion).
        let top = cfg.rps_start * cfg.rps_factor.powi(cfg.rps_steps as i32 - 1);
        assert!(top > 1.0e7, "quick ladder tops out at {top} rps — not guaranteed to trip");
        assert!(cfg.step_secs <= 1.0, "quick rungs must stay short for CI");
    }
}
