//! Prometheus text-exposition parser for the loadgen metrics scraper.
//!
//! The load harness reads its latency distributions from the service's
//! own `GET /metrics` endpoint (`util::metrics::render` plus the store's
//! scrape-time series) instead of re-instrumenting the client side: the
//! server-side `balsam_api_request_seconds{endpoint=...}` histograms are
//! what production alerting consumes, so the SLO verdicts measure the
//! same distribution operators will stare at. This module parses the
//! text format (version 0.0.4) far enough for that job: sample lines
//! with optional labels (including escaped label values), histogram
//! reassembly from `_bucket`/`_sum`/`_count` series, delta between two
//! scrapes, and `histogram_quantile`-style estimation.
//!
//! Round-trip against [`crate::util::metrics::render`] output is pinned
//! by the unit tests below.

/// One sample line: `name{k="v",...} value`.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    /// Metric name (for histograms the suffixed series name, e.g.
    /// `balsam_api_request_seconds_bucket`).
    pub name: String,
    /// Label pairs in exposition order.
    pub labels: Vec<(String, String)>,
    /// Parsed value (`+Inf`/`-Inf`/`NaN` accepted).
    pub value: f64,
}

impl Sample {
    /// Value of the label `key`, if present.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Does this sample carry every requested `(key, value)` pair?
    /// (Extra labels on the sample are allowed — callers match on the
    /// labels they care about, like a PromQL selector.)
    fn matches(&self, labels: &[(&str, &str)]) -> bool {
        labels.iter().all(|(k, v)| self.label(k) == Some(*v))
    }
}

/// A parsed scrape: every sample line of one `/metrics` response.
#[derive(Debug, Clone, Default)]
pub struct Scrape {
    samples: Vec<Sample>,
}

/// A histogram reassembled from one scrape: cumulative bucket counts
/// keyed by their `le` upper bounds, plus the `_sum`/`_count` series.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Hist {
    /// `(le_bound, cumulative_count)` in ascending bound order; the last
    /// entry is the `+Inf` bucket (bound `f64::INFINITY`).
    pub buckets: Vec<(f64, f64)>,
    /// Sum of observed values.
    pub sum: f64,
    /// Total observations.
    pub count: f64,
}

impl Scrape {
    /// Parse a text-exposition document. Comment (`# ...`) and blank
    /// lines are skipped; a malformed sample line is an error (the
    /// loadgen must not silently compute SLO verdicts over a scrape it
    /// misread).
    pub fn parse(text: &str) -> Result<Scrape, String> {
        let mut samples = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            samples.push(
                parse_sample(line).map_err(|e| format!("line {}: {e}: {line:?}", lineno + 1))?,
            );
        }
        Ok(Scrape { samples })
    }

    /// Every parsed sample.
    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// The value of the first sample named `name` matching all requested
    /// labels (extra labels on the sample are ignored).
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        self.samples.iter().find(|s| s.name == name && s.matches(labels)).map(|s| s.value)
    }

    /// Reassemble the histogram family `name` restricted to `labels`
    /// (e.g. `("endpoint", "SessionSync")`): collects the
    /// `<name>_bucket` series (sorted by their `le` bound), `<name>_sum`
    /// and `<name>_count`. `None` when no bucket series matches — a
    /// family whose endpoint has not served a request yet.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<Hist> {
        let bucket_name = format!("{name}_bucket");
        let mut buckets: Vec<(f64, f64)> = Vec::new();
        for s in &self.samples {
            if s.name == bucket_name && s.matches(labels) {
                let le = parse_float(s.label("le")?).ok()?;
                buckets.push((le, s.value));
            }
        }
        if buckets.is_empty() {
            return None;
        }
        buckets.sort_by(|a, b| a.0.total_cmp(&b.0));
        let sum = self.value(&format!("{name}_sum"), labels).unwrap_or(0.0);
        let count = self
            .value(&format!("{name}_count"), labels)
            .unwrap_or_else(|| buckets.last().map(|b| b.1).unwrap_or(0.0));
        Some(Hist { buckets, sum, count })
    }
}

impl Hist {
    /// No observations?
    pub fn is_empty(&self) -> bool {
        self.count <= 0.0
    }

    /// The histogram of observations recorded *between* `base` and
    /// `self` (two scrapes of the same monotonically-growing family):
    /// bucket-wise cumulative-count difference. `None` when the bucket
    /// bound layouts differ (different metric, or a process restart
    /// reset the registry — counts going backwards).
    pub fn delta(&self, base: &Hist) -> Option<Hist> {
        if self.buckets.len() != base.buckets.len() {
            return None;
        }
        let mut buckets = Vec::with_capacity(self.buckets.len());
        for (&(le, cum), &(ble, bcum)) in self.buckets.iter().zip(&base.buckets) {
            if le.total_cmp(&ble) != std::cmp::Ordering::Equal || cum < bcum {
                return None;
            }
            buckets.push((le, cum - bcum));
        }
        if self.count < base.count {
            return None;
        }
        Some(Hist { buckets, sum: self.sum - base.sum, count: self.count - base.count })
    }

    /// Accumulate another histogram with the same bucket layout into this
    /// one (summing a mix's per-endpoint families into one distribution).
    /// Mismatched layouts are ignored rather than corrupting the merge.
    pub fn merge(&mut self, other: &Hist) {
        if self.buckets.is_empty() {
            *self = other.clone();
            return;
        }
        if self.buckets.len() != other.buckets.len() {
            return;
        }
        for (b, ob) in self.buckets.iter_mut().zip(&other.buckets) {
            b.1 += ob.1;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    /// `histogram_quantile`-style estimate: find the bucket the q-th
    /// observation (q in [0, 1]) falls in and interpolate linearly inside
    /// it. Observations in the `+Inf` bucket report the highest finite
    /// bound (the value is only known to be "past the last bucket").
    /// `None` on an empty histogram.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let total = self.buckets.last()?.1;
        if total <= 0.0 {
            return None;
        }
        let rank = q.clamp(0.0, 1.0) * total;
        let mut prev_le = 0.0;
        let mut prev_cum = 0.0;
        for &(le, cum) in &self.buckets {
            if cum >= rank && cum > prev_cum {
                if le.is_infinite() {
                    return Some(prev_le);
                }
                let frac = ((rank - prev_cum) / (cum - prev_cum)).clamp(0.0, 1.0);
                return Some(prev_le + (le - prev_le) * frac);
            }
            if cum > prev_cum {
                prev_cum = cum;
                prev_le = le;
            }
        }
        // rank > every cumulative count (float slop): the last bucket.
        let &(le, _) = self.buckets.last()?;
        Some(if le.is_infinite() { prev_le } else { le })
    }
}

/// Parse `name{k="v",...} value` or `name value`.
fn parse_sample(line: &str) -> Result<Sample, String> {
    let name_end = line
        .find(|c: char| c == '{' || c.is_whitespace())
        .ok_or("no value separator")?;
    let name = line[..name_end].to_string();
    if name.is_empty() {
        return Err("empty metric name".into());
    }
    let rest = &line[name_end..];
    let (labels, value_part) = if let Some(body) = rest.strip_prefix('{') {
        let (labels, after) = parse_labels(body)?;
        (labels, after)
    } else {
        (Vec::new(), rest)
    };
    let value_str = value_part.split_whitespace().next().ok_or("missing value")?;
    let value = parse_float(value_str)?;
    Ok(Sample { name, labels, value })
}

/// Parse `k="v",k2="v2"}` (after the opening brace); returns the pairs
/// and the remainder after the closing brace. Label values support the
/// exposition-format escapes `\\`, `\"` and `\n`.
fn parse_labels(mut s: &str) -> Result<(Vec<(String, String)>, &str), String> {
    let mut labels = Vec::new();
    loop {
        s = s.trim_start_matches([' ', ',']);
        if let Some(rest) = s.strip_prefix('}') {
            return Ok((labels, rest));
        }
        let eq = s.find('=').ok_or("label without '='")?;
        let key = s[..eq].trim().to_string();
        if key.is_empty() {
            return Err("empty label name".into());
        }
        s = s[eq + 1..].strip_prefix('"').ok_or("label value not quoted")?;
        let mut value = String::new();
        let mut chars = s.char_indices();
        let close = loop {
            let (i, c) = chars.next().ok_or("unterminated label value")?;
            match c {
                '"' => break i,
                '\\' => match chars.next().ok_or("dangling escape")? {
                    (_, '\\') => value.push('\\'),
                    (_, '"') => value.push('"'),
                    (_, 'n') => value.push('\n'),
                    (_, other) => return Err(format!("unknown escape \\{other}")),
                },
                c => value.push(c),
            }
        };
        labels.push((key, value));
        s = &s[close + 1..];
    }
}

/// Exposition float: ordinary f64 plus `+Inf` / `-Inf` / `NaN`.
fn parse_float(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" | "Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        _ => s.parse::<f64>().map_err(|e| format!("bad float {s:?}: {e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::metrics;

    #[test]
    fn parses_plain_and_labeled_samples() {
        let text = "\
# HELP x help text with {braces} and \"quotes\"
# TYPE x counter
x 42
y{a=\"1\",b=\"two\"} 3.5
z{le=\"+Inf\"} 7
";
        let s = Scrape::parse(text).unwrap();
        assert_eq!(s.samples().len(), 3);
        assert_eq!(s.value("x", &[]), Some(42.0));
        assert_eq!(s.value("y", &[("b", "two")]), Some(3.5));
        assert_eq!(s.value("y", &[("a", "1"), ("b", "two")]), Some(3.5));
        assert_eq!(s.value("y", &[("a", "2")]), None);
        assert!(s.value("z", &[("le", "+Inf")]).unwrap() == 7.0);
    }

    #[test]
    fn escaped_label_values_roundtrip() {
        let text = "f{path=\"C:\\\\tmp\",msg=\"say \\\"hi\\\"\",nl=\"a\\nb\"} 1\n";
        let s = Scrape::parse(text).unwrap();
        let sample = &s.samples()[0];
        assert_eq!(sample.label("path"), Some("C:\\tmp"));
        assert_eq!(sample.label("msg"), Some("say \"hi\""));
        assert_eq!(sample.label("nl"), Some("a\nb"));
    }

    #[test]
    fn malformed_lines_are_errors() {
        for bad in [
            "name_only",
            "x{unclosed=\"v\" 1",
            "x{noquote=v} 1",
            "x{k=\"bad escape \\x\"} 1",
            "x notafloat",
        ] {
            assert!(Scrape::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn histogram_reassembly_and_quantiles() {
        let text = "\
h_bucket{le=\"0.1\"} 10
h_bucket{le=\"0.5\"} 30
h_bucket{le=\"+Inf\"} 40
h_sum 12.5
h_count 40
";
        let s = Scrape::parse(text).unwrap();
        let h = s.histogram("h", &[]).unwrap();
        assert_eq!(h.count, 40.0);
        assert_eq!(h.sum, 12.5);
        assert_eq!(h.buckets.len(), 3);
        // p25 is the 10th observation: exactly the first bucket edge.
        assert!((h.quantile(0.25).unwrap() - 0.1).abs() < 1e-9);
        // p50 is the 20th: halfway through the (0.1, 0.5] bucket's 20.
        assert!((h.quantile(0.5).unwrap() - 0.3).abs() < 1e-9);
        // Observations in +Inf report the last finite bound.
        assert!((h.quantile(0.999).unwrap() - 0.5).abs() < 1e-9);
        assert!(s.histogram("h", &[("endpoint", "nope")]).is_none());
    }

    #[test]
    fn histogram_delta_between_scrapes() {
        let base = Scrape::parse("h_bucket{le=\"1\"} 5\nh_bucket{le=\"+Inf\"} 6\nh_sum 4\nh_count 6\n")
            .unwrap()
            .histogram("h", &[])
            .unwrap();
        let later =
            Scrape::parse("h_bucket{le=\"1\"} 9\nh_bucket{le=\"+Inf\"} 12\nh_sum 10\nh_count 12\n")
                .unwrap()
                .histogram("h", &[])
                .unwrap();
        let d = later.delta(&base).unwrap();
        assert_eq!(d.count, 6.0);
        assert_eq!(d.sum, 6.0);
        assert_eq!(d.buckets, vec![(1.0, 4.0), (f64::INFINITY, 6.0)]);
        // Counts going backwards (process restart) refuse to diff.
        assert!(base.delta(&later).is_none());
    }

    #[test]
    fn merge_accumulates_same_layout() {
        let a = Scrape::parse("h_bucket{le=\"1\"} 2\nh_bucket{le=\"+Inf\"} 3\nh_sum 2\nh_count 3\n")
            .unwrap()
            .histogram("h", &[])
            .unwrap();
        let mut acc = Hist::default();
        acc.merge(&a);
        acc.merge(&a);
        assert_eq!(acc.count, 6.0);
        assert_eq!(acc.buckets[0].1, 4.0);
    }

    #[test]
    fn empty_histogram_has_no_quantile() {
        let h = Hist { buckets: vec![(1.0, 0.0), (f64::INFINITY, 0.0)], sum: 0.0, count: 0.0 };
        assert!(h.quantile(0.5).is_none());
        assert!(h.is_empty());
    }

    /// Round-trip against the real registry exposition: every histogram
    /// family `util::metrics::render` emits must reassemble with exactly
    /// the registry's bucket bounds plus `+Inf`, in ascending order.
    /// Values are not asserted — the registry is process-global and
    /// sibling tests move it concurrently.
    #[test]
    fn roundtrips_registry_exposition() {
        // Ensure at least one per-endpoint family has series to parse.
        // Sibling tests may briefly flip the global recording switch off,
        // so retry until the observation lands.
        let s = loop {
            metrics::set_enabled(true);
            metrics::api_observe("SessionSync", false, metrics::clock());
            let s = Scrape::parse(&metrics::render()).expect("render() output must parse");
            if s.histogram("balsam_api_request_seconds", &[("endpoint", "SessionSync")]).is_some() {
                break s;
            }
            std::thread::yield_now();
        };
        for name in ["balsam_wal_fsync_seconds", "balsam_wal_append_seconds"] {
            let h = s.histogram(name, &[]).unwrap_or_else(|| panic!("no histogram {name}"));
            assert_eq!(h.buckets.len(), metrics::LATENCY_BOUNDS.len() + 1, "{name}");
            for (b, bound) in h.buckets.iter().zip(metrics::LATENCY_BOUNDS) {
                assert_eq!(b.0, *bound, "{name} bound mismatch");
            }
            assert!(h.buckets.last().unwrap().0.is_infinite(), "{name} missing +Inf");
            // Cumulative counts never decrease across buckets.
            for w in h.buckets.windows(2) {
                assert!(w[1].1 >= w[0].1, "{name} buckets not cumulative");
            }
        }
        let ep = s
            .histogram("balsam_api_request_seconds", &[("endpoint", "SessionSync")])
            .expect("per-endpoint histogram after api_observe");
        assert!(ep.buckets.last().unwrap().0.is_infinite());
        // Plain counter/gauge families parse as unlabeled samples.
        assert!(s.value("balsam_http_connections_total", &[]).is_some());
        assert!(s.value("balsam_persist_poisoned", &[]).is_some());
    }
}
