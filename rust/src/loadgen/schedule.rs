//! Open-loop send schedule.
//!
//! The defining property of an open-loop load generator is that request
//! *send times* are fixed up front by the offered rate, independent of
//! how long the server takes to answer — a slow response does not slow
//! the arrival process down, so queueing delay shows up in the measured
//! latency instead of being silently absorbed (the "coordinated
//! omission" failure mode of closed-loop drivers).
//!
//! A plan at `rps` over `duration_s` seconds defines tick `i` at offset
//! `i / rps` seconds from the step start, for `i in 0..ceil(rps *
//! duration_s)`. Ticks are partitioned across `senders` round-robin
//! (sender `s` owns ticks `i ≡ s (mod senders)`), so each sender walks
//! its own arithmetic sequence of deadlines and no coordination is
//! needed at runtime. A sender that falls too far behind its schedule
//! *skips* the overdue ticks and counts them against the failure rate —
//! dropping load on the floor is a failure of the system under test,
//! not a reprieve.

use std::time::Duration;

/// Fixed-rate open-loop schedule for one sweep step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpenLoopPlan {
    /// Offered request rate, requests/second across all senders. Must
    /// be finite and > 0.
    pub rps: f64,
    /// Number of concurrent sender threads the ticks are split over.
    pub senders: usize,
    /// Step duration in seconds.
    pub duration_s: f64,
}

impl OpenLoopPlan {
    /// Total ticks the plan offers: `ceil(rps * duration_s)`.
    pub fn planned_ticks(&self) -> u64 {
        (self.rps * self.duration_s).ceil().max(0.0) as u64
    }

    /// Offset from step start of tick `i`.
    pub fn deadline(&self, tick: u64) -> Duration {
        Duration::from_secs_f64(tick as f64 / self.rps)
    }

    /// The ticks owned by `sender` (0-based), in deadline order.
    pub fn sender_ticks(&self, sender: usize) -> SenderTicks {
        SenderTicks { next: sender as u64, stride: self.senders.max(1) as u64, end: self.planned_ticks() }
    }
}

/// Iterator over one sender's tick indices.
#[derive(Debug, Clone)]
pub struct SenderTicks {
    next: u64,
    stride: u64,
    end: u64,
}

impl Iterator for SenderTicks {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if self.next >= self.end {
            return None;
        }
        let tick = self.next;
        self.next += self.stride;
        Some(tick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn planned_ticks_rounds_up() {
        let plan = OpenLoopPlan { rps: 10.0, senders: 1, duration_s: 1.05 };
        assert_eq!(plan.planned_ticks(), 11);
        let plan = OpenLoopPlan { rps: 3.0, senders: 1, duration_s: 1.0 };
        assert_eq!(plan.planned_ticks(), 3);
    }

    #[test]
    fn deadlines_follow_the_offered_rate() {
        let plan = OpenLoopPlan { rps: 200.0, senders: 4, duration_s: 1.0 };
        assert_eq!(plan.deadline(0), Duration::ZERO);
        let d1 = plan.deadline(1).as_secs_f64();
        assert!((d1 - 0.005).abs() < 1e-12);
        // Deadlines depend only on the global tick index, not the sender
        // split: offered rate is constant regardless of concurrency.
        let d100 = plan.deadline(100).as_secs_f64();
        assert!((d100 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn senders_partition_the_ticks() {
        // Property: for random (rps, senders, duration), the per-sender
        // tick streams are disjoint, sorted, and union to 0..planned.
        crate::util::check::forall(
            "schedule::partition",
            0x10ad,
            200,
            |g: &mut Pcg| {
                let rps = 1.0 + g.f64() * 500.0;
                let senders = 1 + g.below(8) as usize;
                let duration_s = 0.1 + g.f64() * 3.0;
                OpenLoopPlan { rps, senders, duration_s }
            },
            |plan| {
                let planned = plan.planned_ticks();
                let mut seen = vec![false; planned as usize];
                for s in 0..plan.senders {
                    let mut prev: Option<u64> = None;
                    for tick in plan.sender_ticks(s) {
                        crate::prop_assert!(tick < planned, "tick {tick} out of range {planned}");
                        crate::prop_assert!(
                            prev.is_none_or(|p| tick > p),
                            "sender {s} ticks not strictly increasing"
                        );
                        crate::prop_assert!(
                            !seen[tick as usize],
                            "tick {tick} owned by two senders"
                        );
                        seen[tick as usize] = true;
                        prev = Some(tick);
                    }
                }
                crate::prop_assert!(
                    seen.iter().all(|&x| x),
                    "some tick owned by no sender"
                );
                Ok(())
            },
        );
    }

    #[test]
    fn zero_senders_degrades_to_one() {
        let plan = OpenLoopPlan { rps: 5.0, senders: 0, duration_s: 1.0 };
        let ticks: Vec<u64> = plan.sender_ticks(0).collect();
        assert_eq!(ticks, vec![0, 1, 2, 3, 4]);
    }
}
