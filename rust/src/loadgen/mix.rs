//! Payload mixes: what each open-loop tick actually sends.
//!
//! A mix shapes the request stream after one of the traffic patterns the
//! paper's deployment sees, so "max sustainable rps" is declared per
//! workload rather than for one synthetic endpoint:
//!
//! - **submit-heavy** — the light-source edge during a burst: mostly
//!   `BulkCreateJobs`, with the monitoring reads (`CountByState`,
//!   `ListJobs`) an experiment dashboard issues alongside.
//! - **sync-heavy** — launcher steady state: the acquire → run →
//!   `SessionSync` lifecycle loop that dominates interior traffic at the
//!   compute sites.
//! - **watch-heavy** — subscriber steady state: `WatchEvents` cursor
//!   probes and `ListEvents` pages over a trickle of job creations that
//!   keeps events flowing.
//!
//! Each sender thread owns one [`MixDriver`]: a small state machine that
//! emits the next request for its tick, watches responses to learn ids
//! (acquired jobs, event cursors), and resets itself on errors so one
//! rejected transition doesn't wedge the stream.

use crate::service::{ApiRequest, ApiResponse, JobCreate, JobFilter, JobState, SessionId, SiteId};
use crate::util::rng::Pcg;

/// Which traffic pattern a sweep combo offers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mix {
    /// Light-source burst: job creation dominates.
    SubmitHeavy,
    /// Launcher lifecycle loop: acquire/update/sync dominates.
    SyncHeavy,
    /// Event subscribers: watch/list dominates.
    WatchHeavy,
}

impl Mix {
    /// Every mix, sweep order.
    pub fn all() -> [Mix; 3] {
        [Mix::SubmitHeavy, Mix::SyncHeavy, Mix::WatchHeavy]
    }

    /// Parse a CLI/env spelling.
    pub fn parse(s: &str) -> Option<Mix> {
        match s.trim() {
            "submit" | "submit-heavy" => Some(Mix::SubmitHeavy),
            "sync" | "sync-heavy" => Some(Mix::SyncHeavy),
            "watch" | "watch-heavy" => Some(Mix::WatchHeavy),
            _ => None,
        }
    }

    /// Stable label used in reports, JSON, and trend-gate keys.
    pub fn label(&self) -> &'static str {
        match self {
            Mix::SubmitHeavy => "submit",
            Mix::SyncHeavy => "sync",
            Mix::WatchHeavy => "watch",
        }
    }

    /// The `endpoint` label values whose server-side
    /// `balsam_api_request_seconds` histograms make up this mix's
    /// latency SLO. `WatchEvents` is deliberately absent everywhere: its
    /// histogram includes intentional long-poll park time, which would
    /// read as latency when it is the feature working as designed (the
    /// drivers only send non-blocking probes, but excluding the family
    /// keeps the verdict robust if other subscribers share the process).
    pub fn latency_endpoints(&self) -> &'static [&'static str] {
        match self {
            Mix::SubmitHeavy => &["BulkCreateJobs", "CountByState", "ListJobs"],
            Mix::SyncHeavy => {
                &["BulkCreateJobs", "SessionAcquire", "BulkUpdateJobState", "SessionSync"]
            }
            Mix::WatchHeavy => &["ListEvents", "BulkCreateJobs"],
        }
    }
}

/// Sync-heavy lifecycle position (see [`MixDriver::next_request`]).
#[derive(Debug, Clone, PartialEq)]
enum SyncPhase {
    /// Feed the queue with runnable jobs.
    Create,
    /// Lease runnable jobs into the session.
    Acquire,
    /// Move the acquired batch to Running.
    Run(Vec<crate::service::JobId>),
    /// Report run completion + postprocess in one SessionSync.
    Sync(Vec<crate::service::JobId>),
}

/// Per-sender request synthesizer for one mix.
#[derive(Debug)]
pub struct MixDriver {
    mix: Mix,
    /// Site this sender's traffic targets.
    site: SiteId,
    /// Launcher lease (sync-heavy only; created during setup).
    session: SessionId,
    /// Registered app name jobs are created against.
    app: String,
    phase: SyncPhase,
    /// Event cursor for watch-heavy pagers.
    since: usize,
}

/// How many jobs one `BulkCreateJobs` tick carries. Small on purpose:
/// the open-loop rate is in *requests*, and each job leaves rows and
/// events behind, so a long sweep step must not balloon memory.
const CREATE_BATCH: usize = 2;

impl MixDriver {
    /// A driver for `mix`, sending against `site` with lease `session`
    /// (pass any session id for mixes that never use it) and app `app`.
    pub fn new(mix: Mix, site: SiteId, session: SessionId, app: &str) -> MixDriver {
        MixDriver { mix, site, session, app: app.to_string(), phase: SyncPhase::Create, since: 0 }
    }

    fn create_jobs(&self, n: usize) -> ApiRequest {
        let jobs =
            (0..n).map(|_| JobCreate::simple(self.site, &self.app, "loadgen")).collect::<Vec<_>>();
        ApiRequest::BulkCreateJobs { jobs }
    }

    /// The request this sender's next tick fires. `g` drives the
    /// probabilistic parts of the mix; the lifecycle parts are
    /// deterministic from response history.
    pub fn next_request(&mut self, g: &mut Pcg) -> ApiRequest {
        match self.mix {
            Mix::SubmitHeavy => {
                let roll = g.f64();
                if roll < 0.8 {
                    self.create_jobs(CREATE_BATCH)
                } else if roll < 0.9 {
                    ApiRequest::CountByState { site: self.site }
                } else {
                    ApiRequest::ListJobs {
                        filter: JobFilter { site: Some(self.site), limit: 32, ..JobFilter::default() },
                    }
                }
            }
            Mix::SyncHeavy => match &self.phase {
                SyncPhase::Create => self.create_jobs(CREATE_BATCH * 2),
                SyncPhase::Acquire => ApiRequest::SessionAcquire {
                    session: self.session,
                    max_nodes: 8,
                    max_jobs: CREATE_BATCH * 2,
                },
                SyncPhase::Run(jobs) => ApiRequest::BulkUpdateJobState {
                    jobs: jobs.clone(),
                    to: JobState::Running,
                    data: String::new(),
                },
                SyncPhase::Sync(jobs) => ApiRequest::SessionSync {
                    session: self.session,
                    updates: jobs
                        .iter()
                        .flat_map(|&j| {
                            [
                                (j, JobState::RunDone, String::new()),
                                (j, JobState::Postprocessed, String::new()),
                            ]
                        })
                        .collect(),
                },
            },
            Mix::WatchHeavy => {
                let roll = g.f64();
                if roll < 0.6 {
                    // Non-blocking probe: timeout 0 never parks a worker,
                    // so the offered rate stays honest.
                    ApiRequest::WatchEvents {
                        site: Some(self.site),
                        since: self.since,
                        timeout_ms: 0,
                        max_events: 0,
                    }
                } else if roll < 0.8 {
                    ApiRequest::ListEvents { since: self.since }
                } else {
                    self.create_jobs(1)
                }
            }
        }
    }

    /// Learn from a successful response: advance the sync lifecycle and
    /// the event cursor.
    pub fn observe(&mut self, req_was_acquire_or_events: &ApiRequest, resp: &ApiResponse) {
        match (req_was_acquire_or_events, resp) {
            (ApiRequest::BulkCreateJobs { .. }, _) if self.mix == Mix::SyncHeavy => {
                self.phase = SyncPhase::Acquire;
            }
            (ApiRequest::SessionAcquire { .. }, ApiResponse::Jobs(jobs)) => {
                if jobs.is_empty() {
                    // Queue drained (another sender took them): refill.
                    self.phase = SyncPhase::Create;
                } else {
                    self.phase = SyncPhase::Run(jobs.iter().map(|j| j.id).collect());
                }
            }
            (ApiRequest::BulkUpdateJobState { jobs, .. }, _) => {
                self.phase = SyncPhase::Sync(jobs.clone());
            }
            (ApiRequest::SessionSync { .. }, _) => {
                self.phase = SyncPhase::Create;
            }
            (
                ApiRequest::WatchEvents { .. } | ApiRequest::ListEvents { .. },
                ApiResponse::Events(page),
            ) => {
                if let Some(last) = page.events.last() {
                    self.since = (last.seq + 1) as usize;
                } else if let Some(t) = page.truncated_before {
                    self.since = self.since.max(t as usize);
                }
            }
            _ => {}
        }
    }

    /// A request failed (transport or 4xx/5xx): restart the lifecycle
    /// from a safe state so the stream keeps flowing.
    pub fn on_error(&mut self) {
        self.phase = SyncPhase::Create;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::{ApiConn, ServiceCore};

    /// In-process conn: drives the mix machines against a real core.
    struct Direct {
        svc: ServiceCore,
        token: String,
        now: f64,
    }

    impl Direct {
        fn call(&mut self, req: ApiRequest) -> Result<ApiResponse, crate::service::ApiError> {
            self.now += 0.01;
            self.svc.handle(self.now, &self.token, req)
        }
    }

    fn setup() -> (Direct, SiteId, SessionId) {
        let svc = ServiceCore::new(b"loadgen-test");
        let token = svc.admin_token();
        let mut d = Direct { svc, token, now: 0.0 };
        let site = d
            .call(ApiRequest::CreateSite {
                name: "mixsite".into(),
                hostname: "h".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        d.call(ApiRequest::RegisterApp {
            site,
            name: "loadapp".into(),
            command_template: "echo {x}".into(),
            parameters: vec!["x".into()],
        })
        .unwrap();
        let session =
            d.call(ApiRequest::CreateSession { site, batch_job: None }).unwrap().session_id();
        (d, site, session)
    }

    #[test]
    fn parse_and_labels_roundtrip() {
        for mix in Mix::all() {
            assert_eq!(Mix::parse(mix.label()), Some(mix));
            assert_eq!(Mix::parse(&format!("{}-heavy", mix.label())), Some(mix));
        }
        assert_eq!(Mix::parse("nope"), None);
    }

    #[test]
    fn latency_endpoints_are_registered_and_exclude_watch() {
        for mix in Mix::all() {
            for ep in mix.latency_endpoints() {
                assert!(
                    crate::util::metrics::ENDPOINTS.contains(ep),
                    "{ep} not a registered endpoint label"
                );
                assert_ne!(*ep, "WatchEvents", "park time must not enter the latency SLO");
            }
        }
    }

    /// The sync-heavy machine walks its whole lifecycle against a real
    /// core without ever sending an illegal transition.
    #[test]
    fn sync_mix_lifecycle_round_trips() {
        let (mut d, site, session) = setup();
        let mut drv = MixDriver::new(Mix::SyncHeavy, site, session, "loadapp");
        let mut g = Pcg::seeded(7);
        let mut synced = 0;
        for _ in 0..40 {
            let req = drv.next_request(&mut g);
            if matches!(req, ApiRequest::SessionSync { .. }) {
                synced += 1;
            }
            match d.call(req.clone()) {
                Ok(resp) => {
                    if let ApiResponse::JobIds(rejected) = &resp {
                        if matches!(req, ApiRequest::SessionSync { .. }) {
                            assert!(rejected.is_empty(), "sync rejected: {rejected:?}");
                        }
                    }
                    drv.observe(&req, &resp);
                }
                Err(e) => panic!("sync mix sent an illegal request {req:?}: {e:?}"),
            }
        }
        assert!(synced >= 2, "lifecycle never reached SessionSync");
    }

    /// Submit- and watch-heavy streams run clean against a real core and
    /// the watch cursor actually advances.
    #[test]
    fn submit_and_watch_mixes_run_clean() {
        let (mut d, site, session) = setup();
        for mix in [Mix::SubmitHeavy, Mix::WatchHeavy] {
            let mut drv = MixDriver::new(mix, site, session, "loadapp");
            let mut g = Pcg::seeded(11);
            for _ in 0..60 {
                let req = drv.next_request(&mut g);
                let resp = d.call(req.clone()).unwrap_or_else(|e| {
                    panic!("{} mix sent an illegal request {req:?}: {e:?}", mix.label())
                });
                drv.observe(&req, &resp);
            }
            if mix == Mix::WatchHeavy {
                assert!(drv.since > 0, "watch cursor never advanced");
            }
        }
    }

    /// Errors reset the lifecycle to Create rather than wedging.
    #[test]
    fn on_error_resets_lifecycle() {
        let (_, site, session) = setup();
        let mut drv = MixDriver::new(Mix::SyncHeavy, site, session, "loadapp");
        drv.phase = SyncPhase::Run(vec![]);
        drv.on_error();
        assert_eq!(drv.phase, SyncPhase::Create);
        let mut g = Pcg::seeded(3);
        assert!(matches!(drv.next_request(&mut g), ApiRequest::BulkCreateJobs { .. }));
    }
}
