//! Shared experiment scaffolding: deploy a federated Balsam world
//! (service + sites + agents) and drive it with clients, exactly like the
//! paper's §4.1 setup; plus the local-cluster baseline driver of §4.1.5.

use std::collections::BTreeMap;

use crate::client::{ClientActor, WorkloadClient};
use crate::service::api::ApiRequest;
use crate::service::models::SiteId;
use crate::service::ServiceCore;
use crate::sim::{Actor, Engine};
use crate::site::agent::{SimSiteActor, SiteAgent};
use crate::site::config::SiteConfig;
use crate::site::platform::{AllocStatus, SchedulerBackend};
use crate::substrates::facility::{payload_bytes, runtime_model};
use crate::world::World;

/// A deployed federation under simulation.
pub struct Deployment {
    pub world: World,
    pub engine: Engine,
    pub token: String,
    pub sites: BTreeMap<String, SiteId>,
}

/// Create service + one site per facility, register the standard apps,
/// and start a site agent actor for each. `tweak` customizes each site's
/// config (batch size, elastic caps, ...).
pub fn deploy(
    seed: u64,
    facilities: &[&str],
    reserved_nodes: u32,
    tweak: impl Fn(&mut SiteConfig),
) -> Deployment {
    let world = World::standard(seed, reserved_nodes);
    let token = world.service.admin_token();
    let mut engine = Engine::new();
    let mut sites = BTreeMap::new();
    for fac in facilities {
        let site = world
            .service
            .handle(0.0, &token, ApiRequest::CreateSite {
                name: fac.to_string(),
                hostname: format!("{fac}login1"),
                path: format!("/projects/balsam/{fac}"),
            })
            .unwrap()
            .site_id();
        for (app, tmpl) in [("MD", "python -m md_bench {{matrix}}"), ("EigenCorr", "corr {{h5}} -imm {{imm}}")] {
            world
                .service
                .handle(0.0, &token, ApiRequest::RegisterApp {
                    site,
                    name: app.into(),
                    command_template: tmpl.into(),
                    parameters: vec![],
                })
                .unwrap();
        }
        let mut cfg = SiteConfig::defaults(fac, site, token.clone());
        tweak(&mut cfg);
        engine.add(Box::new(SimSiteActor::new(SiteAgent::new(cfg))));
        sites.insert(fac.to_string(), site);
    }
    Deployment { world, engine, token, sites }
}

impl Deployment {
    pub fn add_client(&mut self, client: WorkloadClient) {
        self.engine.add(Box::new(ClientActor { client }));
    }

    pub fn add_actor(&mut self, actor: Box<dyn Actor>) {
        self.engine.add(actor);
    }

    pub fn run_until(&mut self, t_end: f64) {
        self.engine.run_until(&mut self.world, t_end);
    }

    pub fn svc(&self) -> &ServiceCore {
        &self.world.service
    }
}

/// §4.1.5 local-cluster baseline: the MD workload submitted directly to
/// the batch scheduler on an exclusive reservation — no Balsam. Data is
/// "staged" by local filesystem copies inside each job script, so the
/// per-job wall time is stage-in + run + stage-out, and the queueing delay
/// is whatever the scheduler imposes.
pub struct LocalBaseline {
    pub fac: String,
    pub workload: String,
    /// Keep this many jobs in flight (queued+running).
    pub inflight_target: usize,
    pub max_jobs: usize,
    submitted: Vec<(u64, f64)>, // (local_id, submit_t)
    /// (submit_t, queue_delay, wall, end_t, workload)
    pub completed: Vec<(f64, f64, f64, f64, String)>,
    pending: BTreeMap<u64, (f64, String)>,
    next_due: f64,
    rng: crate::util::rng::Pcg,
    /// Local staging bandwidth (bytes/s) and per-copy overhead (s):
    /// parallel-filesystem copy, 1–3 orders faster than WAN (Fig. 4).
    stage_bw: f64,
    stage_overhead: f64,
}

impl LocalBaseline {
    pub fn new(fac: &str, workload: &str, inflight: usize, seed: u64) -> LocalBaseline {
        LocalBaseline {
            fac: fac.to_string(),
            workload: workload.to_string(),
            inflight_target: inflight,
            max_jobs: 0,
            submitted: Vec::new(),
            completed: Vec::new(),
            pending: BTreeMap::new(),
            next_due: 0.0,
            rng: crate::util::rng::Pcg::seeded(seed ^ 0x10ca1),
            stage_bw: 1.8e9,
            stage_overhead: 0.4,
        }
    }

    fn sample_wall(&mut self, workload: &str) -> f64 {
        let (inb, outb) = payload_bytes(workload);
        let (mean, sd) = runtime_model(&self.fac, workload);
        let stage_in = self.stage_overhead + inb as f64 / self.stage_bw;
        let stage_out = self.stage_overhead + outb as f64 / self.stage_bw;
        let run = (mean + sd * self.rng.normal()).max(0.3 * mean);
        stage_in + run + stage_out
    }

    pub fn throughput(&self, t0: f64, t1: f64) -> f64 {
        let n = self.completed.iter().filter(|c| c.3 >= t0 && c.3 <= t1).count();
        n as f64 / (t1 - t0).max(1e-9)
    }
}

impl Actor for LocalBaseline {
    fn name(&self) -> String {
        format!("baseline:{}", self.fac)
    }

    fn wake(&mut self, now: f64, world: &mut World) -> f64 {
        if now < self.next_due {
            return self.next_due;
        }
        let sched = world.scheds.get_mut(&self.fac).expect("facility");
        // Reap completions.
        let ids: Vec<u64> = self.pending.keys().copied().collect();
        for id in ids {
            if let AllocStatus::Finished = sched.status(now, id) {
                let (submit_t, wl) = self.pending.remove(&id).unwrap();
                let delay = sched.queue_delay(id).unwrap_or(0.0);
                let wall = self.submitted.iter().find(|(i, _)| *i == id).map(|_| 0.0).unwrap_or(0.0);
                let _ = wall;
                let end = now; // polled at 1 s granularity
                self.completed.push((submit_t, delay, end - submit_t - delay, end, wl));
            }
        }
        // Top up in-flight jobs.
        let total = self.pending.len() + self.completed.len();
        let budget = if self.max_jobs == 0 { usize::MAX } else { self.max_jobs.saturating_sub(total) };
        let deficit = self.inflight_target.saturating_sub(self.pending.len()).min(budget);
        for _ in 0..deficit {
            let wl = if self.workload == "md_mix" {
                if self.rng.chance(0.5) { "md_small" } else { "md_large" }.to_string()
            } else {
                self.workload.clone()
            };
            let wall = self.sample_wall(&wl);
            let id = sched.submit(now, &self.fac, 1, wall);
            self.submitted.push((id, now));
            self.pending.insert(id, (now, wl));
        }
        self.next_due = now + 1.0;
        self.next_due
    }
}

/// Fault injector for Fig. 7: every `period`, ungracefully kill one
/// randomly-chosen running allocation at `fac` within `[start, stop]`.
pub struct FaultInjector {
    pub fac: String,
    pub period: f64,
    pub start: f64,
    pub stop: f64,
    pub kills: u64,
    next_due: f64,
    rng: crate::util::rng::Pcg,
}

impl FaultInjector {
    pub fn new(fac: &str, period: f64, start: f64, stop: f64, seed: u64) -> FaultInjector {
        FaultInjector {
            fac: fac.to_string(),
            period,
            start,
            stop,
            kills: 0,
            next_due: start,
            rng: crate::util::rng::Pcg::seeded(seed ^ 0xfa17),
        }
    }
}

impl Actor for FaultInjector {
    fn name(&self) -> String {
        format!("faults:{}", self.fac)
    }

    fn wake(&mut self, now: f64, world: &mut World) -> f64 {
        if now < self.next_due {
            return self.next_due;
        }
        if now > self.stop {
            return f64::INFINITY;
        }
        let sched = world.scheds.get_mut(&self.fac).expect("facility");
        let running = sched.running_ids();
        if !running.is_empty() {
            let victim = *self.rng.choose(&running);
            sched.kill(now, victim);
            self.kills += 1;
        }
        self.next_due = now + self.period;
        self.next_due
    }
}

/// Simple fixed-width table printer for experiment reports.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{Strategy, Submission};
    use crate::service::models::JobState;

    #[test]
    fn deploy_creates_sites_and_apps() {
        let d = deploy(1, &["theta", "cori"], 32, |_| {});
        assert_eq!(d.sites.len(), 2);
        assert_eq!(d.svc().store.apps_len(), 4);
    }

    #[test]
    fn deployment_processes_a_small_workload() {
        let mut d = deploy(2, &["cori"], 32, |c| c.transfer.batch_size = 8);
        let site = d.sites["cori"];
        let client = WorkloadClient::new(
            d.token.clone(),
            "APS",
            "MD",
            "md_small",
            Strategy::Single(site),
            Submission::SteadyBacklog { target: 8, period: 2.0 },
            3,
        )
        .with_max_jobs(16);
        d.add_client(client);
        d.run_until(1200.0);
        assert_eq!(d.svc().store.count_in_state(site, JobState::JobFinished), 16);
    }

    #[test]
    fn baseline_driver_completes_jobs() {
        let mut world = World::standard(5, 8);
        let mut engine = Engine::new();
        let mut bl = LocalBaseline::new("cori", "md_small", 8, 5);
        bl.max_jobs = 12;
        engine.add(Box::new(bl));
        engine.run_until(&mut world, 600.0);
        // Actor moved into engine; verify via scheduler state instead:
        // all 12 jobs finished -> all nodes free again.
        assert_eq!(world.scheds.get_mut("cori").unwrap().free_nodes(600.0), 8);
    }

    #[test]
    fn fault_injector_kills_running_allocations() {
        let mut world = World::standard(6, 16);
        {
            let sched = world.scheds.get_mut("theta").unwrap();
            sched.submit(0.0, "theta", 8, 1e5);
            for t in 0..60 {
                sched.pump(t as f64);
            }
            assert_eq!(sched.running_ids().len(), 1);
        }
        let mut engine = Engine::new();
        engine.add(Box::new(FaultInjector::new("theta", 30.0, 60.0, 200.0, 6)));
        engine.run_until(&mut world, 300.0);
        assert!(world.scheds.get_mut("theta").unwrap().running_ids().is_empty());
    }
}
