//! Table 1: APS ↔ Theta pipeline stage durations for the MD benchmark.
//!
//! Paper protocol: jobs submitted to the API at a steady rate onto a
//! 32-node allocation — 1156 small (200 MB) jobs at 2.0 jobs/s and 282
//! large (1.15 GB) jobs at 0.36 jobs/s. Reported: mean ± sd (p95) for
//! Stage In / Run Delay / Run / Stage Out / Time to Solution / Overhead.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table};
use crate::metrics::{job_table, stage_durations, summarize_stage, StageDurations};
use crate::service::models::JobState;

/// Paper's reported values for the comparison column: (stage, small, large).
pub const PAPER: [(&str, &str, &str); 6] = [
    ("Stage In", "17.1 ± 3.8 (23.4)", "47.2 ± 17.9 (83.3)"),
    ("Run Delay", "5.3 ± 11.5 (37.1)", "7.4 ± 14.7 (44.6)"),
    ("Run", "18.6 ± 9.6 (30.4)", "89.1 ± 3.8 (95.8)"),
    ("Stage Out", "11.7 ± 2.1 (14.9)", "17.5 ± 8.1 (34.1)"),
    ("Time to Solution", "52.7 ± 17.6 (103.0)", "161.1 ± 23.8 (205.0)"),
    ("Overhead", "34.1 ± 12.3 (66.3)", "72.1 ± 22.5 (112.2)"),
];

pub struct Cells {
    pub label: String,
    pub stage_in: String,
    pub run_delay: String,
    pub run: String,
    pub stage_out: String,
    pub tts: String,
    pub overhead: String,
    pub completed: usize,
}

/// One Table-1 column: `n_jobs` of `workload` at `rate` jobs/s.
pub fn measure(workload: &str, n_jobs: usize, rate: f64, seed: u64) -> Cells {
    let mut d = deploy(seed, &["theta"], 32, |c| {
        c.elastic.block_nodes = 32;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = 3600.0 * 3.0;
        c.transfer.batch_size = 16;
    });
    let site = d.sites["theta"];
    // Steady submission: batch of ceil(rate*4) every 4 s.
    let batch = ((rate * 4.0).round() as usize).max(1);
    let period = batch as f64 / rate;
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "MD",
        workload,
        Strategy::Single(site),
        Submission::Bursts { batch, period },
        seed,
    )
    .with_max_jobs(n_jobs);
    d.add_client(client);
    // Run until everything drains (bounded horizon).
    let horizon = n_jobs as f64 / rate + 1800.0;
    d.run_until(horizon);

    let jobs = job_table(d.svc());
    let durs = stage_durations(&d.svc().store.events(), &jobs);
    let pick = |f: fn(&StageDurations) -> Option<f64>| summarize_stage(&durs, f).table_cell();
    let overhead = {
        let mut s = crate::util::stats::Summary::new();
        for dd in durs.values() {
            if let (Some(tts), Some(run)) = (dd.time_to_solution, dd.run) {
                s.add(tts - run);
            }
        }
        s.table_cell()
    };
    Cells {
        label: workload.to_string(),
        stage_in: pick(|d| d.stage_in),
        run_delay: pick(|d| d.run_delay),
        run: pick(|d| d.run),
        stage_out: pick(|d| d.stage_out),
        tts: pick(|d| d.time_to_solution),
        overhead,
        completed: d.svc().store.count_in_state(site, JobState::JobFinished),
    }
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let (n_small, n_large) = if fast { (120, 40) } else { (1156, 282) };
    let small = measure("md_small", n_small, 2.0, seed);
    let large = measure("md_large", n_large, 0.36, seed + 1);
    let rows: Vec<Vec<String>> = PAPER
        .iter()
        .zip([
            (&small.stage_in, &large.stage_in),
            (&small.run_delay, &large.run_delay),
            (&small.run, &large.run),
            (&small.stage_out, &large.stage_out),
            (&small.tts, &large.tts),
            (&small.overhead, &large.overhead),
        ])
        .map(|((name, p_small, p_large), (m_small, m_large))| {
            vec![
                name.to_string(),
                m_small.clone(),
                p_small.to_string(),
                m_large.clone(),
                p_large.to_string(),
            ]
        })
        .collect();
    print_table(
        &format!(
            "Table 1: APS<->Theta MD pipeline stage durations (s) [{} small, {} large completed]",
            small.completed, large.completed
        ),
        &["Stage", "200MB measured", "200MB paper", "1.15GB measured", "1.15GB paper"],
        &rows,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_md_column_has_paper_shape() {
        let c = measure("md_small", 60, 2.0, 99);
        assert_eq!(c.completed, 60, "all jobs must finish");
        // Parse the mean out of "m ± s (p)" cells.
        let mean = |cell: &str| cell.split('±').next().unwrap().trim().parse::<f64>().unwrap();
        let run = mean(&c.run);
        assert!((run - 18.6).abs() < 8.0, "run={run} should be ~18.6s");
        let si = mean(&c.stage_in);
        assert!(si > 5.0 && si < 60.0, "stage-in={si} out of range");
        let tts = mean(&c.tts);
        assert!(tts > run + si * 0.5, "tts={tts} should dominate run+stage");
    }
}
