//! Figs. 9 + 10: simultaneous XPCS throughput on Theta + Summit + Cori
//! (32 nodes each), with datasets streamed from APS, ALS, or both; node
//! utilization and the Little's-law check.
//!
//! Expected shape: throughput orders Cori > Summit > Theta; Summit runs
//! near 100% utilization (compute-bound), Theta/Cori nearer ~75%
//! (network-I/O-bound); aggregate over three systems ≈ 4.4× Theta alone.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table, Deployment};
use crate::metrics::{littles_law, running_tasks_curve, state_timeline};
use crate::service::models::JobState;

pub struct PanelResult {
    pub label: String,
    /// per-facility: (arrival rate /min, completed, avg utilization %).
    pub per_fac: Vec<(String, f64, usize, f64)>,
    pub aggregate_completed: usize,
}

fn xpcs_deploy(seed: u64) -> Deployment {
    let mut d = deploy(seed, &["theta", "summit", "cori"], 32, |c| {
        c.elastic.block_nodes = 32;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = 3600.0 * 2.0;
        c.transfer.batch_size = 32; // paper: up to 32 files per transfer
        c.transfer.max_concurrent = 5; // and up to 5 concurrent tasks
    });
    // XPCS-campaign WAN conditions (see facility::XPCS_CAMPAIGN_BW_SCALE).
    d.world.xfer.net.bw_scale = crate::substrates::facility::XPCS_CAMPAIGN_BW_SCALE;
    d
}

/// One Fig. 9 panel: stream XPCS datasets from `sources` for `horizon` s,
/// steady backlog of 32 per site (split across sources when both run).
pub fn panel(sources: &[&str], horizon: f64, seed: u64) -> PanelResult {
    let mut d = xpcs_deploy(seed);
    let facs = ["theta", "summit", "cori"];
    let sites: Vec<_> = facs.iter().map(|f| d.sites[*f]).collect();
    let target = 32 / sources.len();
    for (i, src) in sources.iter().enumerate() {
        for &site in &sites {
            let client = WorkloadClient::new(
                d.token.clone(),
                src,
                "EigenCorr",
                "xpcs",
                Strategy::Single(site),
                Submission::SteadyBacklog { target, period: 4.0 },
                seed + i as u64 * 31,
            );
            d.add_client(client);
        }
    }
    d.run_until(horizon);
    let events = d.svc().store.events();
    let (t0, t1) = (horizon * 0.2, horizon);
    let mut per_fac = Vec::new();
    let mut aggregate = 0;
    for (fac, &site) in facs.iter().zip(&sites) {
        let arrivals = state_timeline(&events, site, JobState::StagedIn).rate(t0, t1) * 60.0;
        let completed = d.svc().store.count_in_state(site, JobState::JobFinished);
        let curve = running_tasks_curve(&events, site, horizon, 100);
        let util: f64 = curve
            .iter()
            .filter(|(t, _)| *t >= t0)
            .map(|(_, r)| *r as f64 / 32.0)
            .sum::<f64>()
            / curve.iter().filter(|(t, _)| *t >= t0).count().max(1) as f64;
        aggregate += completed;
        per_fac.push((fac.to_string(), arrivals, completed, util * 100.0));
    }
    PanelResult { label: sources.join("+"), per_fac, aggregate_completed: aggregate }
}

/// Theta-alone reference (the paper's 240-task baseline for the 4.37x).
pub fn theta_alone(horizon: f64, seed: u64) -> usize {
    let mut d = xpcs_deploy(seed);
    let site = d.sites["theta"];
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "EigenCorr",
        "xpcs",
        Strategy::Single(site),
        Submission::SteadyBacklog { target: 32, period: 4.0 },
        seed,
    );
    d.add_client(client);
    d.run_until(horizon);
    d.svc().store.count_in_state(site, JobState::JobFinished)
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let horizon = if fast { 600.0 } else { 1140.0 }; // paper: 19-minute run
    let mut rows = Vec::new();
    let mut aps_panel = None;
    for sources in [vec!["APS"], vec!["ALS"], vec!["APS", "ALS"]] {
        let p = panel(&sources, horizon, seed);
        for (fac, arr, done, util) in &p.per_fac {
            rows.push(vec![
                p.label.clone(),
                fac.clone(),
                format!("{arr:.1}"),
                done.to_string(),
                format!("{util:.0}%"),
            ]);
        }
        rows.push(vec![p.label.clone(), "TOTAL".into(), String::new(), p.aggregate_completed.to_string(), String::new()]);
        if p.label == "APS" {
            aps_panel = Some(p);
        }
    }
    print_table(
        "Fig 9: simultaneous XPCS throughput (32 nodes/site)",
        &["sources", "facility", "arrivals/min", "completed", "avg util"],
        &rows,
    );

    // Headline: aggregate vs Theta alone (paper: 4.37x; 1049 vs 240).
    let alone = theta_alone(horizon, seed + 99);
    let agg = aps_panel.as_ref().unwrap().aggregate_completed;
    println!(
        "\nheadline: {} tasks on 3 systems vs {} on Theta alone -> {:.2}x (paper: 4.37x, 1049 vs 240)",
        agg,
        alone,
        agg as f64 / alone.max(1) as f64
    );

    // Fig 10: Little's law check on the APS panel.
    let p = panel(&["APS"], horizon, seed + 7);
    let _ = p;
    let mut d = xpcs_deploy(seed + 7);
    let sites: Vec<_> = ["theta", "summit", "cori"].iter().map(|f| (f.to_string(), d.sites[*f])).collect();
    for &(_, site) in &sites {
        let client = WorkloadClient::new(
            d.token.clone(), "APS", "EigenCorr", "xpcs",
            Strategy::Single(site),
            Submission::SteadyBacklog { target: 32, period: 4.0 },
            seed + 7,
        );
        d.add_client(client);
    }
    d.run_until(horizon);
    let mut rows10 = Vec::new();
    for (fac, site) in &sites {
        let chk = littles_law(&d.svc().store.events(), *site, horizon * 0.2, horizon);
        rows10.push(vec![
            fac.clone(),
            format!("{:.2}", chk.lambda * 60.0),
            format!("{:.0}", chk.mean_runtime),
            format!("{:.1}", chk.expected_l),
            format!("{:.1}", chk.measured_l),
            format!("{:.0}%", 100.0 * chk.measured_l / 32.0),
        ]);
    }
    print_table(
        "Fig 10: Little's law (L = lambda*W) vs measured node utilization",
        &["facility", "lambda (/min)", "W (s)", "lambda*W", "measured L", "util"],
        &rows10,
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_ordering_and_aggregate_speedup() {
        let horizon = 700.0;
        let p = panel(&["APS"], horizon, 5);
        let done = |f: &str| p.per_fac.iter().find(|x| x.0 == f).unwrap().2;
        assert!(done("cori") > done("summit"), "cori {} !> summit {}", done("cori"), done("summit"));
        assert!(done("summit") >= done("theta"), "summit {} !>= theta {}", done("summit"), done("theta"));
        let alone = theta_alone(horizon, 6);
        let speedup = p.aggregate_completed as f64 / alone.max(1) as f64;
        assert!(
            (2.5..7.0).contains(&speedup),
            "aggregate speedup {speedup} out of paper-shaped range (4.37x)"
        );
    }

    #[test]
    fn littles_law_holds_in_steady_state() {
        let horizon = 700.0;
        let mut d = xpcs_deploy(11);
        let site = d.sites["summit"];
        let client = WorkloadClient::new(
            d.token.clone(), "APS", "EigenCorr", "xpcs",
            Strategy::Single(site),
            Submission::SteadyBacklog { target: 32, period: 4.0 },
            11,
        );
        d.add_client(client);
        d.run_until(horizon);
        let chk = littles_law(&d.svc().store.events(), site, horizon * 0.3, horizon);
        assert!(chk.expected_l > 1.0);
        let rel = (chk.expected_l - chk.measured_l).abs() / chk.measured_l.max(1.0);
        assert!(rel < 0.35, "L={} vs lambda*W={}", chk.measured_l, chk.expected_l);
    }
}
