//! Fig. 8: contribution of each pipeline stage to total XPCS analysis
//! latency, per route ({APS, ALS} × {Theta, Summit, Cori}), with at most
//! one 878 MB dataset in flight per route (no pipelining/batching).
//!
//! Expected shape: data transfer dominates overheads; totals range from
//! ~86 s (APS↔Cori) to ~150 s (ALS↔Theta); Cori's short runtime makes it
//! the fastest total; launcher startup overhead is 1–2 s.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table};
use crate::metrics::{job_table, stage_durations, summarize_stage};

pub struct RouteBreakdown {
    pub source: String,
    pub fac: String,
    pub stage_in: f64,
    pub run_delay: f64,
    pub run: f64,
    pub stage_out: f64,
    pub total: f64,
}

/// Median stage breakdown for `n` sequential XPCS jobs on one route.
pub fn route_breakdown(source: &str, fac: &str, n: usize, seed: u64) -> RouteBreakdown {
    let mut d = deploy(seed, &[fac], 32, |c| {
        c.elastic.block_nodes = 32;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = 3.0 * 3600.0;
        c.transfer.max_concurrent = 1; // max one dataset in flight
        c.transfer.batch_size = 2;     // one job = 1 IMM+HDF bundle
    });
    d.world.xfer.net.bw_scale = crate::substrates::facility::XPCS_CAMPAIGN_BW_SCALE;
    let site = d.sites[fac];
    let client = WorkloadClient::new(
        d.token.clone(),
        source,
        "EigenCorr",
        "xpcs",
        Strategy::Single(site),
        Submission::SteadyBacklog { target: 1, period: 2.0 },
        seed,
    )
    .with_max_jobs(n);
    d.add_client(client);
    d.run_until(3.0 * 3600.0);
    let jobs = job_table(d.svc());
    let durs = stage_durations(&d.svc().store.events(), &jobs);
    let med = |f: fn(&crate::metrics::StageDurations) -> Option<f64>| {
        summarize_stage(&durs, f).percentile(50.0)
    };
    let (si, rd, run, so) = (med(|d| d.stage_in), med(|d| d.run_delay), med(|d| d.run), med(|d| d.stage_out));
    RouteBreakdown {
        source: source.to_string(),
        fac: fac.to_string(),
        stage_in: si,
        run_delay: rd,
        run,
        stage_out: so,
        total: si + rd + run + so,
    }
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let n = if fast { 5 } else { 12 };
    let mut rows = Vec::new();
    let mut s = seed;
    for source in ["APS", "ALS"] {
        for fac in ["theta", "summit", "cori"] {
            s += 1;
            let b = route_breakdown(source, fac, n, s);
            rows.push(vec![
                format!("{}<->{}", b.source, b.fac),
                format!("{:.1}", b.stage_in),
                format!("{:.1}", b.run_delay),
                format!("{:.1}", b.run),
                format!("{:.1}", b.stage_out),
                format!("{:.1}", b.total),
            ]);
        }
    }
    print_table(
        "Fig 8: median XPCS stage latencies per route (s), one 878MB dataset in flight",
        &["route", "stage in", "run delay", "run", "stage out", "total"],
        &rows,
    );
    println!("paper shape: totals ~86s (APS<->cori) to ~150s (ALS<->theta); transfer dominates overhead");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cori_total_fastest_and_transfer_dominates_overhead() {
        let theta = route_breakdown("APS", "theta", 4, 21);
        let cori = route_breakdown("APS", "cori", 4, 22);
        assert!(cori.total < theta.total, "cori {} !< theta {}", cori.total, theta.total);
        // Overheads = stage_in + run_delay + stage_out; transfers dominate.
        let xfer = theta.stage_in + theta.stage_out;
        assert!(xfer > 2.0 * theta.run_delay, "transfer should dominate run delay");
        // Run delay small (pilot already provisioned).
        assert!(theta.run_delay < 20.0);
        // Totals in the paper's order of magnitude (tens of seconds to ~3 min).
        assert!(theta.total > 60.0 && theta.total < 400.0, "total={}", theta.total);
    }
}
