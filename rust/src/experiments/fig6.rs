//! Fig. 6: APS -> Theta dataset arrival rate vs transfer batch size, for
//! 128 MD datasets (200 MB and 1.15 GB variants), up to 3 concurrent
//! transfer tasks.
//!
//! Expected shape: small datasets improve steadily with batch size, then
//! DROP at batch = 128 (one task cannot use the full route bandwidth —
//! GridFTP default concurrency limits a single task); large datasets peak
//! near batch 16.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table};
use crate::metrics::state_timeline;
use crate::service::models::JobState;

pub const BATCH_SIZES: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];

/// Arrival rate (datasets/min) for 128 datasets at the given batch size.
/// Staging-only: elastic queue disabled so jobs park at PREPROCESSED.
pub fn arrival_rate(workload: &str, batch_size: usize, seed: u64) -> f64 {
    let n = 128;
    let mut d = deploy(seed, &["theta"], 32, |c| {
        c.elastic.enabled = false;
        c.transfer.batch_size = batch_size;
        c.transfer.max_concurrent = 3; // paper: up to three concurrent transfers
        c.transfer.split_across_slots = false; // paper's greedy batching
    });
    let site = d.sites["theta"];
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "MD",
        workload,
        Strategy::Single(site),
        Submission::Bursts { batch: n, period: 1e9 }, // all up front
        seed,
    )
    .with_max_jobs(n);
    d.add_client(client);
    d.run_until(3.0 * 3600.0);
    let tl = state_timeline(&d.svc().store.events(), site, JobState::StagedIn);
    assert_eq!(tl.count(), n, "all datasets must arrive");
    let t_last = tl.curve(3.0 * 3600.0, 3600).iter().find(|(_, c)| *c == n).unwrap().0;
    n as f64 / (t_last / 60.0)
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let sizes: &[usize] = if fast { &[1, 16, 64, 128] } else { &BATCH_SIZES };
    let mut rows = Vec::new();
    for &bs in sizes {
        let small = arrival_rate("md_small", bs, seed + bs as u64);
        let large = arrival_rate("md_large", bs, seed + 1000 + bs as u64);
        rows.push(vec![bs.to_string(), format!("{small:.1}"), format!("{large:.1}")]);
    }
    print_table(
        "Fig 6: APS dataset arrival rate vs transfer batch size (datasets/min, 128 jobs, <=3 tasks)",
        &["batch size", "200MB arrivals/min", "1.15GB arrivals/min"],
        &rows,
    );
    println!("paper shape: rate rises with batch size; drops at 128 (single task can't fill route)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batching_beats_single_file_and_128_drops() {
        let r1 = arrival_rate("md_small", 1, 42);
        let r16 = arrival_rate("md_small", 16, 43);
        let r64 = arrival_rate("md_small", 64, 44);
        let r128 = arrival_rate("md_small", 128, 45);
        assert!(r16 > 1.5 * r1, "batching should help: {r1} -> {r16}");
        // The single-task regime loses concurrency (paper's key finding).
        assert!(r128 < r64, "batch=128 should drop below 64: {r64} -> {r128}");
    }
}
