//! Fig. 3: weak scaling of MD throughput — local batch-queue pipeline vs
//! the Balsam APS↔{Theta, Cori} pipeline at 4–32 nodes, for small / large
//! / mixed input sizes.
//!
//! Expected shape (paper §4.2): Cobalt local throughput is FLAT (start-rate
//! throttled); Slurm local is moderately scalable; Balsam scales at
//! 85–100% efficiency on both machines despite WAN staging.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table, LocalBaseline};
use crate::metrics::completion_rate;
use crate::world::World;

pub const NODE_COUNTS: [u32; 4] = [4, 8, 16, 32];

/// Balsam pipeline throughput (jobs/s) at `nodes`.
pub fn balsam_rate(fac: &str, workload: &str, nodes: u32, horizon: f64, seed: u64) -> f64 {
    let mut d = deploy(seed, &[fac], nodes, |c| {
        c.elastic.block_nodes = nodes;
        c.elastic.max_nodes = nodes;
        c.elastic.wall_time_s = horizon * 2.0;
        c.transfer.batch_size = 16;
    });
    let site = d.sites[fac];
    // Paper: steady-state backlog of up to 48 datasets in flight.
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "MD",
        workload,
        Strategy::Single(site),
        Submission::SteadyBacklog { target: 48, period: 2.0 },
        seed,
    );
    d.add_client(client);
    d.run_until(horizon);
    // Measure over the steady-state back half.
    completion_rate(&d.svc().store.events(), site, horizon * 0.33, horizon)
}

/// Local batch-queue pipeline throughput (jobs/s) at `nodes`. The driver
/// is stepped directly (not via the engine) so the completion log stays
/// accessible after the run.
pub fn baseline_rate(fac: &str, workload: &str, nodes: u32, horizon: f64, seed: u64) -> f64 {
    let mut world = World::standard(seed, nodes);
    let mut bl = LocalBaseline::new(fac, workload, 48, seed);
    let mut t = 0.0;
    while t < horizon {
        use crate::sim::Actor;
        t = bl.wake(t, &mut world);
    }
    bl.throughput(horizon * 0.33, horizon)
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let horizon = if fast { 600.0 } else { 1500.0 };
    let node_counts: &[u32] = if fast { &[4, 32] } else { &NODE_COUNTS };
    for workload in ["md_small", "md_large", "md_mix"] {
        let mut rows = Vec::new();
        for fac in ["theta", "cori"] {
            for &n in node_counts {
                let b = balsam_rate(fac, workload, n, horizon, seed + n as u64);
                let l = baseline_rate(fac, workload, n, horizon, seed + 7 * n as u64);
                rows.push(vec![
                    fac.to_string(),
                    n.to_string(),
                    format!("{:.3}", l),
                    format!("{:.3}", b),
                    format!("{:.2}x", b / l.max(1e-9)),
                ]);
            }
        }
        print_table(
            &format!("Fig 3 ({workload}): weak scaling, local batch queue vs Balsam"),
            &["facility", "nodes", "local jobs/s", "balsam jobs/s", "balsam/local"],
            &rows,
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cobalt_baseline_is_flat_but_balsam_scales() {
        let h = 900.0;
        let base4 = baseline_rate("theta", "md_small", 4, h, 1);
        let base32 = baseline_rate("theta", "md_small", 32, h, 2);
        // Cobalt start-rate throttling: 8x nodes buys < 2x throughput.
        assert!(
            base32 < 2.0 * base4.max(1e-3),
            "cobalt should be flat: {base4} -> {base32}"
        );
        let bal4 = balsam_rate("theta", "md_small", 4, h, 3);
        let bal32 = balsam_rate("theta", "md_small", 32, h, 4);
        // Balsam weak-scales (>=60% of ideal 8x even in a short window).
        assert!(
            bal32 > 4.0 * bal4,
            "balsam should scale: {bal4} -> {bal32}"
        );
        // And Balsam beats the local Cobalt pipeline outright at 32 nodes.
        assert!(bal32 > base32, "balsam {bal32} <= cobalt baseline {base32}");
    }

    #[test]
    fn slurm_baseline_moderately_scalable() {
        let h = 700.0;
        let base4 = baseline_rate("cori", "md_small", 4, h, 5);
        let base32 = baseline_rate("cori", "md_small", 32, h, 6);
        let eff = base32 / (8.0 * base4);
        assert!(eff > 0.4, "slurm efficiency {eff} too low (paper: ~0.66)");
    }
}
