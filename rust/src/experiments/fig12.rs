//! Figs. 12–14: client-driven task-distribution strategies for the XPCS
//! benchmark from the APS — round-robin vs shortest-backlog — with
//! 16-job batches submitted every 8 s across Theta/Summit/Cori.
//!
//! Expected shape: shortest-backlog shifts work away from Theta (slow
//! transfers ⇒ backlog accumulates) toward Summit/Cori, buying ~16%
//! higher Cori throughput and a modest aggregate gain.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table};
use crate::metrics::state_timeline;
use crate::service::models::JobState;

pub struct StrategyOutcome {
    pub label: String,
    /// per facility: (submitted, staged_in, completed).
    pub per_fac: Vec<(String, usize, usize, usize)>,
    pub total_completed: usize,
}

pub fn run_strategy(shortest_backlog: bool, horizon: f64, seed: u64) -> StrategyOutcome {
    let mut d = deploy(seed, &["theta", "summit", "cori"], 32, |c| {
        c.elastic.block_nodes = 32;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = 2.0 * 3600.0;
        c.transfer.batch_size = 32;
        c.transfer.max_concurrent = 5;
    });
    d.world.xfer.net.bw_scale = crate::substrates::facility::XPCS_CAMPAIGN_BW_SCALE;
    let facs = ["theta", "summit", "cori"];
    let sites: Vec<_> = facs.iter().map(|f| d.sites[*f]).collect();
    let strategy = if shortest_backlog {
        Strategy::ShortestBacklog(sites.clone())
    } else {
        Strategy::RoundRobin(sites.clone())
    };
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "EigenCorr",
        "xpcs",
        strategy,
        Submission::Bursts { batch: 16, period: 8.0 },
        seed,
    );
    d.add_client(client);
    d.run_until(horizon);
    let mut per_fac = Vec::new();
    let mut total = 0;
    for (fac, &site) in facs.iter().zip(&sites) {
        let submitted = d
            .svc()
            .store
            .jobs_snapshot()
            .iter()
            .filter(|j| j.site_id == site)
            .count();
        let staged = state_timeline(&d.svc().store.events(), site, JobState::StagedIn).count();
        let done = d.svc().store.count_in_state(site, JobState::JobFinished);
        total += done;
        per_fac.push((fac.to_string(), submitted, staged, done));
    }
    StrategyOutcome {
        label: if shortest_backlog { "shortest-backlog" } else { "round-robin" }.into(),
        per_fac,
        total_completed: total,
    }
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let horizon = if fast { 600.0 } else { 720.0 }; // paper: ~6 min of submission
    let rr = run_strategy(false, horizon, seed);
    let sb = run_strategy(true, horizon, seed + 1);
    let mut rows = Vec::new();
    for out in [&rr, &sb] {
        for (fac, submitted, staged, done) in &out.per_fac {
            rows.push(vec![
                out.label.clone(),
                fac.clone(),
                submitted.to_string(),
                staged.to_string(),
                done.to_string(),
            ]);
        }
        rows.push(vec![out.label.clone(), "TOTAL".into(), String::new(), String::new(), out.total_completed.to_string()]);
    }
    print_table(
        "Fig 12-14: round-robin vs shortest-backlog (APS XPCS, 16 jobs / 8 s)",
        &["strategy", "facility", "submitted", "staged-in", "completed"],
        &rows,
    );
    // Fig 13: delta submitted per site.
    let mut rows13 = Vec::new();
    for ((fac, rr_sub, _, _), (_, sb_sub, _, _)) in rr.per_fac.iter().zip(&sb.per_fac) {
        rows13.push(vec![fac.clone(), format!("{:+}", *sb_sub as i64 - *rr_sub as i64)]);
    }
    print_table("Fig 13: Δ submitted (shortest-backlog − round-robin)", &["facility", "delta"], &rows13);
    // Fig 14: Cori throughput comparison.
    let cori_rr = rr.per_fac.iter().find(|x| x.0 == "cori").unwrap().3;
    let cori_sb = sb.per_fac.iter().find(|x| x.0 == "cori").unwrap().3;
    println!(
        "\nFig 14: Cori completed {} (RR) vs {} (SB) -> {:+.0}% (paper: +16%)",
        cori_rr,
        cori_sb,
        100.0 * (cori_sb as f64 - cori_rr as f64) / cori_rr.max(1) as f64
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shortest_backlog_shifts_load_off_theta() {
        let horizon = 420.0;
        let rr = run_strategy(false, horizon, 3);
        let sb = run_strategy(true, horizon, 4);
        let sub = |o: &StrategyOutcome, f: &str| o.per_fac.iter().find(|x| x.0 == f).unwrap().1;
        // RR is even by construction.
        let rr_theta = sub(&rr, "theta");
        let rr_cori = sub(&rr, "cori");
        assert!((rr_theta as i64 - rr_cori as i64).abs() <= 16);
        // SB submits fewer to theta than to cori (theta accumulates backlog).
        assert!(
            sub(&sb, "theta") < sub(&sb, "cori"),
            "SB should prefer cori: theta={} cori={}",
            sub(&sb, "theta"),
            sub(&sb, "cori")
        );
        // And SB does not lose meaningful aggregate throughput (paper:
        // "marginal differences" outside Cori at overloaded rates).
        assert!(sb.total_completed as f64 > 0.85 * rr.total_completed as f64);
    }
}
