//! Fig. 7: elastic-scaling stress test with fault injection (80 min,
//! APS↔Theta, 200 MB MD datasets).
//!
//! Phases: (1) 15 min at 1.0 jobs/s — completions track submissions;
//! (2) 15 min at 3.0 jobs/s — backlog grows beyond the 32-node elastic
//! cap; (3) 15 min in which a random launcher is killed every 2 min;
//! (4) submission stops and Balsam drains the FULL backlog — no task is
//! ever lost (durable state + heartbeat recovery).

use crate::client::{ClientActor, Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table, FaultInjector};
use crate::metrics::{running_tasks_curve, state_timeline};
use crate::service::models::JobState;

pub struct StressOutcome {
    pub submitted: usize,
    pub completed: usize,
    pub kills: u64,
    /// (t, submitted, staged, completed, running) samples.
    pub timeline: Vec<(f64, usize, usize, usize, usize)>,
}

pub fn stress(fast: bool, seed: u64) -> StressOutcome {
    let phase = if fast { 300.0 } else { 900.0 };
    let drain = if fast { 900.0 } else { 2100.0 };
    let horizon = 3.0 * phase + drain;
    let mut d = deploy(seed, &["theta"], 40, |c| {
        c.elastic.block_nodes = 8;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = 20.0 * 60.0;
        c.launcher.idle_timeout_s = 60.0;
    });
    let site = d.sites["theta"];
    // Phase 1: 1 job/s; phase 2: 3 jobs/s. Implemented as two burst
    // clients with bounded budgets.
    let c1 = WorkloadClient::new(
        d.token.clone(), "APS", "MD", "md_small",
        Strategy::Single(site),
        Submission::Bursts { batch: 4, period: 4.0 },
        seed,
    )
    .with_max_jobs(phase as usize);
    d.add_client(c1);
    // Phase-2 client starts at t=phase via an offset actor.
    let mut c2 = WorkloadClient::new(
        d.token.clone(), "APS", "MD", "md_small",
        Strategy::Single(site),
        Submission::Bursts { batch: 12, period: 4.0 },
        seed + 1,
    )
    .with_max_jobs(3 * phase as usize);
    c2.per_site.clear();
    c2.per_site.push((site, 0));
    d.add_actor(Box::new(DelayedClient { start: phase, inner: ClientActor { client: c2 } }));
    // Phase 3: fault injection every 2 min.
    d.add_actor(Box::new(FaultInjector::new("theta", 120.0, 2.0 * phase, 3.0 * phase, seed)));

    d.run_until(horizon);

    let events = d.svc().store.events();
    let sub_tl = state_timeline(&events, site, JobState::Ready);
    let staged_tl = state_timeline(&events, site, JobState::StagedIn);
    let done_tl = state_timeline(&events, site, JobState::JobFinished);
    let running = running_tasks_curve(&events, site, horizon, 80);
    let timeline = running
        .iter()
        .map(|&(t, r)| (t, sub_tl.cum_at(t), staged_tl.cum_at(t), done_tl.cum_at(t), r))
        .collect();
    StressOutcome {
        submitted: d.svc().store.job_count(),
        completed: d.svc().store.count_in_state(site, JobState::JobFinished),
        kills: 0, // injector moved into engine; kills implied by timeline
        timeline,
    }
}

/// Wrap an actor so it only starts ticking at `start`.
struct DelayedClient {
    start: f64,
    inner: ClientActor,
}

impl crate::sim::Actor for DelayedClient {
    fn name(&self) -> String {
        format!("delayed-{}", self.inner.name())
    }
    fn wake(&mut self, now: f64, world: &mut crate::world::World) -> f64 {
        if now < self.start {
            return self.start;
        }
        self.inner.wake(now, world)
    }
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let out = stress(fast, seed);
    let rows: Vec<Vec<String>> = out
        .timeline
        .iter()
        .step_by(4)
        .map(|&(t, sub, staged, done, running)| {
            vec![
                format!("{:.0}", t / 60.0),
                sub.to_string(),
                staged.to_string(),
                done.to_string(),
                running.to_string(),
            ]
        })
        .collect();
    print_table(
        "Fig 7: elastic scaling + fault injection timeline (Theta, 200MB MD)",
        &["t (min)", "submitted", "staged-in", "completed", "running tasks"],
        &rows,
    );
    println!(
        "final: submitted={} completed={} -> {}",
        out.submitted,
        out.completed,
        if out.submitted == out.completed { "NO TASKS LOST (paper §4.4)" } else { "TASKS MISSING!" }
    );
    crate::ensure!(out.submitted == out.completed, "lost tasks under faults");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_tasks_lost_under_faults_and_overload() {
        let out = stress(true, 7);
        assert!(out.submitted > 0);
        assert_eq!(
            out.submitted, out.completed,
            "every submitted job must eventually finish despite kills"
        );
        // Backlog grew during overload: staged-in lags submissions mid-run.
        let mid = &out.timeline[out.timeline.len() / 2];
        assert!(mid.1 > mid.3, "submissions should outpace completions mid-run");
    }
}
