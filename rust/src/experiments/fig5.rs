//! Fig. 5: effective cross-facility Globus transfer rates — quartile boxes
//! over ~390 transfer tasks of >= 10 GB from the APS, per facility.
//! The rate includes transfer-task queue time (API request -> completion),
//! so it sits below raw end-to-end bandwidth.

use crate::experiments::common::print_table;
use crate::service::models::Direction;
use crate::site::platform::{TransferBackend, XferStatus};
use crate::substrates::globus::SimTransfer;
use crate::util::rng::Pcg;
use crate::util::stats::Summary;

pub struct RouteRates {
    pub fac: String,
    pub mbps: Summary,
}

/// Sample `n_tasks` >=10 GB transfer tasks per facility and compute
/// effective rates (task submit -> completion, queueing included).
pub fn measure(n_tasks: usize, seed: u64) -> Vec<RouteRates> {
    let mut out = Vec::new();
    for fac in ["theta", "summit", "cori"] {
        let mut g = SimTransfer::new(seed + fac.len() as u64);
        // Fig 5 was measured during the XPCS campaign.
        g.net.bw_scale = crate::substrates::facility::XPCS_CAMPAIGN_BW_SCALE;
        let mut rng = Pcg::seeded(seed ^ 0x515);
        let mut pending = Vec::new();
        let mut t = 0.0;
        // Keep up to 5 tasks in flight like a busy site transfer module.
        let mut submitted = 0;
        let mut rates = Summary::new();
        while rates.count() < n_tasks as u64 {
            while pending.len() < 5 && submitted < n_tasks * 2 {
                let gb = rng.uniform(10.0, 25.0);
                let bytes = (gb * 1e9) as u64;
                let files = rng.below(24) as usize + 8;
                let id = g.submit(t, "APS", fac, Direction::In, bytes, files);
                pending.push((id, t, bytes));
                submitted += 1;
            }
            t += 2.0;
            pending.retain(|&(id, t0, bytes)| match g.poll(t, id) {
                XferStatus::Done => {
                    rates.add(bytes as f64 / 1e6 / (t - t0));
                    false
                }
                _ => true,
            });
            if t > 1e6 {
                break;
            }
        }
        out.push(RouteRates { fac: fac.to_string(), mbps: rates });
    }
    out
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let n = if fast { 40 } else { 130 }; // 130 x 3 facilities ≈ paper's 390
    let rates = measure(n, seed);
    let rows: Vec<Vec<String>> = rates
        .iter()
        .map(|r| {
            let (q1, q2, q3) = r.mbps.quartiles();
            vec![
                r.fac.clone(),
                format!("{}", r.mbps.count()),
                format!("{q1:.0}"),
                format!("{q2:.0}"),
                format!("{q3:.0}"),
            ]
        })
        .collect();
    print_table(
        "Fig 5: effective APS->facility Globus rates over >=10 GB tasks (MB/s)",
        &["facility", "tasks", "q1", "median", "q3"],
        &rows,
    );
    println!("paper shape: theta markedly slower than summit/cori; cori fastest");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_ordering_matches_paper() {
        let rates = measure(25, 3);
        let med = |f: &str| {
            rates.iter().find(|r| r.fac == f).unwrap().mbps.percentile(50.0)
        };
        assert!(med("theta") < med("summit"), "theta {} !< summit {}", med("theta"), med("summit"));
        assert!(med("summit") < med("cori"), "summit {} !< cori {}", med("summit"), med("cori"));
        // Magnitudes are ~100s of MB/s, not KB/s or GB/s.
        assert!(med("theta") > 20.0 && med("cori") < 2000.0);
    }
}
