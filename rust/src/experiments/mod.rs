//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§4) on the simulated federation. See DESIGN.md §5 for the
//! experiment index. Each module prints the same rows/series the paper
//! reports; `fast` mode shrinks workload counts (used by tests/benches —
//! shapes still hold, error bars are wider).

pub mod common;
pub mod table1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig11;
pub mod fig12;

/// All experiment ids, in paper order.
pub const ALL: [&str; 10] =
    ["table1", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig11", "fig12"];

/// Run one experiment by id ("fig9"), or "all".
pub fn run(id: &str, fast: bool, seed: u64) -> crate::Result<()> {
    match id {
        "table1" => table1::run(fast, seed),
        "fig3" => fig3::run(fast, seed),
        "fig4" => fig4::run(fast, seed),
        "fig5" => fig5::run(fast, seed),
        "fig6" => fig6::run(fast, seed),
        "fig7" => fig7::run(fast, seed),
        "fig8" => fig8::run(fast, seed),
        "fig9" | "fig10" => fig9::run(fast, seed),
        "fig11" => fig11::run(fast, seed),
        "fig12" | "fig13" | "fig14" => fig12::run(fast, seed),
        "all" => {
            for id in ALL {
                run(id, fast, seed)?;
            }
            Ok(())
        }
        other => crate::bail!("unknown experiment '{other}' (try one of {ALL:?} or 'all')"),
    }
}
