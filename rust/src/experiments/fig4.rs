//! Fig. 4: latency histograms for the 200 MB MD benchmark through three
//! pipelines — Cobalt batch queuing (Theta local), Slurm batch queuing
//! (Cori local), and the APS↔Theta Balsam pipeline.
//!
//! Expected shape: local staging is 1–3 orders of magnitude faster than
//! WAN staging; Cobalt queueing (median ~273 s) dwarfs everything; Slurm
//! queueing is seconds; Balsam replaces queueing with a small Run Delay.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, LocalBaseline};
use crate::metrics::{job_table, stage_durations};
use crate::sim::Actor;
use crate::util::stats::{Histogram, Summary};
use crate::world::World;

pub struct PipelineStats {
    pub label: String,
    pub queueing: Summary,
    pub stage_in: Summary,
    pub run: Summary,
    pub stage_out: Summary,
}

/// Local pipeline (Cobalt on theta / Slurm on cori).
pub fn local_stats(fac: &str, n_jobs: usize, horizon: f64, seed: u64) -> PipelineStats {
    let mut world = World::standard(seed, 32);
    let mut bl = LocalBaseline::new(fac, "md_small", 48, seed);
    bl.max_jobs = n_jobs;
    let mut t = 0.0;
    while t < horizon {
        t = bl.wake(t, &mut world);
    }
    let mut s = PipelineStats {
        label: format!("{fac} local"),
        queueing: Summary::new(),
        stage_in: Summary::new(),
        run: Summary::new(),
        stage_out: Summary::new(),
    };
    // The baseline job script is stage+run+stage; reconstruct components
    // from the same model it sampled (bandwidth is deterministic).
    let stage = 0.4 + 200_000_000.0 / 1.8e9;
    for (_, delay, wall, _, _) in &bl.completed {
        s.queueing.add(*delay);
        s.stage_in.add(stage);
        s.run.add(wall - 2.0 * stage);
        s.stage_out.add(0.4 + 40_000.0 / 1.8e9);
    }
    s
}

/// Balsam APS↔Theta pipeline.
pub fn balsam_stats(n_jobs: usize, horizon: f64, seed: u64) -> PipelineStats {
    let mut d = deploy(seed, &["theta"], 32, |c| {
        c.elastic.block_nodes = 32;
        c.elastic.max_nodes = 32;
        c.elastic.wall_time_s = horizon * 2.0;
    });
    let site = d.sites["theta"];
    let client = WorkloadClient::new(
        d.token.clone(),
        "APS",
        "MD",
        "md_small",
        Strategy::Single(site),
        Submission::Bursts { batch: 8, period: 4.0 }, // 2 jobs/s
        seed,
    )
    .with_max_jobs(n_jobs);
    d.add_client(client);
    d.run_until(horizon);
    let jobs = job_table(d.svc());
    let durs = stage_durations(&d.svc().store.events(), &jobs);
    let mut s = PipelineStats {
        label: "APS<->theta Balsam".into(),
        queueing: Summary::new(), // pilot jobs: no per-task queueing
        stage_in: Summary::new(),
        run: Summary::new(),
        stage_out: Summary::new(),
    };
    for d in durs.values() {
        if let Some(x) = d.run_delay {
            s.queueing.add(x); // "Run Delay" plays the queueing role
        }
        if let Some(x) = d.stage_in {
            s.stage_in.add(x);
        }
        if let Some(x) = d.run {
            s.run.add(x);
        }
        if let Some(x) = d.stage_out {
            s.stage_out.add(x);
        }
    }
    s
}

fn print_pipeline(s: &PipelineStats) {
    println!("\n-- {} --", s.label);
    for (name, sum) in [
        ("Queueing/RunDelay", &s.queueing),
        ("Stage In", &s.stage_in),
        ("Run", &s.run),
        ("Stage Out", &s.stage_out),
    ] {
        if sum.count() == 0 {
            continue;
        }
        println!("{name:>18}: {}  [n={}]", sum.table_cell(), sum.count());
        let hi = (sum.max() * 1.1).max(1.0);
        let mut h = Histogram::new(0.0, hi, 12);
        for &x in sum.samples() {
            h.add(x);
        }
        print!("{}", h.ascii(40));
    }
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let (n, horizon) = if fast { (80, 900.0) } else { (400, 3000.0) };
    println!("\n== Fig 4: stage-latency histograms, 200 MB MD benchmark ==");
    let cobalt = local_stats("theta", n, horizon, seed);
    let slurm = local_stats("cori", n, horizon, seed + 1);
    let balsam = balsam_stats(n, horizon, seed + 2);
    print_pipeline(&cobalt);
    print_pipeline(&slurm);
    print_pipeline(&balsam);
    println!(
        "\nshape checks: cobalt queue median {:.0}s (paper 273), slurm {:.1}s (paper 2.7), \
         balsam run-delay median {:.1}s; local stage-in {:.2}s vs balsam WAN {:.1}s",
        cobalt.queueing.percentile(50.0),
        slurm.queueing.percentile(50.0),
        balsam.queueing.percentile(50.0),
        slurm.stage_in.percentile(50.0),
        balsam.stage_in.percentile(50.0),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_holds() {
        let cobalt = local_stats("theta", 50, 900.0, 11);
        let slurm = local_stats("cori", 50, 600.0, 12);
        let balsam = balsam_stats(40, 700.0, 13);
        // Cobalt median queueing in the hundreds of seconds.
        assert!(cobalt.queueing.percentile(50.0) > 80.0);
        // Slurm queueing in seconds.
        assert!(slurm.queueing.percentile(50.0) < 15.0);
        // Balsam "queueing" (run delay) also small.
        assert!(balsam.queueing.percentile(50.0) < 30.0);
        // Local staging 1-3 orders faster than Balsam WAN staging.
        assert!(balsam.stage_in.mean() > 10.0 * slurm.stage_in.mean());
    }
}
