//! Fig. 11: weak scaling of XPCS throughput with launcher size on Theta,
//! WAN transfers removed (datasets read from local storage): 64 -> 512
//! nodes, ~2 jobs per node, mpi pilot mode.
//!
//! Expected shape: ≥ ~90% weak-scaling efficiency at 512 nodes.

use crate::client::{Strategy, Submission, WorkloadClient};
use crate::experiments::common::{deploy, print_table};
use crate::metrics::state_timeline;
use crate::service::models::JobState;

pub const NODE_COUNTS: [u32; 4] = [64, 128, 256, 512];

/// Completion rate (jobs/s) for 2 jobs/node with no WAN staging.
pub fn rate_at(nodes: u32, seed: u64) -> f64 {
    let n_jobs = (2 * nodes) as usize;
    let mut d = deploy(seed, &["theta"], nodes, |c| {
        c.elastic.block_nodes = nodes;
        c.elastic.max_nodes = nodes;
        c.elastic.wall_time_s = 3.0 * 3600.0;
    });
    let site = d.sites["theta"];
    // Datasets on local storage: the "local" endpoint stages over the
    // intra-facility route (parallel filesystem), effectively removing the
    // WAN from the pipeline.
    let client = WorkloadClient::new(
        d.token.clone(),
        "local",
        "EigenCorr",
        "xpcs",
        Strategy::Single(site),
        Submission::Bursts { batch: n_jobs, period: 1e9 },
        seed,
    )
    .with_max_jobs(n_jobs);
    d.add_client(client);
    d.run_until(3.0 * 3600.0);
    let tl = state_timeline(&d.svc().store.events(), site, JobState::JobFinished);
    assert_eq!(tl.count(), n_jobs, "all local jobs must complete ({} did)", tl.count());
    let end = tl.curve(3.0 * 3600.0, 10000).iter().find(|(_, c)| *c == n_jobs).unwrap().0;
    n_jobs as f64 / end
}

pub fn run(fast: bool, seed: u64) -> crate::Result<()> {
    let counts: &[u32] = if fast { &[64, 256] } else { &NODE_COUNTS };
    let base_nodes = counts[0];
    let base = rate_at(base_nodes, seed);
    let mut rows = Vec::new();
    for &n in counts {
        let r = if n == base_nodes { base } else { rate_at(n, seed + n as u64) };
        let ideal = base * n as f64 / base_nodes as f64;
        rows.push(vec![
            n.to_string(),
            format!("{:.2}", r),
            format!("{:.0}%", 100.0 * r / ideal),
        ]);
    }
    print_table(
        "Fig 11: XPCS weak scaling on Theta without WAN staging (mpi pilot mode)",
        &["nodes", "jobs/s", "efficiency"],
        &rows,
    );
    println!("paper shape: ~90% efficiency from 64 to 512 nodes");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weak_scaling_efficiency_above_85_percent() {
        let r64 = rate_at(64, 31);
        let r256 = rate_at(256, 32);
        let eff = r256 / (r64 * 4.0);
        assert!(eff > 0.85, "weak-scaling efficiency {eff} below paper's ~0.90");
    }
}
