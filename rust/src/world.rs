//! The simulated world: service + every facility substrate, owned in one
//! place so single-threaded discrete-event runs are deterministic.
//!
//! Site-agent actors destructure the world into disjoint `&mut` borrows
//! (service connection, transfer fabric, per-facility scheduler, executor)
//! and hand them to the platform-interface-typed module code.

use std::collections::BTreeMap;

use crate::service::api::{ApiConn, ApiError, ApiRequest, ApiResponse};
use crate::service::ServiceCore;
use crate::site::platform::{ExecBackend, RunId, RunStatus};
use crate::substrates::batchsim::BatchSim;
use crate::substrates::facility::{self, APP_STARTUP_OVERHEAD};
use crate::substrates::globus::SimTransfer;
use crate::util::rng::Pcg;

/// Simulated application executor (the AppRun platform interface in
/// simulated mode): completion times sampled from the calibrated runtime
/// model; failure injection via `fail_prob`.
pub struct SimExec {
    runs: BTreeMap<RunId, (f64, bool)>, // id -> (done_t, ok)
    next_id: u64,
    rng: Pcg,
    pub fail_prob: f64,
}

impl SimExec {
    pub fn new(seed: u64) -> SimExec {
        SimExec { runs: BTreeMap::new(), next_id: 0, rng: Pcg::seeded(seed ^ 0xeeec), fail_prob: 0.0 }
    }
}

impl ExecBackend for SimExec {
    fn start(&mut self, now: f64, fac: &str, workload: &str, _num_nodes: u32) -> RunId {
        let (mean, sd) = facility::runtime_model(fac, workload);
        let startup = self.rng.uniform(APP_STARTUP_OVERHEAD.0, APP_STARTUP_OVERHEAD.1);
        let dur = (mean + sd * self.rng.normal()).max(0.3 * mean);
        let ok = !self.rng.chance(self.fail_prob);
        self.next_id += 1;
        let id = RunId(self.next_id);
        self.runs.insert(id, (now + startup + dur, ok));
        id
    }

    fn poll(&mut self, now: f64, id: RunId) -> RunStatus {
        match self.runs.get(&id) {
            Some(&(done_t, ok)) if now >= done_t => RunStatus::Done { ok },
            Some(_) => RunStatus::Running,
            None => RunStatus::Done { ok: false },
        }
    }

    fn kill(&mut self, _now: f64, id: RunId) {
        self.runs.remove(&id);
    }
}

/// Everything the simulation owns.
pub struct World {
    pub now: f64,
    pub service: ServiceCore,
    /// Shared Globus + WAN fabric (routes/limits are global, §4.5).
    pub xfer: SimTransfer,
    /// Per-facility batch schedulers.
    pub scheds: BTreeMap<String, BatchSim>,
    /// Per-facility executors.
    pub execs: BTreeMap<String, SimExec>,
    pub rng: Pcg,
}

impl World {
    /// Standard three-supercomputer world with `reserved_nodes` exclusive
    /// reservations at each facility (paper §4.1.2).
    pub fn standard(seed: u64, reserved_nodes: u32) -> World {
        let mut scheds = BTreeMap::new();
        let mut execs = BTreeMap::new();
        for (i, fac) in ["theta", "summit", "cori"].iter().enumerate() {
            scheds.insert(fac.to_string(), BatchSim::new(fac, reserved_nodes, seed + 11 * i as u64));
            execs.insert(fac.to_string(), SimExec::new(seed + 101 * i as u64));
        }
        World {
            now: 0.0,
            service: ServiceCore::new(b"sim-secret"),
            xfer: SimTransfer::new(seed ^ 0xf10e),
            scheds,
            execs,
            rng: Pcg::seeded(seed),
        }
    }

    /// Minimal world for unit tests (no facilities registered).
    pub fn for_tests() -> World {
        World {
            now: 0.0,
            service: ServiceCore::new(b"test-secret"),
            xfer: SimTransfer::new(7),
            scheds: BTreeMap::new(),
            execs: BTreeMap::new(),
            rng: Pcg::seeded(7),
        }
    }

    /// In-process API connection at the current simulated time.
    pub fn conn(&mut self) -> InProcConn<'_> {
        InProcConn { now: self.now, svc: &mut self.service }
    }
}

/// In-process [`ApiConn`]: the simulated-mode transport (zero-latency; the
/// real-latency path is exercised by the HTTP gateway in real-time mode).
pub struct InProcConn<'a> {
    pub now: f64,
    pub svc: &'a mut ServiceCore,
}

impl ApiConn for InProcConn<'_> {
    fn api(&mut self, token: &str, req: ApiRequest) -> Result<ApiResponse, ApiError> {
        self.svc.handle(self.now, token, req)
    }
}
