//! Light-source clients: the experiment-side workload generators.
//!
//! Reproduces the three submission protocols of the evaluation:
//!
//! * **constant rate** — jobs/second, optionally in bursts of `batch`
//!   every `period` (Fig. 7 phases, §4.6's 16-jobs-per-8 s bursts);
//! * **steady backlog** — throttle submission to hold each site's
//!   pre-running backlog near a target (Figs. 3/9);
//! * and the two *distribution strategies* of §4.6: **round-robin** and
//!   adaptive **shortest-backlog** routing via the Backlog API.
//!
//! Result delivery is push-first: a [`ResultSubscription`] holds a
//! `WatchEvents` cursor scoped to one owned site and dispatches terminal
//! job states into per-job completion callbacks in one long-poll round
//! trip, demoting the old result poll to a drift-free fallback heartbeat.
//! [`ExperimentClient`] bundles a submission stream with one subscription
//! per routed site — the beamline edge of the paper's end-to-end
//! real-time path (scenario suite: `tests/scenario_realtime.rs`).

use std::collections::BTreeMap;

use crate::service::api::{ApiConn, ApiError, ApiRequest, JobCreate, JobFilter};
use crate::service::models::{Event, JobId, JobState, SiteId};
use crate::sim::Actor;
use crate::site::watch::EventWatcher;
use crate::substrates::facility::payload_bytes;
use crate::util::rng::Pcg;
use crate::world::{InProcConn, World};

/// How jobs are mapped onto sites (paper §4.6).
#[derive(Debug, Clone)]
pub enum Strategy {
    /// All jobs to one site.
    Single(SiteId),
    /// Evenly alternate among sites.
    RoundRobin(Vec<SiteId>),
    /// Adaptively route each batch to the site with the smallest pending
    /// workload (polled via the Backlog API).
    ShortestBacklog(Vec<SiteId>),
}

/// When jobs are injected.
#[derive(Debug, Clone)]
pub enum Submission {
    /// `batch` jobs every `period` seconds (constant average rate).
    Bursts { batch: usize, period: f64 },
    /// Keep each site's pre-running backlog near `target`.
    SteadyBacklog { target: usize, period: f64 },
}

/// A light-source client (APS or ALS).
///
/// All API traffic goes through the [`ApiConn`] handed to [`Self::tick`]:
/// in-process in simulated mode, a persistent keep-alive
/// [`crate::service::http_gw::HttpConn`] in real-time mode — a client
/// instance should be driven with ONE connection for its lifetime so the
/// whole submission stream (including the per-batch Backlog polls of the
/// shortest-backlog strategy) rides a single authenticated TCP stream.
pub struct WorkloadClient {
    pub token: String,
    /// Light source endpoint name ("APS" | "ALS").
    pub source: String,
    pub app: String,
    /// Workload class; "md_mix" draws small/large uniformly (Fig. 3 right).
    pub workload: String,
    pub strategy: Strategy,
    pub submission: Submission,
    /// Stop after this many jobs (0 = unlimited).
    pub max_jobs: usize,
    pub submitted: usize,
    pub created: Vec<JobId>,
    /// Per-site submitted counts, aligned with strategy site order
    /// (Fig. 13 diagnostics).
    pub per_site: Vec<(SiteId, usize)>,
    rr_idx: usize,
    next_due: f64,
    rng: Pcg,
    /// Honored `Retry-After`: ticks before this time are silent no-ops
    /// (absolute, includes jitter). A throttled burst is deferred, never
    /// dropped.
    pub backoff_until: f64,
    /// API calls answered 429/503 (diagnostics).
    pub throttled: u64,
    /// Deterministic per-client spread for backoff jitter (from the seed,
    /// like the launcher's `local_alloc_id % 97` and the watcher's
    /// `cursor % 83`) so a fleet of throttled clients does not re-arrive
    /// in one synchronized wave.
    jitter_salt: u64,
}

impl WorkloadClient {
    pub fn new(
        token: String,
        source: &str,
        app: &str,
        workload: &str,
        strategy: Strategy,
        submission: Submission,
        seed: u64,
    ) -> WorkloadClient {
        let sites = match &strategy {
            Strategy::Single(s) => vec![*s],
            Strategy::RoundRobin(v) | Strategy::ShortestBacklog(v) => v.clone(),
        };
        WorkloadClient {
            token,
            source: source.to_string(),
            app: app.to_string(),
            workload: workload.to_string(),
            strategy,
            submission,
            max_jobs: 0,
            submitted: 0,
            created: Vec::new(),
            per_site: sites.into_iter().map(|s| (s, 0)).collect(),
            rr_idx: 0,
            next_due: 0.0,
            rng: Pcg::seeded(seed ^ 0xc11e),
            backoff_until: 0.0,
            throttled: 0,
            jitter_salt: seed,
        }
    }

    pub fn with_max_jobs(mut self, n: usize) -> Self {
        self.max_jobs = n;
        self
    }

    fn make_job(&mut self, site: SiteId) -> JobCreate {
        let workload = if self.workload == "md_mix" {
            if self.rng.chance(0.5) { "md_small" } else { "md_large" }
        } else {
            &self.workload
        }
        .to_string();
        let mut jc = JobCreate::simple(site, &self.app, &workload);
        // Source "local" = datasets already on the facility filesystem
        // (paper Fig. 11: "input datasets are read directly from local HPC
        // storage") — no transfer items at all.
        if self.source != "local" {
            let (inb, outb) = payload_bytes(&workload);
            jc.transfers_in = vec![(self.source.clone(), inb)];
            jc.transfers_out = vec![(self.source.clone(), outb)];
        }
        jc.tags = vec![("source".into(), self.source.clone())];
        jc
    }

    /// Arm the `Retry-After` cooldown, matching the site modules' jitter
    /// shape: the hinted window plus up to half of it again, spread
    /// deterministically per client.
    fn note_backpressure(&mut self, now: f64, retry_after_s: u64) {
        self.throttled += 1;
        let base = retry_after_s as f64;
        let jitter = (self.jitter_salt % 89) as f64 / 89.0 * base * 0.5;
        self.backoff_until = self.backoff_until.max(now + base + jitter);
    }

    fn pick_site(&mut self, conn: &mut dyn ApiConn, now: f64) -> SiteId {
        match self.strategy.clone() {
            Strategy::Single(s) => s,
            Strategy::RoundRobin(sites) => {
                let s = sites[self.rr_idx % sites.len()];
                self.rr_idx += 1;
                s
            }
            Strategy::ShortestBacklog(sites) => {
                let mut best = sites[0];
                let mut best_backlog = usize::MAX;
                for &s in &sites {
                    let b = match conn.api(&self.token, ApiRequest::SiteBacklog { site: s }) {
                        Ok(r) => r.backlog().backlog_jobs,
                        Err(ApiError::Backpressure { retry_after_s }) => {
                            self.note_backpressure(now, retry_after_s);
                            usize::MAX
                        }
                        Err(_) => usize::MAX,
                    };
                    if b < best_backlog {
                        best_backlog = b;
                        best = s;
                    }
                }
                best
            }
        }
    }

    /// Returns `false` when the service throttled the submission (the
    /// burst is deferred to after the cooldown, not dropped).
    fn submit_batch(&mut self, conn: &mut dyn ApiConn, site: SiteId, n: usize, now: f64) -> bool {
        if n == 0 {
            return true;
        }
        let jobs: Vec<JobCreate> = (0..n).map(|_| self.make_job(site)).collect();
        match conn.api(&self.token, ApiRequest::BulkCreateJobs { jobs }) {
            Ok(resp) => {
                let ids = resp.job_ids();
                self.submitted += ids.len();
                if let Some(entry) = self.per_site.iter_mut().find(|(s, _)| *s == site) {
                    entry.1 += ids.len();
                }
                self.created.extend(ids);
                true
            }
            Err(ApiError::Backpressure { retry_after_s }) => {
                self.note_backpressure(now, retry_after_s);
                false
            }
            // Other transient errors: the burst is skipped (pre-existing
            // behavior); the next trigger fires on schedule.
            Err(_) => true,
        }
    }

    fn budget(&self, want: usize) -> usize {
        if self.max_jobs == 0 {
            want
        } else {
            want.min(self.max_jobs.saturating_sub(self.submitted))
        }
    }

    /// One client step; returns next wake time. A tick inside an armed
    /// `Retry-After` window sends nothing at all; a tick whose submission
    /// is answered 429/503 arms the window and leaves `next_due` in
    /// place, so the deferred burst fires right after the cooldown
    /// instead of being dropped (or hammering the hinted window).
    pub fn tick(&mut self, now: f64, conn: &mut dyn ApiConn) -> f64 {
        if now < self.backoff_until {
            return self.backoff_until.max(self.next_due);
        }
        if now < self.next_due {
            return self.next_due;
        }
        match self.submission.clone() {
            Submission::Bursts { batch, period } => {
                let n = self.budget(batch);
                if n > 0 {
                    let site = self.pick_site(conn, now);
                    if !self.submit_batch(conn, site, n, now) {
                        return self.backoff_until.max(self.next_due);
                    }
                }
                self.next_due = now + period;
            }
            Submission::SteadyBacklog { target, period } => {
                // Top up every site to its backlog target.
                let sites: Vec<SiteId> = self.per_site.iter().map(|(s, _)| *s).collect();
                for site in sites {
                    let backlog = match conn.api(&self.token, ApiRequest::SiteBacklog { site }) {
                        Ok(r) => r.backlog().backlog_jobs,
                        Err(ApiError::Backpressure { retry_after_s }) => {
                            self.note_backpressure(now, retry_after_s);
                            return self.backoff_until.max(self.next_due);
                        }
                        Err(_) => target,
                    };
                    let deficit = target.saturating_sub(backlog);
                    let n = self.budget(deficit);
                    if !self.submit_batch(conn, site, n, now) {
                        return self.backoff_until.max(self.next_due);
                    }
                }
                self.next_due = now + period;
            }
        }
        self.next_due
    }
}

/// Per-job completion callback: invoked exactly once with the job's
/// terminal event (`JobFinished` or `Failed`). Reconciled completions —
/// delivered by the fallback list instead of the push channel — carry a
/// synthetic event with `seq == 0`.
pub type OnResult = Box<dyn FnMut(JobId, &Event) + Send>;

/// Client-side push subscription: the experiment half of `WatchEvents`.
///
/// One subscription holds a credit-paged cursor over one owned site's
/// event stream (the tenant scope; `None` is the admin firehose) and a
/// set of in-flight jobs with per-job completion callbacks. Each
/// [`ResultSubscription::pump`] is one long-poll round trip: terminal
/// states for subscribed jobs dispatch into their callbacks in event
/// time, so trigger-to-result latency is one round trip instead of up to
/// one poll period — the poll survives only as a drift-free fallback
/// heartbeat (and as the one-shot reconciliation after an event-log
/// retention truncation). Backpressure is honored with jittered backoff
/// by the embedded [`EventWatcher`]; a throttled reconcile arms the same
/// cooldown.
pub struct ResultSubscription {
    /// Bearer token for all watch/list round trips.
    pub token: String,
    /// Tenant scope: the owned site whose stream this cursor pages
    /// (`None` subscribes to every site — admin diagnostics only).
    pub site: Option<SiteId>,
    /// The durable cursor (push mechanics, retention jumps, backpressure
    /// cooldown all live here — shared with the site modules).
    pub watcher: EventWatcher,
    /// Disable the watch entirely (`false` = poll-only result delivery;
    /// the scenario suite's baseline client).
    pub push: bool,
    /// Fallback list period (s). A safety net, not the latency floor —
    /// demote to huge values in pure push mode.
    pub poll_period: f64,
    /// Jobs awaiting a terminal event, each with its callback.
    pending: BTreeMap<JobId, OnResult>,
    /// Terminal states delivered so far (each job exactly once).
    pub completed: u64,
    /// Reconciling `ListJobs` sweeps performed (fallback heartbeats plus
    /// one per retention truncation). Zero in a healthy pure-push run.
    pub reconciles: u64,
    /// Drift-free fallback deadline (anchored on first pump).
    next_poll: f64,
    truncations_seen: u64,
}

impl ResultSubscription {
    pub fn new(token: String, site: Option<SiteId>, poll_period: f64) -> ResultSubscription {
        ResultSubscription {
            token,
            site,
            watcher: EventWatcher::new(),
            push: true,
            poll_period,
            pending: BTreeMap::new(),
            completed: 0,
            reconciles: 0,
            next_poll: 0.0,
            truncations_seen: 0,
        }
    }

    /// A poll-only subscription: result delivery degraded to the listing
    /// heartbeat (the pre-push client behavior, kept as the scenario
    /// suite's measured baseline).
    pub fn poll_only(token: String, site: Option<SiteId>, poll_period: f64) -> ResultSubscription {
        let mut s = ResultSubscription::new(token, site, poll_period);
        s.push = false;
        s
    }

    /// Register a job for completion delivery. The callback fires exactly
    /// once, from whichever channel observes the terminal state first.
    pub fn subscribe(&mut self, job: JobId, on_result: OnResult) {
        self.pending.insert(job, on_result);
    }

    /// Jobs still awaiting their terminal event.
    pub fn pending_jobs(&self) -> usize {
        self.pending.len()
    }

    /// The armed fallback deadline (0 until the first pump anchors it).
    pub fn next_poll(&self) -> f64 {
        self.next_poll
    }

    /// One delivery round: a long-poll watch (blocking in the gateway up
    /// to `timeout_ms` when `push`), terminal-event dispatch, then the
    /// retention/fallback reconciliation if due. Returns completions
    /// delivered. Transport errors read as an empty page — the fallback
    /// heartbeat still drives delivery when the event channel is down.
    pub fn pump(&mut self, conn: &mut dyn ApiConn, now: f64, timeout_ms: u64) -> usize {
        let evs = if self.push {
            self.watcher
                .watch(conn, &self.token, self.site, timeout_ms, now)
                .unwrap_or_default()
        } else {
            Vec::new()
        };
        let mut delivered = 0;
        for e in &evs {
            if e.to.is_terminal() {
                if let Some(mut cb) = self.pending.remove(&e.job_id) {
                    cb(e.job_id, e);
                    self.completed += 1;
                    delivered += 1;
                }
            }
        }
        // Retention gap: events in [old cursor, jumped cursor) were
        // dropped before this subscriber read them — one reconciling list
        // closes the window, then push resumes from the jumped cursor.
        if self.watcher.truncations > self.truncations_seen {
            self.truncations_seen = self.watcher.truncations;
            delivered += self.reconcile(conn, now);
        }
        // Drift-free fallback heartbeat (skipped while a Retry-After
        // cooldown is armed; grid advancement shared with the site
        // modules).
        if self.next_poll <= 0.0 {
            self.next_poll = now + self.poll_period;
        } else if now >= self.next_poll {
            if now >= self.watcher.cooldown_until && !self.pending.is_empty() {
                delivered += self.reconcile(conn, now);
            }
            self.next_poll = crate::site::advance_on_grid(self.next_poll, now, self.poll_period);
        }
        delivered
    }

    /// One reconciling sweep: list terminal jobs in scope and complete
    /// any still pending (synthetic event, `seq == 0`). A throttled list
    /// arms the watcher's jittered cooldown, like every other module.
    fn reconcile(&mut self, conn: &mut dyn ApiConn, now: f64) -> usize {
        self.reconciles += 1;
        let filter = JobFilter {
            site: self.site,
            states: vec![JobState::JobFinished, JobState::Failed],
            ..JobFilter::default()
        };
        let jobs = match conn.api(&self.token, ApiRequest::ListJobs { filter }) {
            Ok(resp) => resp.jobs(),
            Err(ApiError::Backpressure { retry_after_s }) => {
                self.watcher.throttled += 1;
                let base = retry_after_s as f64;
                let jitter = (self.watcher.cursor % 83) as f64 / 83.0 * base * 0.5;
                self.watcher.cooldown_until = self.watcher.cooldown_until.max(now + base + jitter);
                return 0;
            }
            Err(_) => return 0,
        };
        let mut delivered = 0;
        for j in jobs {
            if let Some(mut cb) = self.pending.remove(&j.id) {
                let ev = Event {
                    seq: 0,
                    job_id: j.id,
                    site_id: j.site_id,
                    ts: now,
                    from: j.state,
                    to: j.state,
                    data: "reconciled".into(),
                };
                cb(j.id, &ev);
                self.completed += 1;
                delivered += 1;
            }
        }
        delivered
    }
}

/// A beamline experiment client: a [`WorkloadClient`] submission stream
/// plus one [`ResultSubscription`] per routed site, so every submitted
/// job's terminal state comes back as a push callback (paper §4.6's
/// APS/ALS clients, end-to-end).
pub struct ExperimentClient {
    pub client: WorkloadClient,
    /// One subscription per site, aligned with `client.per_site` order.
    pub subs: Vec<ResultSubscription>,
}

impl ExperimentClient {
    /// Wrap a submission stream; `fallback_poll_s` is each subscription's
    /// reconcile heartbeat (1e9 effectively disables it — pure push).
    pub fn new(client: WorkloadClient, fallback_poll_s: f64) -> ExperimentClient {
        let subs = client
            .per_site
            .iter()
            .map(|(s, _)| {
                ResultSubscription::new(client.token.clone(), Some(*s), fallback_poll_s)
            })
            .collect();
        ExperimentClient { client, subs }
    }

    /// One submission tick; every newly created job is subscribed for
    /// completion with a callback built by `mk`. Jobs are attributed to
    /// sites by the per-site submission deltas (the submission loop fills
    /// sites in `per_site` order, so deltas chunk `created` in order).
    pub fn tick(
        &mut self,
        now: f64,
        conn: &mut dyn ApiConn,
        mk: &mut dyn FnMut(JobId) -> OnResult,
    ) -> f64 {
        let before_counts: Vec<usize> = self.client.per_site.iter().map(|(_, n)| *n).collect();
        let before_len = self.client.created.len();
        let next = self.client.tick(now, conn);
        let new = &self.client.created[before_len..];
        let mut off = 0;
        for (i, (_, after)) in self.client.per_site.iter().enumerate() {
            let delta = after - before_counts[i];
            for &job in &new[off..off + delta] {
                self.subs[i].subscribe(job, mk(job));
            }
            off += delta;
        }
        next
    }

    /// One delivery round across all subscriptions: the `timeout_ms`
    /// budget is split over the sites that still await results (idle
    /// sites are skipped), so a single-threaded driver stays within one
    /// budget per loop regardless of fan-out.
    pub fn pump(&mut self, now: f64, conn: &mut dyn ApiConn, timeout_ms: u64) -> usize {
        let active = self.subs.iter().filter(|s| s.pending_jobs() > 0).count();
        if active == 0 {
            return 0;
        }
        let slice = timeout_ms / active as u64;
        let mut delivered = 0;
        for sub in &mut self.subs {
            if sub.pending_jobs() > 0 {
                delivered += sub.pump(conn, now, slice);
            }
        }
        delivered
    }

    /// Jobs submitted but not yet completed.
    pub fn pending_results(&self) -> usize {
        self.subs.iter().map(|s| s.pending_jobs()).sum()
    }

    /// Terminal states delivered across all subscriptions.
    pub fn completed(&self) -> u64 {
        self.subs.iter().map(|s| s.completed).sum()
    }
}

/// Discrete-event wrapper for clients.
pub struct ClientActor {
    pub client: WorkloadClient,
}

impl Actor for ClientActor {
    fn name(&self) -> String {
        format!("client:{}", self.client.source)
    }

    fn wake(&mut self, now: f64, world: &mut World) -> f64 {
        let mut conn = InProcConn { now, svc: &mut world.service };
        self.client.tick(now, &mut conn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::ServiceCore;

    fn setup(n_sites: usize) -> (ServiceCore, String, Vec<SiteId>) {
        let svc = ServiceCore::new(b"k");
        let tok = svc.admin_token();
        let mut sites = Vec::new();
        for name in ["theta", "summit", "cori"].iter().take(n_sites) {
            let site = svc
                .handle(0.0, &tok, ApiRequest::CreateSite {
                    name: name.to_string(),
                    hostname: "h".into(),
                    path: "/p".into(),
                })
                .unwrap()
                .site_id();
            svc.handle(0.0, &tok, ApiRequest::RegisterApp {
                site,
                name: "EigenCorr".into(),
                command_template: "corr".into(),
                parameters: vec![],
            })
            .unwrap();
            sites.push(site);
        }
        (svc, tok, sites)
    }

    #[test]
    fn bursts_submit_at_constant_rate() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 16, period: 8.0 },
            1,
        );
        for step in 0..4 {
            let t = step as f64 * 8.0;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            c.tick(t, &mut conn);
        }
        assert_eq!(c.submitted, 64); // 16 jobs / 8 s * 32 s = 2 jobs/s avg
    }

    #[test]
    fn round_robin_distributes_evenly() {
        let (mut svc, tok, sites) = setup(3);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::RoundRobin(sites.clone()),
            Submission::Bursts { batch: 1, period: 1.0 },
            2,
        );
        for step in 0..9 {
            let t = step as f64;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            c.tick(t, &mut conn);
        }
        for (_, n) in &c.per_site {
            assert_eq!(*n, 3);
        }
    }

    #[test]
    fn shortest_backlog_prefers_empty_site() {
        let (mut svc, tok, sites) = setup(2);
        // Preload site 0 with backlog.
        let jobs: Vec<JobCreate> =
            (0..10).map(|_| JobCreate::simple(sites[0], "EigenCorr", "xpcs")).collect();
        svc.handle(0.0, &tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::ShortestBacklog(sites.clone()),
            Submission::Bursts { batch: 4, period: 1.0 },
            3,
        );
        let mut conn = InProcConn { now: 0.0, svc: &mut svc };
        c.tick(0.0, &mut conn);
        assert_eq!(c.per_site[0].1, 0);
        assert_eq!(c.per_site[1].1, 4);
    }

    #[test]
    fn steady_backlog_holds_target() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::SteadyBacklog { target: 32, period: 1.0 },
            4,
        );
        {
            let mut conn = InProcConn { now: 0.0, svc: &mut svc };
            c.tick(0.0, &mut conn);
        }
        assert_eq!(c.submitted, 32);
        // Nothing consumed -> no further submission.
        let mut conn = InProcConn { now: 1.0, svc: &mut svc };
        c.tick(1.0, &mut conn);
        assert_eq!(c.submitted, 32);
    }

    #[test]
    fn max_jobs_cap_respected() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 50, period: 1.0 },
            5,
        )
        .with_max_jobs(70);
        for step in 0..5 {
            let t = step as f64;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            c.tick(t, &mut conn);
        }
        assert_eq!(c.submitted, 70);
    }

    #[test]
    fn md_mix_draws_both_sizes() {
        let (mut svc, tok, sites) = setup(1);
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site: sites[0],
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "MD",
            "md_mix",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 60, period: 1.0 },
            6,
        );
        let mut conn = InProcConn { now: 0.0, svc: &mut svc };
        c.tick(0.0, &mut conn);
        let (mut small, mut large) = (0, 0);
        for j in svc.store.jobs_snapshot() {
            match j.workload.as_str() {
                "md_small" => small += 1,
                "md_large" => large += 1,
                _ => {}
            }
        }
        assert_eq!(small + large, 60);
        assert!(small > 10 && large > 10, "mix should draw both: {small}/{large}");
    }

    use std::sync::{Arc, Mutex};

    /// Walk one no-stage-in job (created in Preprocessed) to JobFinished
    /// through legality-checked transitions, emitting the real events.
    /// The last hop is implicit: a job with no stage-out items is
    /// auto-finished by the store the moment it reaches Postprocessed.
    fn finish_job(svc: &mut ServiceCore, tok: &str, job: JobId, t: f64) {
        for to in [JobState::Running, JobState::RunDone, JobState::Postprocessed] {
            svc.handle(t, tok, ApiRequest::UpdateJobState { job, to, data: String::new() })
                .unwrap();
        }
        assert_eq!(svc.store.job(job).unwrap().state, JobState::JobFinished);
    }

    /// Answers submissions with a gateway-style 429 + Retry-After and
    /// counts every round trip that reaches the wire.
    struct ThrottledSubmitConn<'a, 'b> {
        inner: InProcConn<'a>,
        calls: &'b mut usize,
    }

    impl crate::service::api::ApiConn for ThrottledSubmitConn<'_, '_> {
        fn api(
            &mut self,
            token: &str,
            req: ApiRequest,
        ) -> Result<crate::service::api::ApiResponse, ApiError> {
            *self.calls += 1;
            if matches!(req, ApiRequest::BulkCreateJobs { .. }) {
                return Err(ApiError::Backpressure { retry_after_s: 2 });
            }
            self.inner.api(token, req)
        }
    }

    /// Satellite pin: a 429/503 on submission arms a deterministic
    /// jittered `Retry-After` window; ticks inside it send NOTHING, and
    /// the throttled burst is deferred past the window, not dropped.
    #[test]
    fn throttled_burst_is_deferred_with_jittered_backoff() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok.clone(),
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 8, period: 4.0 },
            7,
        );
        let mut calls = 0;
        {
            let inner = InProcConn { now: 0.0, svc: &mut svc };
            let mut conn = ThrottledSubmitConn { inner, calls: &mut calls };
            let next = c.tick(0.0, &mut conn);
            assert!(next >= 2.0, "wake must not precede the hinted window: {next}");
        }
        assert_eq!(c.submitted, 0);
        assert_eq!(c.throttled, 1);
        // Matching the site modules' jitter shape: window + up to half of
        // it again, spread by the client's seed.
        let expected = 2.0 + (7u64 % 89) as f64 / 89.0 * 2.0 * 0.5;
        assert!((c.backoff_until - expected).abs() < 1e-9, "got {}", c.backoff_until);
        // Inside the window: silent — zero round trips.
        {
            let inner = InProcConn { now: 1.0, svc: &mut svc };
            let mut conn = ThrottledSubmitConn { inner, calls: &mut calls };
            c.tick(1.0, &mut conn);
        }
        assert_eq!(calls, 1, "a backed-off client must stay off the wire");
        assert_eq!(c.submitted, 0);
        // Past the window: the deferred burst lands.
        let t = c.backoff_until + 0.01;
        let mut conn = InProcConn { now: t, svc: &mut svc };
        c.tick(t, &mut conn);
        assert_eq!(c.submitted, 8, "a throttled burst is deferred, never dropped");
        // Equal seeds arm identical windows; different seeds spread, so a
        // throttled fleet does not re-arrive in one synchronized wave.
        let armed = |seed: u64| {
            let mut x = WorkloadClient::new(
                "t".into(),
                "APS",
                "EigenCorr",
                "xpcs",
                Strategy::Single(sites[0]),
                Submission::Bursts { batch: 1, period: 1.0 },
                seed,
            );
            x.note_backpressure(0.0, 4);
            x.backoff_until
        };
        assert_eq!(armed(11), armed(11));
        assert_ne!(armed(11), armed(12));
    }

    /// Tentpole pin: terminal-state events dispatch into per-job
    /// callbacks via the push cursor — exactly once, with the real event,
    /// and with zero reconciling lists.
    #[test]
    fn subscription_pushes_terminal_events_into_callbacks_exactly_once() {
        let (mut svc, tok, sites) = setup(1);
        let jobs = svc
            .handle(0.0, &tok, ApiRequest::BulkCreateJobs {
                jobs: (0..2).map(|_| JobCreate::simple(sites[0], "EigenCorr", "xpcs")).collect(),
            })
            .unwrap()
            .job_ids();
        let mut sub = ResultSubscription::new(tok.clone(), Some(sites[0]), 1e9);
        let seen: Arc<Mutex<Vec<(JobId, u64, JobState)>>> = Arc::new(Mutex::new(Vec::new()));
        for &j in &jobs {
            let seen = seen.clone();
            sub.subscribe(
                j,
                Box::new(move |id, ev| seen.lock().unwrap().push((id, ev.seq, ev.to))),
            );
        }
        // Drain the creation backlog: no terminal states yet.
        {
            let mut conn = InProcConn { now: 1.0, svc: &mut svc };
            sub.pump(&mut conn, 1.0, 0);
        }
        assert_eq!(sub.completed, 0);
        assert_eq!(sub.pending_jobs(), 2);
        finish_job(&mut svc, &tok, jobs[0], 2.0);
        let delivered = {
            let mut conn = InProcConn { now: 3.0, svc: &mut svc };
            sub.pump(&mut conn, 3.0, 0)
        };
        assert_eq!(delivered, 1);
        // Re-pump at the tail: the cursor is past the terminal event.
        {
            let mut conn = InProcConn { now: 4.0, svc: &mut svc };
            sub.pump(&mut conn, 4.0, 0);
        }
        finish_job(&mut svc, &tok, jobs[1], 5.0);
        {
            let mut conn = InProcConn { now: 6.0, svc: &mut svc };
            sub.pump(&mut conn, 6.0, 0);
        }
        let got = seen.lock().unwrap().clone();
        assert_eq!(got.len(), 2, "each job completes exactly once: {got:?}");
        assert_eq!(got[0].0, jobs[0]);
        assert_eq!(got[1].0, jobs[1]);
        assert!(
            got.iter().all(|(_, seq, to)| *seq > 0 && *to == JobState::JobFinished),
            "push delivery carries the real terminal event: {got:?}"
        );
        assert_eq!(sub.reconciles, 0, "pure push needs no reconciling list");
        assert_eq!(sub.pending_jobs(), 0);
    }

    /// The demoted result poll: anchored on first pump, fires a
    /// reconciling list when due, re-aligns to the grid after a late wake
    /// (no fixed-delay drift), and delivers via a synthetic seq-0 event.
    #[test]
    fn poll_fallback_reconciles_on_a_drift_free_grid() {
        let (mut svc, tok, sites) = setup(1);
        let jobs = svc
            .handle(0.0, &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(sites[0], "EigenCorr", "xpcs")],
            })
            .unwrap()
            .job_ids();
        let mut sub = ResultSubscription::poll_only(tok.clone(), Some(sites[0]), 5.0);
        let seen: Arc<Mutex<Vec<(JobId, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        {
            let seen = seen.clone();
            sub.subscribe(
                jobs[0],
                Box::new(move |id, ev| seen.lock().unwrap().push((id, ev.seq))),
            );
        }
        finish_job(&mut svc, &tok, jobs[0], 0.5);
        // First pump anchors the heartbeat; nothing is due yet.
        {
            let mut conn = InProcConn { now: 1.0, svc: &mut svc };
            sub.pump(&mut conn, 1.0, 0);
        }
        assert_eq!(sub.reconciles, 0);
        assert!((sub.next_poll() - 6.0).abs() < 1e-9);
        // Wake 2.3 periods late: exactly one reconcile fires and the next
        // deadline re-aligns to the anchor grid, not to the wake time.
        let t = 1.0 + 5.0 * 2.3;
        let delivered = {
            let mut conn = InProcConn { now: t, svc: &mut svc };
            sub.pump(&mut conn, t, 0)
        };
        assert_eq!(delivered, 1);
        assert_eq!(sub.reconciles, 1);
        assert!((sub.next_poll() - 16.0).abs() < 1e-9, "got {}", sub.next_poll());
        let got = seen.lock().unwrap().clone();
        assert_eq!(got, vec![(jobs[0], 0)], "reconciled results carry the synthetic event");
    }

    /// A retention jump recorded by the watcher triggers exactly one
    /// reconciling list, so a terminal state inside the dropped window is
    /// still delivered (full socket-level version: integration_http.rs).
    #[test]
    fn truncation_falls_back_to_one_reconciling_list() {
        let (mut svc, tok, sites) = setup(1);
        let jobs = svc
            .handle(0.0, &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(sites[0], "EigenCorr", "xpcs")],
            })
            .unwrap()
            .job_ids();
        finish_job(&mut svc, &tok, jobs[0], 1.0);
        let mut sub = ResultSubscription::new(tok.clone(), Some(sites[0]), 1e9);
        sub.push = false; // the event channel saw the gap, not the events
        let seen: Arc<Mutex<Vec<JobId>>> = Arc::new(Mutex::new(Vec::new()));
        {
            let seen = seen.clone();
            sub.subscribe(jobs[0], Box::new(move |id, _| seen.lock().unwrap().push(id)));
        }
        // As if watch() had jumped the cursor over a truncated_before.
        sub.watcher.truncations = 1;
        {
            let mut conn = InProcConn { now: 2.0, svc: &mut svc };
            sub.pump(&mut conn, 2.0, 0);
        }
        assert_eq!(sub.reconciles, 1, "one list per retention jump");
        assert_eq!(sub.completed, 1);
        assert_eq!(*seen.lock().unwrap(), vec![jobs[0]]);
        // The jump is consumed: no further reconciling lists.
        {
            let mut conn = InProcConn { now: 3.0, svc: &mut svc };
            sub.pump(&mut conn, 3.0, 0);
        }
        assert_eq!(sub.reconciles, 1);
    }

    /// ExperimentClient attributes each newly submitted job to its routed
    /// site's subscription and drains all callbacks through one pump.
    #[test]
    fn experiment_client_subscribes_jobs_on_their_routed_site() {
        let (mut svc, tok, sites) = setup(3);
        let wc = WorkloadClient::new(
            tok.clone(),
            "local",
            "EigenCorr",
            "xpcs",
            Strategy::RoundRobin(sites.clone()),
            Submission::Bursts { batch: 1, period: 1.0 },
            9,
        );
        let mut ec = ExperimentClient::new(wc, 1e9);
        let done: Arc<Mutex<Vec<JobId>>> = Arc::new(Mutex::new(Vec::new()));
        for step in 0..6 {
            let t = step as f64;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            let done = done.clone();
            let mut mk = move |_job: JobId| -> OnResult {
                let done = done.clone();
                Box::new(move |id, _ev| done.lock().unwrap().push(id))
            };
            ec.tick(t, &mut conn, &mut mk);
        }
        assert_eq!(ec.pending_results(), 6);
        for (i, sub) in ec.subs.iter().enumerate() {
            assert_eq!(sub.site, Some(sites[i]));
            assert_eq!(sub.pending_jobs(), 2, "round-robin puts 2 of 6 jobs on site {i}");
        }
        let ids = ec.client.created.clone();
        for &id in &ids {
            finish_job(&mut svc, &tok, id, 10.0);
        }
        let delivered = {
            let mut conn = InProcConn { now: 11.0, svc: &mut svc };
            ec.pump(11.0, &mut conn, 0)
        };
        assert_eq!(delivered, 6);
        assert_eq!(ec.completed(), 6);
        assert_eq!(ec.pending_results(), 0);
        let mut got = done.lock().unwrap().clone();
        got.sort();
        let mut want = ids;
        want.sort();
        assert_eq!(got, want, "every submitted job completed exactly once");
    }
}
