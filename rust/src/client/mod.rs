//! Light-source clients: the experiment-side workload generators.
//!
//! Reproduces the three submission protocols of the evaluation:
//!
//! * **constant rate** — jobs/second, optionally in bursts of `batch`
//!   every `period` (Fig. 7 phases, §4.6's 16-jobs-per-8 s bursts);
//! * **steady backlog** — throttle submission to hold each site's
//!   pre-running backlog near a target (Figs. 3/9);
//! * and the two *distribution strategies* of §4.6: **round-robin** and
//!   adaptive **shortest-backlog** routing via the Backlog API.

use crate::service::api::{ApiConn, ApiRequest, JobCreate};
use crate::service::models::{JobId, SiteId};
use crate::sim::Actor;
use crate::substrates::facility::payload_bytes;
use crate::util::rng::Pcg;
use crate::world::{InProcConn, World};

/// How jobs are mapped onto sites (paper §4.6).
#[derive(Debug, Clone)]
pub enum Strategy {
    /// All jobs to one site.
    Single(SiteId),
    /// Evenly alternate among sites.
    RoundRobin(Vec<SiteId>),
    /// Adaptively route each batch to the site with the smallest pending
    /// workload (polled via the Backlog API).
    ShortestBacklog(Vec<SiteId>),
}

/// When jobs are injected.
#[derive(Debug, Clone)]
pub enum Submission {
    /// `batch` jobs every `period` seconds (constant average rate).
    Bursts { batch: usize, period: f64 },
    /// Keep each site's pre-running backlog near `target`.
    SteadyBacklog { target: usize, period: f64 },
}

/// A light-source client (APS or ALS).
///
/// All API traffic goes through the [`ApiConn`] handed to [`Self::tick`]:
/// in-process in simulated mode, a persistent keep-alive
/// [`crate::service::http_gw::HttpConn`] in real-time mode — a client
/// instance should be driven with ONE connection for its lifetime so the
/// whole submission stream (including the per-batch Backlog polls of the
/// shortest-backlog strategy) rides a single authenticated TCP stream.
pub struct WorkloadClient {
    pub token: String,
    /// Light source endpoint name ("APS" | "ALS").
    pub source: String,
    pub app: String,
    /// Workload class; "md_mix" draws small/large uniformly (Fig. 3 right).
    pub workload: String,
    pub strategy: Strategy,
    pub submission: Submission,
    /// Stop after this many jobs (0 = unlimited).
    pub max_jobs: usize,
    pub submitted: usize,
    pub created: Vec<JobId>,
    /// Per-site submitted counts, aligned with strategy site order
    /// (Fig. 13 diagnostics).
    pub per_site: Vec<(SiteId, usize)>,
    rr_idx: usize,
    next_due: f64,
    rng: Pcg,
}

impl WorkloadClient {
    pub fn new(
        token: String,
        source: &str,
        app: &str,
        workload: &str,
        strategy: Strategy,
        submission: Submission,
        seed: u64,
    ) -> WorkloadClient {
        let sites = match &strategy {
            Strategy::Single(s) => vec![*s],
            Strategy::RoundRobin(v) | Strategy::ShortestBacklog(v) => v.clone(),
        };
        WorkloadClient {
            token,
            source: source.to_string(),
            app: app.to_string(),
            workload: workload.to_string(),
            strategy,
            submission,
            max_jobs: 0,
            submitted: 0,
            created: Vec::new(),
            per_site: sites.into_iter().map(|s| (s, 0)).collect(),
            rr_idx: 0,
            next_due: 0.0,
            rng: Pcg::seeded(seed ^ 0xc11e),
        }
    }

    pub fn with_max_jobs(mut self, n: usize) -> Self {
        self.max_jobs = n;
        self
    }

    fn make_job(&mut self, site: SiteId) -> JobCreate {
        let workload = if self.workload == "md_mix" {
            if self.rng.chance(0.5) { "md_small" } else { "md_large" }
        } else {
            &self.workload
        }
        .to_string();
        let mut jc = JobCreate::simple(site, &self.app, &workload);
        // Source "local" = datasets already on the facility filesystem
        // (paper Fig. 11: "input datasets are read directly from local HPC
        // storage") — no transfer items at all.
        if self.source != "local" {
            let (inb, outb) = payload_bytes(&workload);
            jc.transfers_in = vec![(self.source.clone(), inb)];
            jc.transfers_out = vec![(self.source.clone(), outb)];
        }
        jc.tags = vec![("source".into(), self.source.clone())];
        jc
    }

    fn pick_site(&mut self, conn: &mut dyn ApiConn) -> SiteId {
        match &self.strategy {
            Strategy::Single(s) => *s,
            Strategy::RoundRobin(sites) => {
                let s = sites[self.rr_idx % sites.len()];
                self.rr_idx += 1;
                s
            }
            Strategy::ShortestBacklog(sites) => {
                let mut best = sites[0];
                let mut best_backlog = usize::MAX;
                for &s in sites {
                    let b = conn
                        .api(&self.token, ApiRequest::SiteBacklog { site: s })
                        .map(|r| r.backlog().backlog_jobs)
                        .unwrap_or(usize::MAX);
                    if b < best_backlog {
                        best_backlog = b;
                        best = s;
                    }
                }
                best
            }
        }
    }

    fn submit_batch(&mut self, conn: &mut dyn ApiConn, site: SiteId, n: usize) {
        if n == 0 {
            return;
        }
        let jobs: Vec<JobCreate> = (0..n).map(|_| self.make_job(site)).collect();
        if let Ok(resp) = conn.api(&self.token, ApiRequest::BulkCreateJobs { jobs }) {
            let ids = resp.job_ids();
            self.submitted += ids.len();
            if let Some(entry) = self.per_site.iter_mut().find(|(s, _)| *s == site) {
                entry.1 += ids.len();
            }
            self.created.extend(ids);
        }
    }

    fn budget(&self, want: usize) -> usize {
        if self.max_jobs == 0 {
            want
        } else {
            want.min(self.max_jobs.saturating_sub(self.submitted))
        }
    }

    /// One client step; returns next wake time.
    pub fn tick(&mut self, now: f64, conn: &mut dyn ApiConn) -> f64 {
        if now < self.next_due {
            return self.next_due;
        }
        match self.submission.clone() {
            Submission::Bursts { batch, period } => {
                let n = self.budget(batch);
                if n > 0 {
                    let site = self.pick_site(conn);
                    self.submit_batch(conn, site, n);
                }
                self.next_due = now + period;
            }
            Submission::SteadyBacklog { target, period } => {
                // Top up every site to its backlog target.
                let sites: Vec<SiteId> = self.per_site.iter().map(|(s, _)| *s).collect();
                for site in sites {
                    let backlog = conn
                        .api(&self.token, ApiRequest::SiteBacklog { site })
                        .map(|r| r.backlog().backlog_jobs)
                        .unwrap_or(target);
                    let deficit = target.saturating_sub(backlog);
                    let n = self.budget(deficit);
                    self.submit_batch(conn, site, n);
                }
                self.next_due = now + period;
            }
        }
        self.next_due
    }
}

/// Discrete-event wrapper for clients.
pub struct ClientActor {
    pub client: WorkloadClient,
}

impl Actor for ClientActor {
    fn name(&self) -> String {
        format!("client:{}", self.client.source)
    }

    fn wake(&mut self, now: f64, world: &mut World) -> f64 {
        let mut conn = InProcConn { now, svc: &mut world.service };
        self.client.tick(now, &mut conn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::ServiceCore;

    fn setup(n_sites: usize) -> (ServiceCore, String, Vec<SiteId>) {
        let svc = ServiceCore::new(b"k");
        let tok = svc.admin_token();
        let mut sites = Vec::new();
        for name in ["theta", "summit", "cori"].iter().take(n_sites) {
            let site = svc
                .handle(0.0, &tok, ApiRequest::CreateSite {
                    name: name.to_string(),
                    hostname: "h".into(),
                    path: "/p".into(),
                })
                .unwrap()
                .site_id();
            svc.handle(0.0, &tok, ApiRequest::RegisterApp {
                site,
                name: "EigenCorr".into(),
                command_template: "corr".into(),
                parameters: vec![],
            })
            .unwrap();
            sites.push(site);
        }
        (svc, tok, sites)
    }

    #[test]
    fn bursts_submit_at_constant_rate() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 16, period: 8.0 },
            1,
        );
        for step in 0..4 {
            let t = step as f64 * 8.0;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            c.tick(t, &mut conn);
        }
        assert_eq!(c.submitted, 64); // 16 jobs / 8 s * 32 s = 2 jobs/s avg
    }

    #[test]
    fn round_robin_distributes_evenly() {
        let (mut svc, tok, sites) = setup(3);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::RoundRobin(sites.clone()),
            Submission::Bursts { batch: 1, period: 1.0 },
            2,
        );
        for step in 0..9 {
            let t = step as f64;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            c.tick(t, &mut conn);
        }
        for (_, n) in &c.per_site {
            assert_eq!(*n, 3);
        }
    }

    #[test]
    fn shortest_backlog_prefers_empty_site() {
        let (mut svc, tok, sites) = setup(2);
        // Preload site 0 with backlog.
        let jobs: Vec<JobCreate> =
            (0..10).map(|_| JobCreate::simple(sites[0], "EigenCorr", "xpcs")).collect();
        svc.handle(0.0, &tok, ApiRequest::BulkCreateJobs { jobs }).unwrap();
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::ShortestBacklog(sites.clone()),
            Submission::Bursts { batch: 4, period: 1.0 },
            3,
        );
        let mut conn = InProcConn { now: 0.0, svc: &mut svc };
        c.tick(0.0, &mut conn);
        assert_eq!(c.per_site[0].1, 0);
        assert_eq!(c.per_site[1].1, 4);
    }

    #[test]
    fn steady_backlog_holds_target() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::SteadyBacklog { target: 32, period: 1.0 },
            4,
        );
        {
            let mut conn = InProcConn { now: 0.0, svc: &mut svc };
            c.tick(0.0, &mut conn);
        }
        assert_eq!(c.submitted, 32);
        // Nothing consumed -> no further submission.
        let mut conn = InProcConn { now: 1.0, svc: &mut svc };
        c.tick(1.0, &mut conn);
        assert_eq!(c.submitted, 32);
    }

    #[test]
    fn max_jobs_cap_respected() {
        let (mut svc, tok, sites) = setup(1);
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "EigenCorr",
            "xpcs",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 50, period: 1.0 },
            5,
        )
        .with_max_jobs(70);
        for step in 0..5 {
            let t = step as f64;
            let mut conn = InProcConn { now: t, svc: &mut svc };
            c.tick(t, &mut conn);
        }
        assert_eq!(c.submitted, 70);
    }

    #[test]
    fn md_mix_draws_both_sizes() {
        let (mut svc, tok, sites) = setup(1);
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site: sites[0],
            name: "MD".into(),
            command_template: "md".into(),
            parameters: vec![],
        })
        .unwrap();
        let mut c = WorkloadClient::new(
            tok,
            "APS",
            "MD",
            "md_mix",
            Strategy::Single(sites[0]),
            Submission::Bursts { batch: 60, period: 1.0 },
            6,
        );
        let mut conn = InProcConn { now: 0.0, svc: &mut svc };
        c.tick(0.0, &mut conn);
        let (mut small, mut large) = (0, 0);
        for j in svc.store.jobs_snapshot() {
            match j.workload.as_str() {
                "md_small" => small += 1,
                "md_large" => large += 1,
                _ => {}
            }
        }
        assert_eq!(small + large, 60);
        assert!(small > 10 && large > 10, "mix should draw both: {small}/{large}");
    }
}
