//! Real execution backend: the AppRun platform interface backed by actual
//! PJRT compute on the AOT artifacts (real-time mode / e2e examples).
//!
//! A dedicated worker thread owns the [`Runtime`] (PJRT handles are not
//! `Send`-safe to share) and drains a request channel; `start` enqueues,
//! `poll` observes the shared completion map. This mirrors a head-node
//! launcher farming app-runs onto compute resources.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::site::platform::{ExecBackend, RunId, RunStatus};
use crate::util::rng::Pcg;

use super::Runtime;

enum Req {
    Run { id: RunId, model: String, inputs: Vec<Vec<f32>> },
    Stop,
}

/// Outcome record kept for inspection by examples/tests.
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub model: String,
    pub ok: bool,
    /// First few values of the first output (result fingerprint).
    pub head: Vec<f32>,
    pub wall_s: f64,
}

pub struct RealExec {
    tx: mpsc::Sender<Req>,
    results: Arc<Mutex<BTreeMap<RunId, RunRecord>>>,
    inflight: Arc<Mutex<usize>>,
    next_id: u64,
    rng: Pcg,
    /// workload -> model name mapping.
    model_for: BTreeMap<String, String>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl RealExec {
    /// Spawn the worker thread; it compiles `models` from `artifacts_dir`.
    pub fn start_worker(
        artifacts_dir: std::path::PathBuf,
        models: Vec<String>,
        model_for: BTreeMap<String, String>,
    ) -> crate::Result<RealExec> {
        let (tx, rx) = mpsc::channel::<Req>();
        let results: Arc<Mutex<BTreeMap<RunId, RunRecord>>> = Arc::default();
        let inflight: Arc<Mutex<usize>> = Arc::default();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let results2 = results.clone();
        let inflight2 = inflight.clone();
        let handle = std::thread::spawn(move || {
            let names: Vec<&str> = models.iter().map(String::as_str).collect();
            let rt = match Runtime::load(&artifacts_dir, &names) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Req::Stop => break,
                    Req::Run { id, model, inputs } => {
                        let t0 = std::time::Instant::now();
                        let rec = match rt.model(&model).and_then(|m| m.run_f32(&inputs)) {
                            Ok(outs) => RunRecord {
                                model,
                                ok: outs.iter().all(|o| o.iter().all(|x| x.is_finite())),
                                head: outs.first().map(|o| o.iter().take(4).copied().collect()).unwrap_or_default(),
                                wall_s: t0.elapsed().as_secs_f64(),
                            },
                            Err(e) => {
                                eprintln!("run {model} failed: {e}");
                                RunRecord { model, ok: false, head: vec![], wall_s: t0.elapsed().as_secs_f64() }
                            }
                        };
                        results2.lock().unwrap().insert(id, rec);
                        *inflight2.lock().unwrap() -= 1;
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| crate::err!("runtime worker died"))?
            .map_err(|e| crate::err!("runtime init: {e}"))?;
        Ok(RealExec {
            tx,
            results,
            inflight,
            next_id: 0,
            rng: Pcg::seeded(0x5ea1),
            model_for,
            handle: Some(handle),
        })
    }

    /// Synthetic input generation per model family: a random symmetric
    /// matrix for MD, positive speckle-like frames for XPCS.
    fn gen_inputs(&mut self, model: &str, lens: &[usize]) -> Vec<Vec<f32>> {
        lens.iter()
            .map(|&n| {
                if model.starts_with("md") {
                    // Symmetric-ish noise; the model symmetrizes anyway.
                    (0..n).map(|_| self.rng.normal() as f32).collect()
                } else {
                    (0..n).map(|_| 1.0 + self.rng.f64() as f32).collect()
                }
            })
            .collect()
    }

    pub fn record(&self, id: RunId) -> Option<RunRecord> {
        self.results.lock().unwrap().get(&id).cloned()
    }

    pub fn completed(&self) -> usize {
        self.results.lock().unwrap().len()
    }
}

impl ExecBackend for RealExec {
    fn start(&mut self, _now: f64, _fac: &str, workload: &str, _num_nodes: u32) -> RunId {
        self.next_id += 1;
        let id = RunId(self.next_id);
        let model = self
            .model_for
            .get(workload)
            .cloned()
            .unwrap_or_else(|| self.model_for.values().next().cloned().unwrap_or_default());
        // Input lengths come from the manifest spec via the worker; we keep
        // a local copy in model_for? Simpler: worker computes; but inputs
        // must be built here. We fetch lengths lazily from a static map set
        // at construction via first use of the runtime spec — instead,
        // generate from the known artifact shapes:
        let lens: Vec<usize> = match model.as_str() {
            "md_64" => vec![64 * 64],
            "md_128" => vec![128 * 128],
            "xpcs_t64_p1024" => vec![64 * 1024],
            "xpcs_t128_p4096" => vec![128 * 4096],
            _ => vec![64 * 64],
        };
        let inputs = self.gen_inputs(&model, &lens);
        *self.inflight.lock().unwrap() += 1;
        let _ = self.tx.send(Req::Run { id, model, inputs });
        id
    }

    fn poll(&mut self, _now: f64, id: RunId) -> RunStatus {
        match self.results.lock().unwrap().get(&id) {
            Some(rec) => RunStatus::Done { ok: rec.ok },
            None => RunStatus::Running,
        }
    }

    fn kill(&mut self, _now: f64, _id: RunId) {
        // Real PJRT executions are not interruptible mid-call; the result
        // is simply discarded by the caller.
    }
}

impl Drop for RealExec {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
