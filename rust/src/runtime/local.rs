//! Real-time-mode platform backends for a single-host deployment:
//!
//! * [`LocalResources`] — a SchedulerBackend where allocations start
//!   immediately (the example host plays the role of an idle reserved
//!   partition);
//! * [`LoopbackTransfer`] — a TransferBackend that moves *actual bytes*
//!   through the filesystem on a background thread, optionally throttled
//!   to a configured bandwidth so WAN behaviour is reproduced with real
//!   I/O.
//!
//! Together with [`super::real::RealExec`] these let the identical site
//! agent code that runs in simulation drive real sockets, files, and PJRT
//! compute in the end-to-end examples.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::{Arc, Mutex};

use crate::service::models::{Direction, XferTaskId};
use crate::site::platform::{
    AllocStatus, SchedulerBackend, TransferBackend, XferStatus,
};

/// Instant-start local "scheduler" with a fixed node pool.
pub struct LocalResources {
    total: u32,
    free: u32,
    allocs: BTreeMap<u64, (u32, f64, f64)>, // id -> (nodes, start, wall)
    next_id: u64,
}

impl LocalResources {
    pub fn new(nodes: u32) -> LocalResources {
        LocalResources { total: nodes, free: nodes, allocs: BTreeMap::new(), next_id: 0 }
    }

    pub fn total_nodes(&self) -> u32 {
        self.total
    }
}

impl SchedulerBackend for LocalResources {
    fn submit(&mut self, now: f64, _fac: &str, nodes: u32, wall_s: f64) -> u64 {
        self.next_id += 1;
        let granted = nodes.min(self.free);
        self.free -= granted;
        self.allocs.insert(self.next_id, (granted, now, wall_s));
        self.next_id
    }

    fn status(&mut self, now: f64, id: u64) -> AllocStatus {
        match self.allocs.get(&id) {
            Some(&(nodes, start, wall)) => {
                if now >= start + wall {
                    self.allocs.remove(&id);
                    self.free += nodes;
                    AllocStatus::Finished
                } else {
                    AllocStatus::Running { end_by: start + wall }
                }
            }
            None => AllocStatus::Finished,
        }
    }

    fn delete(&mut self, _now: f64, id: u64) {
        if let Some((nodes, _, _)) = self.allocs.remove(&id) {
            self.free += nodes;
        }
    }

    fn release_early(&mut self, now: f64, id: u64) {
        self.delete(now, id);
    }

    fn free_nodes(&mut self, _now: f64) -> u32 {
        self.free
    }
}

/// Background-thread file transfer with optional bandwidth throttling.
pub struct LoopbackTransfer {
    dir: std::path::PathBuf,
    /// Simulated WAN bandwidth in bytes/s (None = unthrottled disk copy).
    pub throttle_bps: Option<f64>,
    done: Arc<Mutex<BTreeMap<XferTaskId, bool>>>,
    next_id: u64,
}

impl LoopbackTransfer {
    pub fn new(dir: impl Into<std::path::PathBuf>, throttle_bps: Option<f64>) -> LoopbackTransfer {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).ok();
        LoopbackTransfer { dir, throttle_bps, done: Arc::default(), next_id: 0 }
    }
}

impl TransferBackend for LoopbackTransfer {
    fn submit(
        &mut self,
        _now: f64,
        remote: &str,
        fac: &str,
        direction: Direction,
        bytes: u64,
        _nfiles: usize,
    ) -> XferTaskId {
        self.next_id += 1;
        let id = XferTaskId(self.next_id);
        self.done.lock().unwrap().insert(id, false);
        let done = self.done.clone();
        let dir = self.dir.clone();
        let throttle = self.throttle_bps;
        let tag = format!("{remote}-{fac}-{}-{}", self.next_id, if direction == Direction::In { "in" } else { "out" });
        std::thread::spawn(move || {
            // Move real bytes: write source, copy to destination in chunks,
            // sleeping per chunk if throttled.
            let src = dir.join(format!("{tag}.src"));
            let dst = dir.join(format!("{tag}.dst"));
            let chunk = 1 << 20;
            let mut remaining = bytes as usize;
            let payload = vec![0x5au8; chunk];
            if let Ok(mut f) = std::fs::File::create(&src) {
                while remaining > 0 {
                    let n = remaining.min(chunk);
                    if f.write_all(&payload[..n]).is_err() {
                        break;
                    }
                    remaining -= n;
                }
            }
            let t0 = std::time::Instant::now();
            let _ = std::fs::copy(&src, &dst);
            if let Some(bps) = throttle {
                let want = bytes as f64 / bps;
                let elapsed = t0.elapsed().as_secs_f64();
                if want > elapsed {
                    std::thread::sleep(std::time::Duration::from_secs_f64(want - elapsed));
                }
            }
            std::fs::remove_file(&src).ok();
            std::fs::remove_file(&dst).ok();
            done.lock().unwrap().insert(id, true);
        });
        id
    }

    fn poll(&mut self, _now: f64, task: XferTaskId) -> XferStatus {
        match self.done.lock().unwrap().get(&task) {
            Some(true) => XferStatus::Done,
            Some(false) => XferStatus::Active,
            None => XferStatus::Error,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_resources_account_nodes() {
        let mut r = LocalResources::new(8);
        let a = r.submit(0.0, "local", 4, 100.0);
        assert_eq!(r.free_nodes(0.0), 4);
        assert!(matches!(r.status(1.0, a), AllocStatus::Running { .. }));
        assert_eq!(r.status(101.0, a), AllocStatus::Finished);
        assert_eq!(r.free_nodes(101.0), 8);
    }

    #[test]
    fn oversubscription_grants_what_is_free() {
        let mut r = LocalResources::new(4);
        r.submit(0.0, "local", 4, 1e6);
        let b = r.submit(0.0, "local", 4, 1e6);
        // Second allocation granted 0 nodes but exists; delete restores none.
        r.delete(1.0, b);
        assert_eq!(r.free_nodes(1.0), 0);
    }

    #[test]
    fn loopback_transfer_moves_real_bytes() {
        let dir = std::env::temp_dir().join(format!("balsam-xfer-{}", std::process::id()));
        let mut x = LoopbackTransfer::new(&dir, None);
        let id = x.submit(0.0, "APS", "local", Direction::In, 2_000_000, 1);
        let t0 = std::time::Instant::now();
        while x.poll(0.0, id) != XferStatus::Done {
            assert!(t0.elapsed().as_secs() < 20, "copy never finished");
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn throttled_transfer_takes_expected_time() {
        let dir = std::env::temp_dir().join(format!("balsam-xfer-t-{}", std::process::id()));
        let mut x = LoopbackTransfer::new(&dir, Some(2_000_000.0)); // 2 MB/s
        let id = x.submit(0.0, "APS", "local", Direction::In, 1_000_000, 1);
        let t0 = std::time::Instant::now();
        while x.poll(0.0, id) != XferStatus::Done {
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert!(t0.elapsed().as_secs() < 20);
        }
        assert!(t0.elapsed().as_secs_f64() > 0.4, "throttle not applied");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_task_is_error() {
        let mut x = LoopbackTransfer::new(std::env::temp_dir(), None);
        assert_eq!(x.poll(0.0, XferTaskId(99)), XferStatus::Error);
    }
}
