//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the request path — Python is never involved.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md and /opt/xla-example/README.md).
//!
//! PJRT execution needs the native `xla` bindings, which are not on
//! crates.io — the dependency is gated behind the off-by-default `xla`
//! cargo feature (enable it with a vendored `xla` crate via a `[patch]` /
//! path dependency; see README). Without the feature everything still
//! compiles: manifest parsing works, and [`Runtime::load`] /
//! [`CompiledModel::run_f32`] return a descriptive error. Callers probe
//! [`pjrt_available`] to skip gracefully.

pub mod real;
pub mod local;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::Context;
use crate::util::json::Json;
use crate::{ensure, err, Result};

/// Shape/dtype signature of one model from `manifest.json`.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub file: String,
    /// (name, dims) per input; f32 only (all shipped models are f32).
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

impl ModelSpec {
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].1.iter().product::<usize>().max(1)
    }
}

/// Parse `artifacts/manifest.json`.
pub fn read_manifest(dir: &Path) -> Result<Vec<ModelSpec>> {
    let text = std::fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("read {}/manifest.json — run `make artifacts` first", dir.display()))?;
    let j = Json::parse(&text).map_err(|e| err!("manifest parse: {e}"))?;
    let models = j.get("models").and_then(Json::as_obj).ok_or_else(|| err!("missing models"))?;
    let mut out = Vec::new();
    for (name, m) in models {
        let io = |key: &str| -> Vec<(String, Vec<usize>)> {
            m.get(key)
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|x| {
                            let nm = x.get("name").and_then(Json::as_str).unwrap_or("").to_string();
                            let dims = x
                                .get("shape")
                                .and_then(Json::as_arr)
                                .map(|d| d.iter().filter_map(Json::as_u64).map(|v| v as usize).collect())
                                .unwrap_or_default();
                            (nm, dims)
                        })
                        .collect()
                })
                .unwrap_or_default()
        };
        out.push(ModelSpec {
            name: name.clone(),
            file: m.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
            inputs: io("inputs"),
            outputs: io("outputs"),
        });
    }
    Ok(out)
}

/// Is PJRT execution compiled in (`xla` cargo feature)?
pub fn pjrt_available() -> bool {
    cfg!(feature = "xla")
}

/// A compiled model bound to the PJRT CPU client.
pub struct CompiledModel {
    pub spec: ModelSpec,
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledModel {
    /// Execute with f32 inputs; returns the flattened f32 outputs in
    /// manifest order (models are lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "model {} expects {} inputs, got {}",
            self.spec.name,
            self.spec.inputs.len(),
            inputs.len()
        );
        for (i, data) in inputs.iter().enumerate() {
            let want = self.spec.input_len(i);
            ensure!(
                data.len() == want,
                "input {i} of {}: expected {want} elements, got {}",
                self.spec.name,
                data.len()
            );
        }
        self.execute(inputs)
    }

    #[cfg(feature = "xla")]
    fn execute(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let dims: Vec<i64> = self.spec.inputs[i].1.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data);
            let lit = if dims.is_empty() { lit } else { lit.reshape(&dims)? };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    #[cfg(not(feature = "xla"))]
    fn execute(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Err(err!(
            "model {}: balsam was built without the `xla` feature; PJRT execution unavailable",
            self.spec.name
        ))
    }
}

/// The artifact runtime: PJRT CPU client + compiled executables.
pub struct Runtime {
    #[cfg(feature = "xla")]
    #[allow(dead_code)]
    client: xla::PjRtClient,
    pub models: BTreeMap<String, CompiledModel>,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    /// Compile the named models (or all in the manifest if `names` empty).
    #[cfg(feature = "xla")]
    pub fn load(dir: impl AsRef<Path>, names: &[&str]) -> Result<Runtime> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu()?;
        let specs = read_manifest(dir)?;
        let mut models = BTreeMap::new();
        for spec in specs {
            if !names.is_empty() && !names.contains(&spec.name.as_str()) {
                continue;
            }
            let path = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| err!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            models.insert(spec.name.clone(), CompiledModel { spec, exe });
        }
        ensure!(!models.is_empty(), "no models loaded from {}", dir.display());
        Ok(Runtime { client, models, artifacts_dir: dir.to_path_buf() })
    }

    /// Without the `xla` feature, loading fails with a descriptive error
    /// (the manifest is still validated so the message is actionable).
    #[cfg(not(feature = "xla"))]
    pub fn load(dir: impl AsRef<Path>, _names: &[&str]) -> Result<Runtime> {
        let dir = dir.as_ref();
        let _ = read_manifest(dir)?;
        Err(err!(
            "balsam was built without the `xla` feature; enable it (with a vendored xla crate) \
             to execute AOT artifacts from {}",
            dir.display()
        ))
    }

    pub fn model(&self, name: &str) -> Result<&CompiledModel> {
        self.models.get(name).ok_or_else(|| err!("model {name} not loaded"))
    }
}

/// Default artifacts directory: `$BALSAM_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("BALSAM_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing_from_synthetic_doc() {
        let dir = std::env::temp_dir().join(format!("balsam-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format":"hlo-text","models":{"m":{"file":"m.hlo.txt",
                "inputs":[{"name":"a","shape":[2,3],"dtype":"f32"}],
                "outputs":[{"name":"o","shape":[2],"dtype":"f32"}]}}}"#,
        )
        .unwrap();
        let specs = read_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].inputs[0].1, vec![2, 3]);
        assert_eq!(specs[0].input_len(0), 6);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = read_manifest(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
