//! Job state machine: the legal lifecycle transitions.
//!
//! The service rejects illegal transitions (defense against buggy or
//! malicious clients — only specific edges are client/site drivable). The
//! graph mirrors the Balsam REST API state enumeration:
//!
//! ```text
//! CREATED ─► AWAITING_PARENTS ─► READY ─► STAGED_IN ─► PREPROCESSED ─► RUNNING
//!    │               │            ▲                        ▲             │
//!    └───────────────┴────────────┘     RESTART_READY ─────┘        ┌────┴────┐
//!                                            ▲  ▲                RUN_DONE  RUN_ERROR / RUN_TIMEOUT
//!                                            │  └──────────────────┼─────────┘
//!                                            │                 POSTPROCESSED ─► JOB_FINISHED
//!                                            └─ (retry budget left)        └─► FAILED
//! ```

use super::models::JobState;

/// Is `from -> to` a legal edge in the job lifecycle?
pub fn legal(from: JobState, to: JobState) -> bool {
    use JobState::*;
    matches!(
        (from, to),
        (Created, AwaitingParents)
            | (Created, Ready)
            | (Created, StagedIn)          // no stage-in items
            | (AwaitingParents, Ready)
            | (AwaitingParents, StagedIn)
            | (AwaitingParents, Failed)    // parent failed
            | (Ready, StagedIn)
            | (Ready, Failed)              // stage-in error budget exhausted
            | (StagedIn, Preprocessed)
            | (StagedIn, Failed)
            | (Preprocessed, Running)
            | (Running, RunDone)
            | (Running, RunError)
            | (Running, RunTimeout)
            | (RunDone, Postprocessed)
            | (Postprocessed, JobFinished)
            | (RunError, RestartReady)
            | (RunError, Failed)
            | (RunTimeout, RestartReady)
            | (RunTimeout, Failed)
            | (RestartReady, Running)
            | (RestartReady, Failed)
    )
}

/// All legal successor states of `from`.
pub fn successors(from: JobState) -> Vec<JobState> {
    JobState::ALL.iter().copied().filter(|&to| legal(from, to)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use JobState::*;

    #[test]
    fn happy_path_is_legal() {
        let path = [Created, Ready, StagedIn, Preprocessed, Running, RunDone, Postprocessed, JobFinished];
        for w in path.windows(2) {
            assert!(legal(w[0], w[1]), "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn fault_and_recovery_path_is_legal() {
        for w in [Running, RunTimeout, RestartReady, Running, RunError, RestartReady].windows(2) {
            assert!(legal(w[0], w[1]), "{} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn terminal_states_have_no_exits() {
        for s in [JobFinished, Failed] {
            assert!(successors(s).is_empty(), "{s} should be terminal");
        }
    }

    #[test]
    fn cannot_skip_staging() {
        assert!(!legal(Ready, Running));
        assert!(!legal(Created, Running));
        assert!(!legal(StagedIn, Running)); // must preprocess first
    }

    #[test]
    fn cannot_unfinish() {
        assert!(!legal(JobFinished, Running));
        assert!(!legal(Postprocessed, Running));
    }

    #[test]
    fn every_nonterminal_has_an_exit() {
        for s in JobState::ALL {
            if !s.is_terminal() {
                assert!(!successors(s).is_empty(), "{s} is a dead end");
            }
        }
    }

    #[test]
    fn every_state_reachable_from_created() {
        // BFS over the legal graph.
        let mut seen = std::collections::BTreeSet::new();
        let mut queue = vec![Created];
        while let Some(s) = queue.pop() {
            if seen.insert(s) {
                queue.extend(successors(s));
            }
        }
        for s in JobState::ALL {
            assert!(seen.contains(&s), "{s} unreachable");
        }
    }
}
