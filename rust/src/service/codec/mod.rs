//! Wire codec layer: one trait, two encodings.
//!
//! Serialization for [`ApiRequest`]/[`ApiResponse`] lives here, behind
//! the [`WireCodec`] trait, so the HTTP gateway ([`super::http_gw`]) is
//! codec-agnostic: it negotiates an encoding per request and dispatches.
//! Two implementations exist:
//!
//! * [`json::JsonCodec`] — the original JSON envelope
//!   (`application/json`). Default and compatibility surface: any peer
//!   that predates this module speaks it unchanged.
//! * [`frame::FrameCodec`] — a length-prefixed binary frame
//!   (`application/x-balsam-frame`) for the chatty interior paths
//!   (`SessionSync`, `SyncTransferItems`, `WatchEvents`): tag byte +
//!   varint-length fields, decoded straight off the request buffer with
//!   no intermediate tree.
//!
//! Negotiation is standard HTTP content negotiation: the request body's
//! encoding is declared by `Content-Type`, the desired response encoding
//! by `Accept`. Absent/unknown headers mean JSON, so old clients never
//! see a frame. A server with the binary codec disabled answers frame
//! requests with 415 and clients fall back to JSON permanently
//! ([`super::http_gw::HttpConn`]).
//!
//! Row/enum encodings on [`super::models`] types are *not* routed
//! through this trait: WAL and event-log segments stay JSON regardless
//! of the wire codec, so durable state never depends on a transport
//! knob.

use super::api::{ApiError, ApiRequest, ApiResponse};

pub mod frame;
pub mod json;

/// Content type of the JSON envelope encoding (the default).
pub const CT_JSON: &str = "application/json";

/// Content type of the binary frame encoding.
pub const CT_FRAME: &str = "application/x-balsam-frame";

/// One wire encoding for API envelopes. Encoders append to a
/// caller-owned buffer so per-connection scratch space is reusable;
/// decoders read from a borrowed byte slice.
pub trait WireCodec: Sync {
    /// The `Content-Type` value this codec produces and consumes.
    fn content_type(&self) -> &'static str;

    /// Serialize a request envelope into `out` (appended; callers clear).
    fn encode_request(&self, req: &ApiRequest, out: &mut Vec<u8>);

    /// Decode a request body. The error string becomes the framed 400
    /// message, exactly like a malformed-JSON body today.
    fn decode_request(&self, body: &[u8]) -> Result<ApiRequest, String>;

    /// Serialize a success envelope into `out`.
    fn encode_ok(&self, resp: &ApiResponse, out: &mut Vec<u8>);

    /// Serialize an error envelope carrying `msg` into `out`.
    fn encode_err(&self, msg: &str, out: &mut Vec<u8>);

    /// Decode a 200 body. A well-formed *error* envelope (the gateway
    /// never sends one with a 200, but transports can surprise) decodes
    /// to [`ApiError::Transport`], matching the JSON client's behavior.
    fn decode_ok(&self, body: &[u8]) -> Result<ApiResponse, ApiError>;

    /// Best-effort error-message extraction from a non-200 body
    /// (`"unknown"` when the body is not a recognizable error envelope).
    fn decode_err(&self, body: &[u8]) -> String;
}

/// A negotiated wire encoding — the two [`WireCodec`] implementations as
/// a copyable knob (CLI `--wire`, client env `BALSAM_WIRE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wire {
    /// JSON envelopes (`application/json`) — the default.
    Json,
    /// Binary frames (`application/x-balsam-frame`).
    Binary,
}

impl Wire {
    /// The codec implementation behind this knob value.
    pub fn codec(self) -> &'static dyn WireCodec {
        match self {
            Wire::Json => &json::JsonCodec,
            Wire::Binary => &frame::FrameCodec,
        }
    }

    /// The `Content-Type` this encoding travels under.
    pub fn content_type(self) -> &'static str {
        match self {
            Wire::Json => CT_JSON,
            Wire::Binary => CT_FRAME,
        }
    }

    /// Metric-label / CLI value: `"json"` or `"binary"`.
    pub fn label(self) -> &'static str {
        match self {
            Wire::Json => "json",
            Wire::Binary => "binary",
        }
    }

    /// Parse a CLI/config value (`"json"`, `"binary"`, or the alias
    /// `"frame"`); `None` for anything else.
    pub fn parse(s: &str) -> Option<Wire> {
        match s {
            "json" => Some(Wire::Json),
            "binary" | "frame" => Some(Wire::Binary),
            _ => None,
        }
    }
}

/// Client-side default from the `BALSAM_WIRE` env var: `binary` (or
/// `frame`) opts into binary frames; anything else — including unset —
/// is JSON, the compatibility surface.
pub fn wire_from_env() -> Wire {
    match std::env::var("BALSAM_WIRE").as_deref() {
        Ok("binary") | Ok("frame") => Wire::Binary,
        _ => Wire::Json,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_knob_parses_and_labels() {
        assert_eq!(Wire::parse("json"), Some(Wire::Json));
        assert_eq!(Wire::parse("binary"), Some(Wire::Binary));
        assert_eq!(Wire::parse("frame"), Some(Wire::Binary));
        assert_eq!(Wire::parse("yaml"), None);
        assert_eq!(Wire::Json.label(), "json");
        assert_eq!(Wire::Binary.label(), "binary");
        assert_eq!(Wire::Json.content_type(), CT_JSON);
        assert_eq!(Wire::Binary.content_type(), CT_FRAME);
        assert_eq!(Wire::Json.codec().content_type(), CT_JSON);
        assert_eq!(Wire::Binary.codec().content_type(), CT_FRAME);
    }
}
