//! The binary frame codec (`application/x-balsam-frame`).
//!
//! Built for the chatty interior paths — a launcher's `SessionSync`, the
//! transfer module's `SyncTransferItems`, a watcher's `WatchEvents` page
//! — where hand-rolled JSON costs a tree of `String` allocations per
//! request. Frames decode straight off the request buffer with a borrowed
//! cursor: no intermediate value tree, one allocation per owned string
//! field, `Vec` capacities bounded by the bytes actually present.
//!
//! ## Frame layout
//!
//! ```text
//! request   = 0x01  tag:u8  fields...
//! ok-resp   = 0x02  tag:u8  fields...
//! err-resp  = 0x03  msg:str
//!
//! u64/u32/usize = LEB128 varint (7 bits per byte, little-endian groups)
//! f64           = 8 bytes, IEEE-754 bits little-endian
//! bool          = 1 byte (0/1)
//! str           = varint byte-length + UTF-8 bytes
//! option<T>     = presence byte (0/1) + T when present
//! vec<T>        = varint count + count items
//! enum          = u8 (declaration-order index; `JobState` via `ALL`)
//! ```
//!
//! Request/response `tag` is the variant's declaration-order index in
//! [`ApiRequest`]/[`ApiResponse`] — appending a variant is wire-safe,
//! reordering is not (same contract as the JSON `"type"` names, just
//! positional). Unknown tags, truncated bodies, and trailing bytes all
//! decode to an error string that the gateway answers as a framed 400.

use crate::service::api::*;
use crate::service::models::*;

use super::{WireCodec, CT_FRAME};

const KIND_REQUEST: u8 = 0x01;
const KIND_OK: u8 = 0x02;
const KIND_ERR: u8 = 0x03;

/// [`WireCodec`] over the binary frame encoding.
pub struct FrameCodec;

impl WireCodec for FrameCodec {
    fn content_type(&self) -> &'static str {
        CT_FRAME
    }

    fn encode_request(&self, req: &ApiRequest, out: &mut Vec<u8>) {
        encode_request(req, out);
    }

    fn decode_request(&self, body: &[u8]) -> Result<ApiRequest, String> {
        decode_request(body)
    }

    fn encode_ok(&self, resp: &ApiResponse, out: &mut Vec<u8>) {
        encode_ok(resp, out);
    }

    fn encode_err(&self, msg: &str, out: &mut Vec<u8>) {
        out.push(KIND_ERR);
        put_str(out, msg);
    }

    fn decode_ok(&self, body: &[u8]) -> Result<ApiResponse, ApiError> {
        decode_response(body).map_err(ApiError::Transport)?.map_err(ApiError::Transport)
    }

    fn decode_err(&self, body: &[u8]) -> String {
        let mut c = Cur::new(body);
        match c.u8() {
            Ok(KIND_ERR) => c.string().unwrap_or_else(|_| "unknown".into()),
            _ => "unknown".into(),
        }
    }
}

// ---------------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------------

fn put_u64(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        None => out.push(0),
        Some(n) => {
            out.push(1);
            put_u64(out, n);
        }
    }
}

fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            put_f64(out, x);
        }
    }
}

fn put_kv(out: &mut Vec<u8>, kv: &[(String, String)]) {
    put_u64(out, kv.len() as u64);
    for (k, v) in kv {
        put_str(out, k);
        put_str(out, v);
    }
}

fn put_xfers(out: &mut Vec<u8>, xs: &[(String, u64)]) {
    put_u64(out, xs.len() as u64);
    for (r, s) in xs {
        put_str(out, r);
        put_u64(out, *s);
    }
}

fn put_ids<T: Copy>(out: &mut Vec<u8>, ids: &[T], f: impl Fn(T) -> u64) {
    put_u64(out, ids.len() as u64);
    for &i in ids {
        put_u64(out, f(i));
    }
}

/// Borrowing decode cursor. Every read is bounds-checked against the
/// frame; errors are plain strings that surface as framed 400s.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

const E_TRUNC: &str = "truncated frame";

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, i: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u8(&mut self) -> Result<u8, String> {
        let v = *self.b.get(self.i).ok_or(E_TRUNC)?;
        self.i += 1;
        Ok(v)
    }

    fn u64(&mut self) -> Result<u64, String> {
        let mut v: u64 = 0;
        for shift in (0..64).step_by(7) {
            let b = self.u8()?;
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err("varint overflow".into())
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(self.u64()? as u32)
    }

    fn usize(&mut self) -> Result<usize, String> {
        Ok(self.u64()? as usize)
    }

    fn f64(&mut self) -> Result<f64, String> {
        if self.remaining() < 8 {
            return Err(E_TRUNC.into());
        }
        let mut bytes = [0u8; 8];
        bytes.copy_from_slice(&self.b[self.i..self.i + 8]);
        self.i += 8;
        Ok(f64::from_bits(u64::from_le_bytes(bytes)))
    }

    fn bool(&mut self) -> Result<bool, String> {
        Ok(self.u8()? != 0)
    }

    /// Borrowed string slice — the zero-copy read; callers own-ify only
    /// when the decoded type demands a `String`.
    fn str(&mut self) -> Result<&'a str, String> {
        let n = self.usize()?;
        if self.remaining() < n {
            return Err(E_TRUNC.into());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + n]).map_err(|_| "bad utf-8 in frame")?;
        self.i += n;
        Ok(s)
    }

    fn string(&mut self) -> Result<String, String> {
        self.str().map(String::from)
    }

    /// Collection count, validated against the bytes left: every element
    /// costs at least one byte, so a frame can never make us reserve more
    /// capacity than its own length (no allocation blowup from a forged
    /// count).
    fn count(&mut self) -> Result<usize, String> {
        let n = self.usize()?;
        if n > self.remaining() {
            return Err(E_TRUNC.into());
        }
        Ok(n)
    }

    fn opt(&mut self) -> Result<Option<u64>, String> {
        match self.u8()? {
            0 => Ok(None),
            _ => Ok(Some(self.u64()?)),
        }
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, String> {
        match self.u8()? {
            0 => Ok(None),
            _ => Ok(Some(self.f64()?)),
        }
    }

    fn kv(&mut self) -> Result<Vec<(String, String)>, String> {
        let n = self.count()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push((self.string()?, self.string()?));
        }
        Ok(out)
    }

    fn xfers(&mut self) -> Result<Vec<(String, u64)>, String> {
        let n = self.count()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push((self.string()?, self.u64()?));
        }
        Ok(out)
    }

    fn ids<T>(&mut self, f: impl Fn(u64) -> T) -> Result<Vec<T>, String> {
        let n = self.count()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f(self.u64()?));
        }
        Ok(out)
    }

    fn finish(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err("trailing bytes in frame".into());
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Enums — u8 declaration-order indices
// ---------------------------------------------------------------------------

fn put_jstate(out: &mut Vec<u8>, s: JobState) {
    out.push(JobState::ALL.iter().position(|&x| x == s).unwrap_or(0) as u8);
}

fn jstate(c: &mut Cur) -> Result<JobState, String> {
    let i = c.u8()? as usize;
    JobState::ALL.get(i).copied().ok_or_else(|| format!("bad job state {i}"))
}

fn put_dir(out: &mut Vec<u8>, d: Direction) {
    out.push(match d {
        Direction::In => 0,
        Direction::Out => 1,
    });
}

fn dir(c: &mut Cur) -> Result<Direction, String> {
    match c.u8()? {
        0 => Ok(Direction::In),
        1 => Ok(Direction::Out),
        n => Err(format!("bad direction {n}")),
    }
}

fn put_tstate(out: &mut Vec<u8>, s: TransferState) {
    out.push(match s {
        TransferState::Pending => 0,
        TransferState::Active => 1,
        TransferState::Done => 2,
        TransferState::Error => 3,
    });
}

fn tstate(c: &mut Cur) -> Result<TransferState, String> {
    match c.u8()? {
        0 => Ok(TransferState::Pending),
        1 => Ok(TransferState::Active),
        2 => Ok(TransferState::Done),
        3 => Ok(TransferState::Error),
        n => Err(format!("bad transfer state {n}")),
    }
}

fn put_bstate(out: &mut Vec<u8>, s: BatchJobState) {
    out.push(match s {
        BatchJobState::Pending => 0,
        BatchJobState::Queued => 1,
        BatchJobState::Running => 2,
        BatchJobState::Finished => 3,
        BatchJobState::Deleted => 4,
    });
}

fn bstate(c: &mut Cur) -> Result<BatchJobState, String> {
    match c.u8()? {
        0 => Ok(BatchJobState::Pending),
        1 => Ok(BatchJobState::Queued),
        2 => Ok(BatchJobState::Running),
        3 => Ok(BatchJobState::Finished),
        4 => Ok(BatchJobState::Deleted),
        n => Err(format!("bad batch-job state {n}")),
    }
}

fn put_mode(out: &mut Vec<u8>, m: JobMode) {
    out.push(match m {
        JobMode::Mpi => 0,
        JobMode::Serial => 1,
    });
}

fn mode(c: &mut Cur) -> Result<JobMode, String> {
    match c.u8()? {
        0 => Ok(JobMode::Mpi),
        1 => Ok(JobMode::Serial),
        n => Err(format!("bad job mode {n}")),
    }
}

// ---------------------------------------------------------------------------
// Rows
// ---------------------------------------------------------------------------

fn put_job(out: &mut Vec<u8>, j: &Job) {
    put_u64(out, j.id.0);
    put_u64(out, j.site_id.0);
    put_u64(out, j.app_id.0);
    put_jstate(out, j.state);
    put_kv(out, &j.params);
    put_kv(out, &j.tags);
    put_u64(out, j.num_nodes as u64);
    put_str(out, &j.workload);
    put_ids(out, &j.parents, |p| p.0);
    put_u64(out, j.attempts as u64);
    put_u64(out, j.max_attempts as u64);
    put_opt(out, j.session.map(|s| s.0));
    put_f64(out, j.created_at);
}

fn job(c: &mut Cur) -> Result<Job, String> {
    Ok(Job {
        id: JobId(c.u64()?),
        site_id: SiteId(c.u64()?),
        app_id: AppId(c.u64()?),
        state: jstate(c)?,
        params: c.kv()?,
        tags: c.kv()?,
        num_nodes: c.u32()?,
        workload: c.string()?,
        parents: c.ids(JobId)?,
        attempts: c.u32()?,
        max_attempts: c.u32()?,
        session: c.opt()?.map(SessionId),
        created_at: c.f64()?,
    })
}

fn put_batch_job(out: &mut Vec<u8>, b: &BatchJob) {
    put_u64(out, b.id.0);
    put_u64(out, b.site_id.0);
    put_u64(out, b.num_nodes as u64);
    put_f64(out, b.wall_time_s);
    put_mode(out, b.mode);
    put_str(out, &b.queue);
    put_str(out, &b.project);
    put_bstate(out, b.state);
    put_opt(out, b.local_id);
    put_f64(out, b.created_at);
    put_opt_f64(out, b.started_at);
    put_opt_f64(out, b.ended_at);
}

fn batch_job(c: &mut Cur) -> Result<BatchJob, String> {
    Ok(BatchJob {
        id: BatchJobId(c.u64()?),
        site_id: SiteId(c.u64()?),
        num_nodes: c.u32()?,
        wall_time_s: c.f64()?,
        mode: mode(c)?,
        queue: c.string()?,
        project: c.string()?,
        state: bstate(c)?,
        local_id: c.opt()?,
        created_at: c.f64()?,
        started_at: c.opt_f64()?,
        ended_at: c.opt_f64()?,
    })
}

fn put_transfer_item(out: &mut Vec<u8>, t: &TransferItem) {
    put_u64(out, t.id.0);
    put_u64(out, t.job_id.0);
    put_u64(out, t.site_id.0);
    put_dir(out, t.direction);
    put_str(out, &t.remote);
    put_u64(out, t.size_bytes);
    put_tstate(out, t.state);
    put_opt(out, t.task_id.map(|x| x.0));
}

fn transfer_item(c: &mut Cur) -> Result<TransferItem, String> {
    Ok(TransferItem {
        id: TransferItemId(c.u64()?),
        job_id: JobId(c.u64()?),
        site_id: SiteId(c.u64()?),
        direction: dir(c)?,
        remote: c.string()?,
        size_bytes: c.u64()?,
        state: tstate(c)?,
        task_id: c.opt()?.map(XferTaskId),
    })
}

fn put_event(out: &mut Vec<u8>, e: &Event) {
    put_u64(out, e.seq);
    put_u64(out, e.job_id.0);
    put_u64(out, e.site_id.0);
    put_f64(out, e.ts);
    put_jstate(out, e.from);
    put_jstate(out, e.to);
    put_str(out, &e.data);
}

fn event(c: &mut Cur) -> Result<Event, String> {
    Ok(Event {
        seq: c.u64()?,
        job_id: JobId(c.u64()?),
        site_id: SiteId(c.u64()?),
        ts: c.f64()?,
        from: jstate(c)?,
        to: jstate(c)?,
        data: c.string()?,
    })
}

fn put_job_create(out: &mut Vec<u8>, jc: &JobCreate) {
    put_u64(out, jc.site_id.0);
    put_str(out, &jc.app);
    put_str(out, &jc.workload);
    put_u64(out, jc.num_nodes as u64);
    put_kv(out, &jc.params);
    put_kv(out, &jc.tags);
    put_xfers(out, &jc.transfers_in);
    put_xfers(out, &jc.transfers_out);
    put_ids(out, &jc.parents, |p| p.0);
}

fn job_create(c: &mut Cur) -> Result<JobCreate, String> {
    Ok(JobCreate {
        site_id: SiteId(c.u64()?),
        app: c.string()?,
        workload: c.string()?,
        num_nodes: c.u32()?,
        params: c.kv()?,
        tags: c.kv()?,
        transfers_in: c.xfers()?,
        transfers_out: c.xfers()?,
        parents: c.ids(JobId)?,
    })
}

fn put_filter(out: &mut Vec<u8>, f: &JobFilter) {
    put_opt(out, f.site.map(|s| s.0));
    put_u64(out, f.states.len() as u64);
    for &s in &f.states {
        put_jstate(out, s);
    }
    put_kv(out, &f.tags);
    put_u64(out, f.limit as u64);
}

fn filter(c: &mut Cur) -> Result<JobFilter, String> {
    let site = c.opt()?.map(SiteId);
    let n = c.count()?;
    let mut states = Vec::with_capacity(n);
    for _ in 0..n {
        states.push(jstate(c)?);
    }
    Ok(JobFilter { site, states, tags: c.kv()?, limit: c.usize()? })
}

// ---------------------------------------------------------------------------
// Envelopes
// ---------------------------------------------------------------------------

/// Variant tags: declaration-order index in [`ApiRequest`].
fn request_tag(req: &ApiRequest) -> u8 {
    use ApiRequest::*;
    match req {
        CreateUser { .. } => 0,
        CreateSite { .. } => 1,
        RegisterApp { .. } => 2,
        BulkCreateJobs { .. } => 3,
        ListJobs { .. } => 4,
        CountByState { .. } => 5,
        UpdateJobState { .. } => 6,
        BulkUpdateJobState { .. } => 7,
        CreateSession { .. } => 8,
        SessionAcquire { .. } => 9,
        SessionHeartbeat { .. } => 10,
        SessionSync { .. } => 11,
        SessionEnd { .. } => 12,
        CreateBatchJob { .. } => 13,
        ListBatchJobs { .. } => 14,
        UpdateBatchJob { .. } => 15,
        PendingTransferItems { .. } => 16,
        UpdateTransferItems { .. } => 17,
        SyncTransferItems { .. } => 18,
        SiteBacklog { .. } => 19,
        ListEvents { .. } => 20,
        WatchEvents { .. } => 21,
    }
}

/// Serialize a request frame (`0x01 tag fields...`) into `out`.
pub fn encode_request(req: &ApiRequest, out: &mut Vec<u8>) {
    use ApiRequest::*;
    out.push(KIND_REQUEST);
    out.push(request_tag(req));
    match req {
        CreateUser { name } => put_str(out, name),
        CreateSite { name, hostname, path } => {
            put_str(out, name);
            put_str(out, hostname);
            put_str(out, path);
        }
        RegisterApp { site, name, command_template, parameters } => {
            put_u64(out, site.0);
            put_str(out, name);
            put_str(out, command_template);
            put_u64(out, parameters.len() as u64);
            for p in parameters {
                put_str(out, p);
            }
        }
        BulkCreateJobs { jobs } => {
            put_u64(out, jobs.len() as u64);
            for jc in jobs {
                put_job_create(out, jc);
            }
        }
        ListJobs { filter } => put_filter(out, filter),
        CountByState { site } => put_u64(out, site.0),
        UpdateJobState { job, to, data } => {
            put_u64(out, job.0);
            put_jstate(out, *to);
            put_str(out, data);
        }
        BulkUpdateJobState { jobs, to, data } => {
            put_ids(out, jobs, |j| j.0);
            put_jstate(out, *to);
            put_str(out, data);
        }
        CreateSession { site, batch_job } => {
            put_u64(out, site.0);
            put_opt(out, batch_job.map(|b| b.0));
        }
        SessionAcquire { session, max_nodes, max_jobs } => {
            put_u64(out, session.0);
            put_u64(out, *max_nodes as u64);
            put_u64(out, *max_jobs as u64);
        }
        SessionHeartbeat { session } => put_u64(out, session.0),
        SessionSync { session, updates } => {
            put_u64(out, session.0);
            put_u64(out, updates.len() as u64);
            for (job, to, data) in updates {
                put_u64(out, job.0);
                put_jstate(out, *to);
                put_str(out, data);
            }
        }
        SessionEnd { session } => put_u64(out, session.0),
        CreateBatchJob { site, num_nodes, wall_time_s, mode, queue, project } => {
            put_u64(out, site.0);
            put_u64(out, *num_nodes as u64);
            put_f64(out, *wall_time_s);
            put_mode(out, *mode);
            put_str(out, queue);
            put_str(out, project);
        }
        ListBatchJobs { site, active_only } => {
            put_u64(out, site.0);
            out.push(*active_only as u8);
        }
        UpdateBatchJob { id, state, local_id } => {
            put_u64(out, id.0);
            put_bstate(out, *state);
            put_opt(out, *local_id);
        }
        PendingTransferItems { site, direction, limit } => {
            put_u64(out, site.0);
            put_dir(out, *direction);
            put_u64(out, *limit as u64);
        }
        UpdateTransferItems { ids, state, task_id } => {
            put_ids(out, ids, |i| i.0);
            put_tstate(out, *state);
            put_opt(out, task_id.map(|t| t.0));
        }
        SyncTransferItems { updates } => {
            put_u64(out, updates.len() as u64);
            for (id, st, task) in updates {
                put_u64(out, id.0);
                put_tstate(out, *st);
                put_opt(out, task.map(|t| t.0));
            }
        }
        SiteBacklog { site } => put_u64(out, site.0),
        ListEvents { since } => put_u64(out, *since as u64),
        WatchEvents { site, since, timeout_ms, max_events } => {
            put_opt(out, site.map(|s| s.0));
            put_u64(out, *since as u64);
            put_u64(out, *timeout_ms);
            put_u64(out, *max_events as u64);
        }
    }
}

/// Decode a request frame. Mirrors the JSON decoder's strictness: the
/// hot `SessionSync`/`SyncTransferItems` tuples are strict, and a bad
/// enum index anywhere is an error (binary has no lenient name fallback
/// — an out-of-range byte is corruption, not version skew).
pub fn decode_request(body: &[u8]) -> Result<ApiRequest, String> {
    let mut c = Cur::new(body);
    if c.u8()? != KIND_REQUEST {
        return Err("bad frame kind".into());
    }
    let tag = c.u8()?;
    let req = match tag {
        0 => ApiRequest::CreateUser { name: c.string()? },
        1 => ApiRequest::CreateSite { name: c.string()?, hostname: c.string()?, path: c.string()? },
        2 => ApiRequest::RegisterApp {
            site: SiteId(c.u64()?),
            name: c.string()?,
            command_template: c.string()?,
            parameters: {
                let n = c.count()?;
                let mut ps = Vec::with_capacity(n);
                for _ in 0..n {
                    ps.push(c.string()?);
                }
                ps
            },
        },
        3 => ApiRequest::BulkCreateJobs {
            jobs: {
                let n = c.count()?;
                let mut jobs = Vec::with_capacity(n);
                for _ in 0..n {
                    jobs.push(job_create(&mut c)?);
                }
                jobs
            },
        },
        4 => ApiRequest::ListJobs { filter: filter(&mut c)? },
        5 => ApiRequest::CountByState { site: SiteId(c.u64()?) },
        6 => ApiRequest::UpdateJobState {
            job: JobId(c.u64()?),
            to: jstate(&mut c)?,
            data: c.string()?,
        },
        7 => ApiRequest::BulkUpdateJobState {
            jobs: c.ids(JobId)?,
            to: jstate(&mut c)?,
            data: c.string()?,
        },
        8 => ApiRequest::CreateSession {
            site: SiteId(c.u64()?),
            batch_job: c.opt()?.map(BatchJobId),
        },
        9 => ApiRequest::SessionAcquire {
            session: SessionId(c.u64()?),
            max_nodes: c.u32()?,
            max_jobs: c.usize()?,
        },
        10 => ApiRequest::SessionHeartbeat { session: SessionId(c.u64()?) },
        11 => {
            let session = SessionId(c.u64()?);
            let n = c.count()?;
            let mut updates = Vec::with_capacity(n);
            for _ in 0..n {
                updates.push((JobId(c.u64()?), jstate(&mut c)?, c.string()?));
            }
            ApiRequest::SessionSync { session, updates }
        }
        12 => ApiRequest::SessionEnd { session: SessionId(c.u64()?) },
        13 => ApiRequest::CreateBatchJob {
            site: SiteId(c.u64()?),
            num_nodes: c.u32()?,
            wall_time_s: c.f64()?,
            mode: mode(&mut c)?,
            queue: c.string()?,
            project: c.string()?,
        },
        14 => ApiRequest::ListBatchJobs { site: SiteId(c.u64()?), active_only: c.bool()? },
        15 => ApiRequest::UpdateBatchJob {
            id: BatchJobId(c.u64()?),
            state: bstate(&mut c)?,
            local_id: c.opt()?,
        },
        16 => ApiRequest::PendingTransferItems {
            site: SiteId(c.u64()?),
            direction: dir(&mut c)?,
            limit: c.usize()?,
        },
        17 => ApiRequest::UpdateTransferItems {
            ids: c.ids(TransferItemId)?,
            state: tstate(&mut c)?,
            task_id: c.opt()?.map(XferTaskId),
        },
        18 => {
            let n = c.count()?;
            let mut updates = Vec::with_capacity(n);
            for _ in 0..n {
                updates.push((TransferItemId(c.u64()?), tstate(&mut c)?, c.opt()?.map(XferTaskId)));
            }
            ApiRequest::SyncTransferItems { updates }
        }
        19 => ApiRequest::SiteBacklog { site: SiteId(c.u64()?) },
        20 => ApiRequest::ListEvents { since: c.usize()? },
        21 => ApiRequest::WatchEvents {
            site: c.opt()?.map(SiteId),
            since: c.usize()?,
            timeout_ms: c.u64()?,
            max_events: c.usize()?,
        },
        n => return Err(format!("unknown request tag {n}")),
    };
    c.finish()?;
    Ok(req)
}

/// Variant tags: declaration-order index in [`ApiResponse`].
fn response_tag(resp: &ApiResponse) -> u8 {
    use ApiResponse::*;
    match resp {
        Unit => 0,
        UserId(_) => 1,
        SiteId(_) => 2,
        AppId(_) => 3,
        JobIds(_) => 4,
        Jobs(_) => 5,
        Counts(_) => 6,
        SessionId(_) => 7,
        BatchJobId(_) => 8,
        BatchJobs(_) => 9,
        TransferItems(_) => 10,
        Backlog(_) => 11,
        Events(_) => 12,
    }
}

/// Serialize a success frame (`0x02 tag fields...`) into `out`.
pub fn encode_ok(resp: &ApiResponse, out: &mut Vec<u8>) {
    use ApiResponse::*;
    out.push(KIND_OK);
    out.push(response_tag(resp));
    match resp {
        Unit => {}
        UserId(x) => put_u64(out, x.0),
        SiteId(x) => put_u64(out, x.0),
        AppId(x) => put_u64(out, x.0),
        SessionId(x) => put_u64(out, x.0),
        BatchJobId(x) => put_u64(out, x.0),
        JobIds(x) => put_ids(out, x, |i| i.0),
        Jobs(x) => {
            put_u64(out, x.len() as u64);
            for j in x {
                put_job(out, j);
            }
        }
        Counts(x) => {
            put_u64(out, x.len() as u64);
            for (s, n) in x {
                put_jstate(out, *s);
                put_u64(out, *n as u64);
            }
        }
        BatchJobs(x) => {
            put_u64(out, x.len() as u64);
            for b in x {
                put_batch_job(out, b);
            }
        }
        TransferItems(x) => {
            put_u64(out, x.len() as u64);
            for t in x {
                put_transfer_item(out, t);
            }
        }
        Backlog(b) => {
            put_u64(out, b.backlog_jobs as u64);
            put_u64(out, b.runnable_nodes as u64);
            put_u64(out, b.inflight_nodes as u64);
            put_u64(out, b.batch_nodes as u64);
        }
        Events(p) => {
            put_opt(out, p.truncated_before);
            put_u64(out, p.events.len() as u64);
            for e in &p.events {
                put_event(out, e);
            }
        }
    }
}

/// Decode a response frame: `Ok(Ok(resp))` for a success frame,
/// `Ok(Err(msg))` for an error frame, `Err(msg)` for a malformed one.
#[allow(clippy::type_complexity)]
pub fn decode_response(body: &[u8]) -> Result<Result<ApiResponse, String>, String> {
    let mut c = Cur::new(body);
    match c.u8()? {
        KIND_ERR => return Ok(Err(c.string()?)),
        KIND_OK => {}
        _ => return Err("bad frame kind".into()),
    }
    let tag = c.u8()?;
    let resp = match tag {
        0 => ApiResponse::Unit,
        1 => ApiResponse::UserId(UserId(c.u64()?)),
        2 => ApiResponse::SiteId(SiteId(c.u64()?)),
        3 => ApiResponse::AppId(AppId(c.u64()?)),
        4 => ApiResponse::JobIds(c.ids(JobId)?),
        5 => {
            let n = c.count()?;
            let mut jobs = Vec::with_capacity(n);
            for _ in 0..n {
                jobs.push(job(&mut c)?);
            }
            ApiResponse::Jobs(jobs)
        }
        6 => {
            let n = c.count()?;
            let mut counts = Vec::with_capacity(n);
            for _ in 0..n {
                counts.push((jstate(&mut c)?, c.usize()?));
            }
            ApiResponse::Counts(counts)
        }
        7 => ApiResponse::SessionId(SessionId(c.u64()?)),
        8 => ApiResponse::BatchJobId(BatchJobId(c.u64()?)),
        9 => {
            let n = c.count()?;
            let mut bs = Vec::with_capacity(n);
            for _ in 0..n {
                bs.push(batch_job(&mut c)?);
            }
            ApiResponse::BatchJobs(bs)
        }
        10 => {
            let n = c.count()?;
            let mut ts = Vec::with_capacity(n);
            for _ in 0..n {
                ts.push(transfer_item(&mut c)?);
            }
            ApiResponse::TransferItems(ts)
        }
        11 => ApiResponse::Backlog(Backlog {
            backlog_jobs: c.usize()?,
            runnable_nodes: c.u32()?,
            inflight_nodes: c.u32()?,
            batch_nodes: c.u32()?,
        }),
        12 => {
            let truncated_before = c.opt()?;
            let n = c.count()?;
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                events.push(event(&mut c)?);
            }
            ApiResponse::Events(EventsPage { truncated_before, events })
        }
        n => return Err(format!("unknown response tag {n}")),
    };
    c.finish()?;
    Ok(Ok(resp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn varint_roundtrip(v: u64) {
        let mut buf = Vec::new();
        put_u64(&mut buf, v);
        let mut c = Cur::new(&buf);
        assert_eq!(c.u64().unwrap(), v);
        assert_eq!(c.remaining(), 0);
    }

    #[test]
    fn varints_roundtrip_across_widths() {
        for v in [0, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            varint_roundtrip(v);
        }
    }

    #[test]
    fn truncated_and_malformed_frames_error() {
        let mut buf = Vec::new();
        encode_request(
            &ApiRequest::SessionSync {
                session: SessionId(7),
                updates: vec![(JobId(1), JobState::RunDone, "x".into())],
            },
            &mut buf,
        );
        // Every proper prefix of a valid frame must decode to an error,
        // never panic or succeed.
        for cut in 0..buf.len() {
            assert!(decode_request(&buf[..cut]).is_err(), "prefix {cut} decoded");
        }
        // Trailing garbage after a complete frame is rejected too.
        let mut noisy = buf.clone();
        noisy.push(0xff);
        assert_eq!(decode_request(&noisy).unwrap_err(), "trailing bytes in frame");
        // Unknown tag and bad kind byte.
        assert_eq!(decode_request(&[KIND_REQUEST, 250]).unwrap_err(), "unknown request tag 250");
        assert_eq!(decode_request(&[0x7e, 0]).unwrap_err(), "bad frame kind");
        assert!(decode_request(&[]).is_err());
    }

    #[test]
    fn forged_count_cannot_reserve_past_frame_length() {
        // A SessionSync frame claiming u64::MAX updates but carrying no
        // bytes for them: the count check fails before any reservation.
        let mut buf = vec![KIND_REQUEST, 11];
        put_u64(&mut buf, 1); // session
        put_u64(&mut buf, u64::MAX); // forged update count
        assert!(decode_request(&buf).is_err());
    }

    #[test]
    fn error_frames_roundtrip() {
        let mut buf = Vec::new();
        FrameCodec.encode_err("not found: site 9", &mut buf);
        assert_eq!(FrameCodec.decode_err(&buf), "not found: site 9");
        match FrameCodec.decode_ok(&buf) {
            Err(ApiError::Transport(m)) => assert_eq!(m, "not found: site 9"),
            other => panic!("expected Transport, got {other:?}"),
        }
    }
}
