//! The JSON envelope codec — the original (and default) wire encoding.
//!
//! A request is `{"type":"<Variant>", ...fields}`; a success response is
//! `{"ok":true,"type":"<Variant>","body":...}`; an error response is
//! `{"ok":false,"error":"..."}`. Row payloads reuse the
//! `to_json`/`from_json` codecs on [`crate::service::models`] types, so a
//! row has exactly one JSON shape on the wire and in the WAL. These
//! functions moved here verbatim from `http_gw` when the codec layer was
//! extracted; `http_gw` re-exports them for compatibility.

use crate::service::api::*;
use crate::service::models::*;
use crate::util::json::{kv_from_json, kv_to_json, u64s_from_json, Json};

use super::{WireCodec, CT_JSON};

/// [`WireCodec`] over the JSON envelope encoding.
pub struct JsonCodec;

impl WireCodec for JsonCodec {
    fn content_type(&self) -> &'static str {
        CT_JSON
    }

    fn encode_request(&self, req: &ApiRequest, out: &mut Vec<u8>) {
        out.extend_from_slice(request_to_json(req).to_string().as_bytes());
    }

    fn decode_request(&self, body: &[u8]) -> Result<ApiRequest, String> {
        let j = Json::parse(&String::from_utf8_lossy(body)).map_err(|e| format!("bad json: {e}"))?;
        request_from_json(&j)
    }

    fn encode_ok(&self, resp: &ApiResponse, out: &mut Vec<u8>) {
        out.extend_from_slice(response_to_json(resp).to_string().as_bytes());
    }

    fn encode_err(&self, msg: &str, out: &mut Vec<u8>) {
        let body = Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]);
        out.extend_from_slice(body.to_string().as_bytes());
    }

    fn decode_ok(&self, body: &[u8]) -> Result<ApiResponse, ApiError> {
        let text = String::from_utf8_lossy(body);
        let parsed = Json::parse(&text).map_err(|e| ApiError::Transport(e.to_string()))?;
        response_from_json(&parsed)
    }

    fn decode_err(&self, body: &[u8]) -> String {
        Json::parse(&String::from_utf8_lossy(body))
            .ok()
            .and_then(|j| j.get("error").and_then(Json::as_str).map(String::from))
            .unwrap_or_else(|| "unknown".to_string())
    }
}

fn xfers_to_json(xs: &[(String, u64)]) -> Json {
    Json::Arr(xs.iter().map(|(r, s)| Json::arr([Json::str(r.clone()), Json::num(*s as f64)])).collect())
}

fn xfers_from_json(j: &Json) -> Vec<(String, u64)> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|p| Some((p.idx(0)?.as_str()?.to_string(), p.idx(1)?.as_u64()?)))
                .collect()
        })
        .unwrap_or_default()
}

fn ids_to_json<T: Copy>(ids: &[T], f: impl Fn(T) -> u64) -> Json {
    Json::Arr(ids.iter().map(|&i| Json::num(f(i) as f64)).collect())
}

// Lenient wire decoders: unknown names fall back to a safe default
// rather than erroring (strict paths use `T::from_name` directly).
fn dir_from(s: &str) -> Direction {
    Direction::from_name(s).unwrap_or(Direction::In)
}

fn tstate_from(s: &str) -> TransferState {
    TransferState::from_name(s).unwrap_or(TransferState::Pending)
}

fn bstate_from(s: &str) -> BatchJobState {
    BatchJobState::from_name(s).unwrap_or(BatchJobState::Pending)
}

fn mode_from(s: &str) -> JobMode {
    JobMode::from_name(s).unwrap_or(JobMode::Mpi)
}

/// Encode a request envelope as `{"type":"<Variant>", ...fields}`.
pub fn request_to_json(req: &ApiRequest) -> Json {
    use ApiRequest::*;
    match req {
        CreateUser { name } => Json::obj(vec![("type", Json::str("CreateUser")), ("name", Json::str(name.clone()))]),
        CreateSite { name, hostname, path } => Json::obj(vec![
            ("type", Json::str("CreateSite")),
            ("name", Json::str(name.clone())),
            ("hostname", Json::str(hostname.clone())),
            ("path", Json::str(path.clone())),
        ]),
        RegisterApp { site, name, command_template, parameters } => Json::obj(vec![
            ("type", Json::str("RegisterApp")),
            ("site", Json::num(site.0 as f64)),
            ("name", Json::str(name.clone())),
            ("command_template", Json::str(command_template.clone())),
            ("parameters", Json::Arr(parameters.iter().map(|p| Json::str(p.clone())).collect())),
        ]),
        BulkCreateJobs { jobs } => Json::obj(vec![
            ("type", Json::str("BulkCreateJobs")),
            (
                "jobs",
                Json::Arr(
                    jobs.iter()
                        .map(|jc| {
                            Json::obj(vec![
                                ("site_id", Json::num(jc.site_id.0 as f64)),
                                ("app", Json::str(jc.app.clone())),
                                ("workload", Json::str(jc.workload.clone())),
                                ("num_nodes", Json::num(jc.num_nodes as f64)),
                                ("params", kv_to_json(&jc.params)),
                                ("tags", kv_to_json(&jc.tags)),
                                ("transfers_in", xfers_to_json(&jc.transfers_in)),
                                ("transfers_out", xfers_to_json(&jc.transfers_out)),
                                ("parents", ids_to_json(&jc.parents, |p| p.0)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        ListJobs { filter } => Json::obj(vec![("type", Json::str("ListJobs")), ("filter", filter_to_json(filter))]),
        CountByState { site } => {
            Json::obj(vec![("type", Json::str("CountByState")), ("site", Json::num(site.0 as f64))])
        }
        UpdateJobState { job, to, data } => Json::obj(vec![
            ("type", Json::str("UpdateJobState")),
            ("job", Json::num(job.0 as f64)),
            ("to", Json::str(to.name())),
            ("data", Json::str(data.clone())),
        ]),
        BulkUpdateJobState { jobs, to, data } => Json::obj(vec![
            ("type", Json::str("BulkUpdateJobState")),
            ("jobs", ids_to_json(jobs, |j| j.0)),
            ("to", Json::str(to.name())),
            ("data", Json::str(data.clone())),
        ]),
        CreateSession { site, batch_job } => Json::obj(vec![
            ("type", Json::str("CreateSession")),
            ("site", Json::num(site.0 as f64)),
            ("batch_job", batch_job.map(|b| Json::num(b.0 as f64)).unwrap_or(Json::Null)),
        ]),
        SessionAcquire { session, max_nodes, max_jobs } => Json::obj(vec![
            ("type", Json::str("SessionAcquire")),
            ("session", Json::num(session.0 as f64)),
            ("max_nodes", Json::num(*max_nodes as f64)),
            ("max_jobs", Json::num(*max_jobs as f64)),
        ]),
        SessionHeartbeat { session } => Json::obj(vec![
            ("type", Json::str("SessionHeartbeat")),
            ("session", Json::num(session.0 as f64)),
        ]),
        SessionSync { session, updates } => Json::obj(vec![
            ("type", Json::str("SessionSync")),
            ("session", Json::num(session.0 as f64)),
            (
                "updates",
                Json::Arr(
                    updates
                        .iter()
                        .map(|(job, to, data)| {
                            Json::arr([
                                Json::num(job.0 as f64),
                                Json::str(to.name()),
                                Json::str(data.clone()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        SessionEnd { session } => {
            Json::obj(vec![("type", Json::str("SessionEnd")), ("session", Json::num(session.0 as f64))])
        }
        CreateBatchJob { site, num_nodes, wall_time_s, mode, queue, project } => Json::obj(vec![
            ("type", Json::str("CreateBatchJob")),
            ("site", Json::num(site.0 as f64)),
            ("num_nodes", Json::num(*num_nodes as f64)),
            ("wall_time_s", Json::num(*wall_time_s)),
            ("mode", Json::str(mode.name())),
            ("queue", Json::str(queue.clone())),
            ("project", Json::str(project.clone())),
        ]),
        ListBatchJobs { site, active_only } => Json::obj(vec![
            ("type", Json::str("ListBatchJobs")),
            ("site", Json::num(site.0 as f64)),
            ("active_only", Json::Bool(*active_only)),
        ]),
        UpdateBatchJob { id, state, local_id } => Json::obj(vec![
            ("type", Json::str("UpdateBatchJob")),
            ("id", Json::num(id.0 as f64)),
            ("state", Json::str(state.name())),
            ("local_id", local_id.map(|l| Json::num(l as f64)).unwrap_or(Json::Null)),
        ]),
        PendingTransferItems { site, direction, limit } => Json::obj(vec![
            ("type", Json::str("PendingTransferItems")),
            ("site", Json::num(site.0 as f64)),
            ("direction", Json::str(direction.name())),
            ("limit", Json::num(*limit as f64)),
        ]),
        UpdateTransferItems { ids, state, task_id } => Json::obj(vec![
            ("type", Json::str("UpdateTransferItems")),
            ("ids", ids_to_json(ids, |i| i.0)),
            ("state", Json::str(state.name())),
            ("task_id", task_id.map(|t| Json::num(t.0 as f64)).unwrap_or(Json::Null)),
        ]),
        SyncTransferItems { updates } => Json::obj(vec![
            ("type", Json::str("SyncTransferItems")),
            (
                "updates",
                Json::Arr(
                    updates
                        .iter()
                        .map(|(id, st, task)| {
                            Json::arr([
                                Json::num(id.0 as f64),
                                Json::str(st.name()),
                                task.map(|t| Json::num(t.0 as f64)).unwrap_or(Json::Null),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        SiteBacklog { site } => {
            Json::obj(vec![("type", Json::str("SiteBacklog")), ("site", Json::num(site.0 as f64))])
        }
        ListEvents { since } => {
            Json::obj(vec![("type", Json::str("ListEvents")), ("since", Json::num(*since as f64))])
        }
        WatchEvents { site, since, timeout_ms, max_events } => Json::obj(vec![
            ("type", Json::str("WatchEvents")),
            ("site", site.map(|s| Json::num(s.0 as f64)).unwrap_or(Json::Null)),
            ("since", Json::num(*since as f64)),
            ("timeout_ms", Json::num(*timeout_ms as f64)),
            ("max_events", Json::num(*max_events as f64)),
        ]),
    }
}

fn filter_to_json(f: &JobFilter) -> Json {
    Json::obj(vec![
        ("site", f.site.map(|s| Json::num(s.0 as f64)).unwrap_or(Json::Null)),
        ("states", Json::Arr(f.states.iter().map(|s| Json::str(s.name())).collect())),
        ("tags", kv_to_json(&f.tags)),
        ("limit", Json::num(f.limit as f64)),
    ])
}

fn filter_from_json(j: &Json) -> JobFilter {
    JobFilter {
        site: j.get("site").and_then(Json::as_u64).map(SiteId),
        states: j
            .get("states")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|s| s.as_str().and_then(JobState::from_name)).collect())
            .unwrap_or_default(),
        tags: j.get("tags").map(kv_from_json).unwrap_or_default(),
        limit: j.get("limit").and_then(Json::as_u64).unwrap_or(0) as usize,
    }
}

/// Decode a request envelope; the error string becomes the framed 400.
pub fn request_from_json(j: &Json) -> Result<ApiRequest, String> {
    let ty = j.get("type").and_then(Json::as_str).ok_or("missing type")?;
    let site = || j.get("site").and_then(Json::as_u64).map(SiteId).ok_or("missing site");
    let get_str = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    Ok(match ty {
        "CreateUser" => ApiRequest::CreateUser { name: get_str("name") },
        "CreateSite" => ApiRequest::CreateSite {
            name: get_str("name"),
            hostname: get_str("hostname"),
            path: get_str("path"),
        },
        "RegisterApp" => ApiRequest::RegisterApp {
            site: site()?,
            name: get_str("name"),
            command_template: get_str("command_template"),
            parameters: j
                .get("parameters")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        },
        "BulkCreateJobs" => ApiRequest::BulkCreateJobs {
            jobs: j
                .get("jobs")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|jc| JobCreate {
                            site_id: SiteId(jc.get("site_id").and_then(Json::as_u64).unwrap_or(0)),
                            app: jc.get("app").and_then(Json::as_str).unwrap_or("").into(),
                            workload: jc.get("workload").and_then(Json::as_str).unwrap_or("").into(),
                            num_nodes: jc.get("num_nodes").and_then(Json::as_u64).unwrap_or(1) as u32,
                            params: jc.get("params").map(kv_from_json).unwrap_or_default(),
                            tags: jc.get("tags").map(kv_from_json).unwrap_or_default(),
                            transfers_in: jc.get("transfers_in").map(xfers_from_json).unwrap_or_default(),
                            transfers_out: jc.get("transfers_out").map(xfers_from_json).unwrap_or_default(),
                            parents: jc
                                .get("parents")
                                .map(u64s_from_json)
                                .unwrap_or_default()
                                .into_iter()
                                .map(JobId)
                                .collect(),
                        })
                        .collect()
                })
                .unwrap_or_default(),
        },
        "ListJobs" => ApiRequest::ListJobs {
            filter: j.get("filter").map(filter_from_json).unwrap_or_default(),
        },
        "CountByState" => ApiRequest::CountByState { site: site()? },
        "UpdateJobState" => ApiRequest::UpdateJobState {
            job: JobId(j.get("job").and_then(Json::as_u64).ok_or("missing job")?),
            to: JobState::from_name(&get_str("to")).ok_or("bad state")?,
            data: get_str("data"),
        },
        "BulkUpdateJobState" => ApiRequest::BulkUpdateJobState {
            jobs: j.get("jobs").map(u64s_from_json).unwrap_or_default().into_iter().map(JobId).collect(),
            to: JobState::from_name(&get_str("to")).ok_or("bad state")?,
            data: get_str("data"),
        },
        "CreateSession" => ApiRequest::CreateSession {
            site: site()?,
            batch_job: j.get("batch_job").and_then(Json::as_u64).map(BatchJobId),
        },
        "SessionAcquire" => ApiRequest::SessionAcquire {
            session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
            max_nodes: j.get("max_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            max_jobs: j.get("max_jobs").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        "SessionHeartbeat" => ApiRequest::SessionHeartbeat {
            session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
        },
        "SessionSync" => {
            // Strict decode: a malformed tuple is a request error, not a
            // silent drop — the endpoint's contract is that every update
            // is either applied or reported back in the failed list.
            let mut updates = Vec::new();
            if let Some(a) = j.get("updates").and_then(Json::as_arr) {
                for u in a {
                    let job = u
                        .idx(0)
                        .and_then(Json::as_u64)
                        .ok_or("SessionSync update: bad job id")?;
                    let to = u
                        .idx(1)
                        .and_then(Json::as_str)
                        .and_then(JobState::from_name)
                        .ok_or("SessionSync update: bad state")?;
                    let data = u.idx(2).and_then(Json::as_str).unwrap_or("").to_string();
                    updates.push((JobId(job), to, data));
                }
            }
            ApiRequest::SessionSync {
                session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
                updates,
            }
        }
        "SessionEnd" => ApiRequest::SessionEnd {
            session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
        },
        "CreateBatchJob" => ApiRequest::CreateBatchJob {
            site: site()?,
            num_nodes: j.get("num_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            wall_time_s: j.get("wall_time_s").and_then(Json::as_f64).unwrap_or(0.0),
            mode: mode_from(&get_str("mode")),
            queue: get_str("queue"),
            project: get_str("project"),
        },
        "ListBatchJobs" => ApiRequest::ListBatchJobs {
            site: site()?,
            active_only: j.get("active_only").and_then(Json::as_bool).unwrap_or(false),
        },
        "UpdateBatchJob" => ApiRequest::UpdateBatchJob {
            id: BatchJobId(j.get("id").and_then(Json::as_u64).ok_or("missing id")?),
            state: bstate_from(&get_str("state")),
            local_id: j.get("local_id").and_then(Json::as_u64),
        },
        "PendingTransferItems" => ApiRequest::PendingTransferItems {
            site: site()?,
            direction: dir_from(&get_str("direction")),
            limit: j.get("limit").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        "UpdateTransferItems" => ApiRequest::UpdateTransferItems {
            ids: j.get("ids").map(u64s_from_json).unwrap_or_default().into_iter().map(TransferItemId).collect(),
            state: tstate_from(&get_str("state")),
            task_id: j.get("task_id").and_then(Json::as_u64).map(XferTaskId),
        },
        "SyncTransferItems" => {
            // Strict decode: an unknown state string must not default to
            // Pending (that would silently reset a live item).
            let mut updates = Vec::new();
            if let Some(a) = j.get("updates").and_then(Json::as_arr) {
                for u in a {
                    let id = u
                        .idx(0)
                        .and_then(Json::as_u64)
                        .ok_or("SyncTransferItems update: bad item id")?;
                    let state = u
                        .idx(1)
                        .and_then(Json::as_str)
                        .and_then(TransferState::from_name)
                        .ok_or("SyncTransferItems update: bad state")?;
                    let task = u.idx(2).and_then(Json::as_u64).map(XferTaskId);
                    updates.push((TransferItemId(id), state, task));
                }
            }
            ApiRequest::SyncTransferItems { updates }
        }
        "SiteBacklog" => ApiRequest::SiteBacklog { site: site()? },
        "ListEvents" => ApiRequest::ListEvents {
            since: j.get("since").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        // A missing/garbled timeout degrades to a non-blocking probe (0),
        // never to an accidental server-side hang. A missing `max_events`
        // (old client) is 0 = server default — wire back-compat for the
        // page-credit field.
        "WatchEvents" => ApiRequest::WatchEvents {
            site: j.get("site").and_then(Json::as_u64).map(SiteId),
            since: j.get("since").and_then(Json::as_u64).unwrap_or(0) as usize,
            timeout_ms: j.get("timeout_ms").and_then(Json::as_u64).unwrap_or(0),
            max_events: j.get("max_events").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        other => return Err(format!("unknown request type {other}")),
    })
}

/// Encode a success envelope as `{"ok":true,"type":...,"body":...}`.
pub fn response_to_json(resp: &ApiResponse) -> Json {
    use ApiResponse::*;
    let (ty, body) = match resp {
        Unit => ("Unit", Json::Null),
        UserId(x) => ("UserId", Json::num(x.0 as f64)),
        SiteId(x) => ("SiteId", Json::num(x.0 as f64)),
        AppId(x) => ("AppId", Json::num(x.0 as f64)),
        JobIds(x) => ("JobIds", ids_to_json(x, |i| i.0)),
        Jobs(x) => ("Jobs", Json::Arr(x.iter().map(Job::to_json).collect())),
        Counts(x) => (
            "Counts",
            Json::Arr(
                x.iter()
                    .map(|(s, n)| Json::arr([Json::str(s.name()), Json::num(*n as f64)]))
                    .collect(),
            ),
        ),
        SessionId(x) => ("SessionId", Json::num(x.0 as f64)),
        BatchJobId(x) => ("BatchJobId", Json::num(x.0 as f64)),
        BatchJobs(x) => ("BatchJobs", Json::Arr(x.iter().map(BatchJob::to_json).collect())),
        TransferItems(x) => ("TransferItems", Json::Arr(x.iter().map(TransferItem::to_json).collect())),
        Backlog(b) => (
            "Backlog",
            Json::obj(vec![
                ("backlog_jobs", Json::num(b.backlog_jobs as f64)),
                ("runnable_nodes", Json::num(b.runnable_nodes as f64)),
                ("inflight_nodes", Json::num(b.inflight_nodes as f64)),
                ("batch_nodes", Json::num(b.batch_nodes as f64)),
            ]),
        ),
        // The legacy wire shape (a bare array) is kept whenever there is
        // no truncation to report — the overwhelmingly common case — so
        // pre-retention clients keep working against a new service; the
        // object shape only appears once retention (a new-server opt-in)
        // actually dropped history.
        Events(p) => (
            "Events",
            match p.truncated_before {
                None => Json::Arr(p.events.iter().map(Event::to_json).collect()),
                Some(n) => Json::obj(vec![
                    ("truncated_before", Json::num(n as f64)),
                    ("events", Json::Arr(p.events.iter().map(Event::to_json).collect())),
                ]),
            },
        ),
    };
    Json::obj(vec![("ok", Json::Bool(true)), ("type", Json::str(ty)), ("body", body)])
}

/// Decode a response envelope; an error envelope (or unknown type)
/// becomes [`ApiError::Transport`].
pub fn response_from_json(j: &Json) -> Result<ApiResponse, ApiError> {
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown").to_string();
        return Err(ApiError::Transport(msg));
    }
    let ty = j.get("type").and_then(Json::as_str).unwrap_or("");
    let b = j.get("body").unwrap_or(&Json::Null);
    let u = |b: &Json| b.as_u64().unwrap_or(0);
    Ok(match ty {
        "Unit" => ApiResponse::Unit,
        "UserId" => ApiResponse::UserId(UserId(u(b))),
        "SiteId" => ApiResponse::SiteId(SiteId(u(b))),
        "AppId" => ApiResponse::AppId(AppId(u(b))),
        "SessionId" => ApiResponse::SessionId(SessionId(u(b))),
        "BatchJobId" => ApiResponse::BatchJobId(BatchJobId(u(b))),
        "JobIds" => ApiResponse::JobIds(u64s_from_json(b).into_iter().map(JobId).collect()),
        "Jobs" => ApiResponse::Jobs(b.as_arr().unwrap_or(&[]).iter().map(Job::from_json).collect()),
        "Counts" => ApiResponse::Counts(
            b.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|p| {
                    Some((
                        JobState::from_name(p.idx(0)?.as_str()?)?,
                        p.idx(1)?.as_u64()? as usize,
                    ))
                })
                .collect(),
        ),
        "BatchJobs" => {
            ApiResponse::BatchJobs(b.as_arr().unwrap_or(&[]).iter().map(BatchJob::from_json).collect())
        }
        "TransferItems" => {
            ApiResponse::TransferItems(b.as_arr().unwrap_or(&[]).iter().map(TransferItem::from_json).collect())
        }
        "Backlog" => ApiResponse::Backlog(Backlog {
            backlog_jobs: b.get("backlog_jobs").and_then(Json::as_u64).unwrap_or(0) as usize,
            runnable_nodes: b.get("runnable_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            inflight_nodes: b.get("inflight_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            batch_nodes: b.get("batch_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
        }),
        // Current shape: {"truncated_before": n|null, "events": [...]}.
        // A bare array is the pre-retention wire shape (an older peer):
        // accept it so version skew degrades to "no truncation info"
        // instead of a silently empty page.
        "Events" => ApiResponse::Events(EventsPage {
            truncated_before: b.get("truncated_before").and_then(Json::as_u64),
            events: b
                .get("events")
                .and_then(Json::as_arr)
                .or_else(|| b.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(Event::from_json)
                .collect(),
        }),
        other => return Err(ApiError::Transport(format!("unknown response type {other}"))),
    })
}
