//! Durable per-shard WAL + snapshot store backend (the paper's PostgreSQL
//! role, §4.2).
//!
//! The sharded [`super::store::Store`] keeps every table in memory; this
//! module makes that state survive process death so launchers can
//! reconnect across service restarts. The layout mirrors the sharding:
//! **one append-only log per site shard plus one for the global tables**
//! (`site-<id>.wal` / `global.wal`), with periodic compacting snapshots
//! (`site-<id>.snap` / `global.snap`).
//!
//! Records are *physical* row upserts ([`WalRecord`]: full rows encoded
//! with the [`super::models`] JSON codecs) plus event appends carrying
//! their already-allocated global sequence numbers. Replay therefore
//! reconstructs shards, routing tables and the id / event-sequence
//! counters exactly — including cross-shard event interleavings that
//! logical op replay could not reproduce.
//!
//! Framing and crash tolerance:
//! * every WAL line is one **atomic batch** — `{"lsn": n, "batch":
//!   [{...}, ...]}` holding every row + event of a single store
//!   mutation, so a compound operation (session acquire, transition with
//!   consequences) commits or rolls back as a unit; a torn prefix can
//!   never recover a session/job pair that disagrees. The per-shard LSN
//!   is allocated under the shard's write lock, so file order equals
//!   apply order within a shard;
//! * appends are a single `write + flush` per store mutation (durable to
//!   the OS; an fsync-per-record policy would serialize the hot path);
//! * a torn final line (crash mid-append) is detected and dropped on
//!   recovery; corruption anywhere earlier is a hard error;
//! * snapshot rotation writes `*.snap.tmp`, fsyncs, renames, then
//!   truncates the WAL. The snapshot header records the highest LSN it
//!   covers, and recovery skips WAL records at or below it — so a crash
//!   between rename and truncate replays idempotently.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::util::error::Context;
use crate::util::json::Json;
use crate::{bail, err};

use super::models::*;

/// Default mutations-per-shard between compacting snapshots.
pub const DEFAULT_SNAPSHOT_EVERY: u64 = 4096;

/// Store durability mode, selectable at `ServiceCore` construction and
/// threaded through the `balsam service` CLI flags.
#[derive(Debug, Clone)]
pub enum PersistMode {
    /// In-memory only (simulations, benches, tests): state dies with the
    /// process.
    Ephemeral,
    /// Per-shard write-ahead log + snapshots under `dir`; reopening the
    /// same dir recovers the full store. `snapshot_every` counts WAL
    /// records per shard between compactions (0 = never compact).
    Wal { dir: PathBuf, snapshot_every: u64 },
}

/// One durable record: a full-row upsert or an event append.
#[derive(Debug, Clone)]
pub enum WalRecord {
    User(User),
    Site(Site),
    App(App),
    Job(Job),
    Session(Session),
    Batch(BatchJob),
    Titem(TransferItem),
    Event(Event),
}

impl WalRecord {
    pub fn to_json(&self) -> Json {
        let (t, r) = match self {
            WalRecord::User(x) => ("user", x.to_json()),
            WalRecord::Site(x) => ("site", x.to_json()),
            WalRecord::App(x) => ("app", x.to_json()),
            WalRecord::Job(x) => ("job", x.to_json()),
            WalRecord::Session(x) => ("session", x.to_json()),
            WalRecord::Batch(x) => ("batch", x.to_json()),
            WalRecord::Titem(x) => ("titem", x.to_json()),
            WalRecord::Event(x) => ("event", x.to_json()),
        };
        Json::obj(vec![("t", Json::str(t)), ("r", r)])
    }

    pub fn from_json(j: &Json) -> Option<WalRecord> {
        let t = j.get("t")?.as_str()?;
        let r = j.get("r")?;
        Some(match t {
            "user" => WalRecord::User(User::from_json(r)),
            "site" => WalRecord::Site(Site::from_json(r)),
            "app" => WalRecord::App(App::from_json(r)),
            "job" => WalRecord::Job(Job::from_json(r)),
            "session" => WalRecord::Session(Session::from_json(r)),
            "batch" => WalRecord::Batch(BatchJob::from_json(r)),
            "titem" => WalRecord::Titem(TransferItem::from_json(r)),
            "event" => WalRecord::Event(Event::from_json(r)),
            _ => return None,
        })
    }
}

/// Which log a record belongs to: `None` = global tables, `Some(site)` =
/// that site's shard.
pub type ShardKey = Option<SiteId>;

fn file_stem(key: ShardKey) -> String {
    match key {
        None => "global".to_string(),
        Some(site) => format!("site-{}", site.0),
    }
}

/// WAL file path for `key` under `dir` (exposed for tests / tooling).
pub fn wal_path(dir: &Path, key: ShardKey) -> PathBuf {
    dir.join(format!("{}.wal", file_stem(key)))
}

/// Snapshot file path for `key` under `dir`.
pub fn snap_path(dir: &Path, key: ShardKey) -> PathBuf {
    dir.join(format!("{}.snap", file_stem(key)))
}

struct WalFile {
    writer: BufWriter<File>,
    /// Next LSN to allocate (per-shard, 1-based).
    next_lsn: u64,
    /// Records appended since the last snapshot compaction.
    since_snapshot: u64,
}

/// Open WAL/snapshot files for one store. One writer per shard key, each
/// behind its own mutex; the store appends while holding the owning
/// shard's write lock, so per-shard record order equals apply order.
pub struct Persist {
    dir: PathBuf,
    snapshot_every: u64,
    files: Mutex<BTreeMap<ShardKey, Arc<Mutex<WalFile>>>>,
}

impl std::fmt::Debug for Persist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Persist")
            .field("dir", &self.dir)
            .field("snapshot_every", &self.snapshot_every)
            .finish()
    }
}

/// Split a log byte stream into complete newline-terminated records.
/// Returns `(records, had_partial_tail)`; a final unterminated fragment
/// (crash mid-append) is excluded from the records.
fn split_records(bytes: &[u8]) -> (Vec<&[u8]>, bool) {
    if bytes.is_empty() {
        return (Vec::new(), false);
    }
    let mut segs: Vec<&[u8]> = bytes.split(|b| *b == b'\n').collect();
    let partial = !bytes.ends_with(b"\n");
    segs.pop(); // trailing empty segment, or the partial fragment
    (segs.into_iter().filter(|l| !l.is_empty()).collect(), partial)
}

/// Parse one log line: a WAL batch (`{"lsn": n, "batch": [...]}`) or a
/// snapshot row (`{"rec": {...}}`, lsn 0).
fn parse_line(line: &[u8]) -> Option<(u64, Vec<WalRecord>)> {
    let text = std::str::from_utf8(line).ok()?;
    let j = Json::parse(text).ok()?;
    let lsn = j.get("lsn").and_then(Json::as_u64).unwrap_or(0);
    if let Some(batch) = j.get("batch").and_then(Json::as_arr) {
        let mut recs = Vec::with_capacity(batch.len());
        for r in batch {
            recs.push(WalRecord::from_json(r)?);
        }
        return Some((lsn, recs));
    }
    let rec = WalRecord::from_json(j.get("rec")?)?;
    Some((lsn, vec![rec]))
}

impl Persist {
    /// Open (creating if needed) a persistence dir and recover its state.
    /// Returns the recovered records per shard key, global tables first,
    /// in apply order. Feed them to the store, then start appending.
    pub fn open(dir: &Path, snapshot_every: u64) -> crate::Result<(Persist, Vec<(ShardKey, Vec<WalRecord>)>)> {
        fs::create_dir_all(dir).with_context(|| format!("create persist dir {}", dir.display()))?;
        let mut keys: BTreeSet<ShardKey> = BTreeSet::new();
        for entry in fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            let stem = match name.strip_suffix(".wal").or_else(|| name.strip_suffix(".snap")) {
                Some(s) => s,
                None => continue,
            };
            if stem == "global" {
                keys.insert(None);
            } else if let Some(n) = stem.strip_prefix("site-").and_then(|s| s.parse::<u64>().ok()) {
                keys.insert(Some(SiteId(n)));
            }
        }
        let persist =
            Persist { dir: dir.to_path_buf(), snapshot_every, files: Mutex::new(BTreeMap::new()) };
        let mut recovered = Vec::new();
        // BTreeSet order puts None (global) first: site rows create their
        // shards before any shard rows are applied.
        for key in keys {
            let (records, next_lsn, since_snapshot) = persist.recover_key(key)?;
            persist.install_writer(key, next_lsn, since_snapshot)?;
            recovered.push((key, records));
        }
        Ok((persist, recovered))
    }

    /// Recover one key: snapshot records first, then the WAL tail above
    /// the snapshot's covered LSN. Returns (records, next_lsn,
    /// records_since_snapshot).
    fn recover_key(&self, key: ShardKey) -> crate::Result<(Vec<WalRecord>, u64, u64)> {
        let mut records = Vec::new();
        let mut snap_lsn = 0u64;
        let mut max_lsn = 0u64;
        let spath = snap_path(&self.dir, key);
        match fs::read(&spath) {
            Ok(bytes) => {
                let (lines, partial) = split_records(&bytes);
                if partial {
                    bail!("corrupt snapshot {} (unterminated record)", spath.display());
                }
                let mut it = lines.into_iter();
                if let Some(hdr) = it.next() {
                    let text = std::str::from_utf8(hdr)
                        .map_err(|_| err!("corrupt snapshot header in {}", spath.display()))?;
                    let j = Json::parse(text)
                        .map_err(|e| err!("corrupt snapshot header in {}: {e}", spath.display()))?;
                    snap_lsn = j
                        .get("snap_lsn")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| err!("snapshot {} missing snap_lsn", spath.display()))?;
                    max_lsn = snap_lsn;
                    for line in it {
                        let (_, recs) = parse_line(line)
                            .ok_or_else(|| err!("corrupt snapshot record in {}", spath.display()))?;
                        records.extend(recs);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => bail!("read {}: {e}", spath.display()),
        }
        let wpath = wal_path(&self.dir, key);
        let mut wal_count = 0u64;
        match fs::read(&wpath) {
            Ok(bytes) => {
                let mut pos = 0usize;
                let mut valid_len = 0usize;
                while pos < bytes.len() {
                    let Some(rel) = bytes[pos..].iter().position(|b| *b == b'\n') else {
                        break; // unterminated fragment: crash mid-append
                    };
                    let line = &bytes[pos..pos + rel];
                    let line_end = pos + rel + 1;
                    if line.is_empty() {
                        valid_len = line_end;
                        pos = line_end;
                        continue;
                    }
                    match parse_line(line) {
                        Some((lsn, recs)) => {
                            if lsn > snap_lsn {
                                wal_count += recs.len() as u64;
                                records.extend(recs);
                                max_lsn = max_lsn.max(lsn);
                            }
                            valid_len = line_end;
                            pos = line_end;
                        }
                        // A complete line that fails to parse is tolerated
                        // only in final position (torn batch tail);
                        // anywhere else it is real corruption.
                        None if line_end == bytes.len() => break,
                        None => bail!("corrupt WAL record in {} at byte {pos}", wpath.display()),
                    }
                }
                if valid_len < bytes.len() {
                    // Drop the torn tail now, so the reopened writer
                    // starts on a record boundary — otherwise the next
                    // append would concatenate onto the fragment and
                    // poison the log for the following recovery.
                    let f = OpenOptions::new()
                        .write(true)
                        .open(&wpath)
                        .with_context(|| format!("open {}", wpath.display()))?;
                    f.set_len(valid_len as u64)
                        .with_context(|| format!("truncate {}", wpath.display()))?;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => bail!("read {}: {e}", wpath.display()),
        }
        Ok((records, max_lsn + 1, wal_count))
    }

    fn install_writer(&self, key: ShardKey, next_lsn: u64, since_snapshot: u64) -> crate::Result<()> {
        let path = wal_path(&self.dir, key);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("open {}", path.display()))?;
        self.files.lock().unwrap().insert(
            key,
            Arc::new(Mutex::new(WalFile { writer: BufWriter::new(file), next_lsn, since_snapshot })),
        );
        Ok(())
    }

    /// Append `records` to `key`'s WAL; the caller holds the owning shard
    /// write lock, so record order matches apply order. When the
    /// per-shard record budget is exhausted, `snapshot` is invoked (under
    /// the same lock — it sees exactly the logged state) and the log is
    /// compacted. A dead disk panics: a durability-mode service must not
    /// silently keep running without its log.
    pub fn append(&self, key: ShardKey, records: &[WalRecord], snapshot: impl FnOnce() -> Vec<WalRecord>) {
        if records.is_empty() {
            return;
        }
        let file = {
            let mut files = self.files.lock().unwrap();
            files
                .entry(key)
                .or_insert_with(|| {
                    let path = wal_path(&self.dir, key);
                    let f = OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(&path)
                        .unwrap_or_else(|e| panic!("open {}: {e}", path.display()));
                    Arc::new(Mutex::new(WalFile {
                        writer: BufWriter::new(f),
                        next_lsn: 1,
                        since_snapshot: 0,
                    }))
                })
                .clone()
        };
        let mut wf = file.lock().unwrap();
        // One line = one atomic batch: the whole mutation (rows + events)
        // commits or is rolled back together by torn-tail recovery.
        let line = Json::obj(vec![
            ("lsn", Json::num(wf.next_lsn as f64)),
            ("batch", Json::Arr(records.iter().map(WalRecord::to_json).collect())),
        ]);
        wf.next_lsn += 1;
        let mut buf = line.to_string();
        buf.push('\n');
        wf.writer.write_all(buf.as_bytes()).expect("wal append");
        wf.writer.flush().expect("wal flush");
        wf.since_snapshot += records.len() as u64;
        if self.snapshot_every > 0 && wf.since_snapshot >= self.snapshot_every {
            self.rotate(key, &mut wf, snapshot());
        }
    }

    /// Write a compacting snapshot covering everything logged so far,
    /// then truncate the WAL. Failure is reported but non-fatal: the WAL
    /// keeps the full history and rotation retries at the next threshold.
    fn rotate(&self, key: ShardKey, wf: &mut WalFile, records: Vec<WalRecord>) {
        let covered = wf.next_lsn - 1;
        let tmp = self.dir.join(format!("{}.snap.tmp", file_stem(key)));
        let snap = snap_path(&self.dir, key);
        let mut out = String::new();
        out.push_str(&Json::obj(vec![("snap_lsn", Json::num(covered as f64))]).to_string());
        out.push('\n');
        for rec in &records {
            out.push_str(&Json::obj(vec![("rec", rec.to_json())]).to_string());
            out.push('\n');
        }
        let result = (|| -> std::io::Result<()> {
            let mut f = File::create(&tmp)?;
            f.write_all(out.as_bytes())?;
            f.sync_all()?;
            fs::rename(&tmp, &snap)?;
            let fresh = File::create(wal_path(&self.dir, key))?;
            wf.writer = BufWriter::new(fresh);
            wf.since_snapshot = 0;
            Ok(())
        })();
        if let Err(e) = result {
            eprintln!("wal snapshot rotation failed for {}: {e}", file_stem(key));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("balsam-persist-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn job(id: u64, state: JobState) -> Job {
        Job {
            id: JobId(id),
            site_id: SiteId(1),
            app_id: AppId(1),
            state,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "md_small".into(),
            parents: vec![],
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: 0.0,
        }
    }

    fn rec_strings(records: &[WalRecord]) -> Vec<String> {
        records.iter().map(|r| r.to_json().to_string()).collect()
    }

    #[test]
    fn record_json_roundtrip() {
        let recs = vec![
            WalRecord::User(User { id: UserId(1), name: "admin".into() }),
            WalRecord::Job(job(5, JobState::Ready)),
            WalRecord::Event(Event {
                seq: 3,
                job_id: JobId(5),
                site_id: SiteId(1),
                ts: 2.0,
                from: JobState::Created,
                to: JobState::Ready,
                data: "".into(),
            }),
        ];
        for r in &recs {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            let back = WalRecord::from_json(&j).unwrap();
            assert_eq!(back.to_json().to_string(), r.to_json().to_string());
        }
        assert!(WalRecord::from_json(&Json::obj(vec![("t", Json::str("nope"))])).is_none());
    }

    #[test]
    fn split_records_handles_partial_tail() {
        let (lines, partial) = split_records(b"a\nb\n");
        assert_eq!(lines, vec![b"a".as_slice(), b"b".as_slice()]);
        assert!(!partial);
        let (lines, partial) = split_records(b"a\nbroken");
        assert_eq!(lines, vec![b"a".as_slice()]);
        assert!(partial);
        let (lines, partial) = split_records(b"");
        assert!(lines.is_empty());
        assert!(!partial);
    }

    #[test]
    fn append_and_recover_roundtrip() {
        let dir = tmpdir("roundtrip");
        let key = Some(SiteId(1));
        let written = vec![
            WalRecord::Job(job(5, JobState::Ready)),
            WalRecord::Job(job(5, JobState::StagedIn)),
            WalRecord::Job(job(6, JobState::Created)),
        ];
        {
            let (p, recovered) = Persist::open(&dir, 0).unwrap();
            assert!(recovered.is_empty());
            p.append(key, &written, Vec::new);
            p.append(None, &[WalRecord::User(User { id: UserId(1), name: "admin".into() })], Vec::new);
        }
        let (_p, recovered) = Persist::open(&dir, 0).unwrap();
        assert_eq!(recovered.len(), 2);
        assert_eq!(recovered[0].0, None);
        assert_eq!(recovered[1].0, key);
        assert_eq!(rec_strings(&recovered[1].1), rec_strings(&written));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_rotation_compacts_and_recovers() {
        let dir = tmpdir("rotate");
        let key = Some(SiteId(1));
        {
            let (p, _) = Persist::open(&dir, 2).unwrap();
            // Threshold 2: this append rotates, compacting to one row.
            p.append(key, &[WalRecord::Job(job(5, JobState::Ready)), WalRecord::Job(job(5, JobState::StagedIn))], || {
                vec![WalRecord::Job(job(5, JobState::StagedIn))]
            });
            // Post-rotation append lands in the fresh WAL.
            p.append(key, &[WalRecord::Job(job(6, JobState::Created))], Vec::new);
        }
        assert!(snap_path(&dir, key).exists());
        let (_p, recovered) = Persist::open(&dir, 2).unwrap();
        assert_eq!(recovered.len(), 1);
        assert_eq!(
            rec_strings(&recovered[0].1),
            rec_strings(&[
                WalRecord::Job(job(5, JobState::StagedIn)),
                WalRecord::Job(job(6, JobState::Created)),
            ])
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_record_is_dropped() {
        let dir = tmpdir("torn");
        let key = Some(SiteId(1));
        {
            let (p, _) = Persist::open(&dir, 0).unwrap();
            p.append(key, &[WalRecord::Job(job(5, JobState::Ready))], Vec::new);
        }
        // Simulate a crash mid-append: partial JSON, no trailing newline.
        let mut f = OpenOptions::new().append(true).open(wal_path(&dir, key)).unwrap();
        f.write_all(b"{\"lsn\":2,\"rec\":{\"t\":\"job\",\"r\":{\"id\":").unwrap();
        drop(f);
        {
            let (p, recovered) = Persist::open(&dir, 0).unwrap();
            assert_eq!(
                rec_strings(&recovered[0].1),
                rec_strings(&[WalRecord::Job(job(5, JobState::Ready))])
            );
            // The torn tail was truncated on open: appends start on a
            // record boundary and the log stays parseable.
            p.append(key, &[WalRecord::Job(job(6, JobState::Created))], Vec::new);
        }
        let (_p, recovered) = Persist::open(&dir, 0).unwrap();
        assert_eq!(recovered[0].1.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lsn_continues_after_recovery() {
        let dir = tmpdir("lsn");
        let key = Some(SiteId(1));
        {
            let (p, _) = Persist::open(&dir, 0).unwrap();
            p.append(key, &[WalRecord::Job(job(5, JobState::Ready))], Vec::new);
        }
        {
            let (p, _) = Persist::open(&dir, 0).unwrap();
            p.append(key, &[WalRecord::Job(job(6, JobState::Ready))], Vec::new);
        }
        let (_p, recovered) = Persist::open(&dir, 0).unwrap();
        assert_eq!(recovered[0].1.len(), 2, "no records lost across reopen");
        let _ = fs::remove_dir_all(&dir);
    }
}
