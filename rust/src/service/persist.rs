//! Durable per-shard WAL + snapshot store backend (the paper's PostgreSQL
//! role, §4.2), with a **group-commit fsync pipeline** and a **segmented
//! append-only event log**.
//!
//! The sharded [`super::store::Store`] keeps every table in memory; this
//! module makes that state survive process death so launchers can
//! reconnect across service restarts. The layout mirrors the sharding —
//! per shard key there are now *three* kinds of files:
//!
//! * `site-<id>.wal` / `global.wal` — the write-ahead log: one atomic
//!   JSON batch per store mutation (rows + events);
//! * `site-<id>.snap` / `global.snap` — compacting snapshots holding
//!   **live rows only** (zero event records), so rotation cost is
//!   O(live rows), not O(all events ever);
//! * `site-<id>.events.0001`, `.0002`, … — the segmented event log:
//!   events are moved here at every snapshot rotation and **never
//!   compacted**. Sealed segments are immutable; a size/age retention
//!   policy may drop the oldest ones, and readers get an explicit
//!   "truncated before seq N" marker instead of silently missing events.
//!
//! Records are *physical* row upserts ([`WalRecord`]: full rows encoded
//! with the [`super::models`] JSON codecs) plus event appends carrying
//! their already-allocated global sequence numbers. Replay therefore
//! reconstructs shards, routing tables and the id / event-sequence
//! counters exactly — including cross-shard event interleavings that
//! logical op replay could not reproduce.
//!
//! Durability ([`FsyncPolicy`]):
//! * `Never` — appends are a single `write + flush` per store mutation
//!   (durable to the OS: a process crash loses nothing, a power loss can
//!   lose the tail);
//! * `Always` — every append is fsynced before the mutation returns;
//! * `Group { records, interval_ms }` — **group commit**: a mutation's
//!   append is acknowledged only once an fsync covers it, but fsyncs are
//!   shared. The first committer to wait becomes the *leader* and fsyncs
//!   with the log mutex released, so every append that lands during the
//!   fsync joins the next group; followers re-check every `interval_ms`
//!   ms (a missed-wakeup guard) and the first to find the device free
//!   leads the next group.
//!
//! Failure policy: any WAL/segment I/O error **poisons** the handle —
//! the first error is recorded, every subsequent append fails fast, and
//! the service layer turns the poisoned state into framed 500 responses
//! instead of silently diverging from the log.
//!
//! Framing and crash tolerance:
//! * every WAL line is one **atomic batch** — `{"lsn": n, "batch":
//!   [{...}, ...]}` holding every row + event of a single store
//!   mutation, so a compound operation commits or rolls back as a unit;
//! * a torn final line (crash mid-append) is detected and dropped on
//!   recovery — in `Group` mode that means losing at most the final
//!   un-fsynced group; corruption anywhere earlier is a hard error;
//! * snapshot rotation archives the un-archived events to the active
//!   segment (fsynced), writes `*.snap.tmp`, fsyncs, renames, then
//!   truncates the WAL. The snapshot header records the highest LSN it
//!   covers and recovery skips WAL records at or below it; WAL events
//!   whose seq is already covered by the segments are deduplicated — so
//!   a crash anywhere in the rotation window replays idempotently.

use std::collections::{BTreeMap, BTreeSet};
use std::fs::{self, File, OpenOptions};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::util::error::Context;
use crate::util::json::Json;
use crate::util::metrics;
use crate::{bail, err};

use super::models::*;

/// Default mutations-per-shard between compacting snapshots.
pub const DEFAULT_SNAPSHOT_EVERY: u64 = 4096;

/// When a mutation's append must be fsynced before it is acknowledged.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FsyncPolicy {
    /// `write + flush` only: durable to the OS page cache. A process
    /// crash loses nothing; a power loss can lose the un-synced tail.
    #[default]
    Never,
    /// fsync every append before acknowledging (maximum durability,
    /// serializes the hot path).
    Always,
    /// Group commit: acknowledgements wait for an fsync, but concurrent
    /// commits share fsyncs — the first waiter leads and fsyncs with all
    /// locks released, so a group naturally collects every append that
    /// lands during the previous fsync. `records` is an advisory
    /// upper-bound tuning knob (groups close as fast as the device
    /// allows, almost always far below it); `interval_ms` is the
    /// follower re-check period — it guards against a missed wakeup, so
    /// a follower leads at most `interval_ms` after the device becomes
    /// free. (An fsync that never returns — a hung device — stalls the
    /// shard's commits; no policy can acknowledge past a dead disk.)
    Group { records: u64, interval_ms: u64 },
}

impl FsyncPolicy {
    pub const DEFAULT_GROUP_RECORDS: u64 = 64;
    pub const DEFAULT_GROUP_INTERVAL_MS: u64 = 5;

    /// Parse a CLI / env spec: `never` (alias `flush`), `always`,
    /// `group` (defaults), or `group:K,T` / `group:K,Tms`.
    pub fn parse(s: &str) -> Option<FsyncPolicy> {
        match s {
            "never" | "flush" => return Some(FsyncPolicy::Never),
            "always" => return Some(FsyncPolicy::Always),
            "group" => {
                return Some(FsyncPolicy::Group {
                    records: FsyncPolicy::DEFAULT_GROUP_RECORDS,
                    interval_ms: FsyncPolicy::DEFAULT_GROUP_INTERVAL_MS,
                })
            }
            _ => {}
        }
        let spec = s.strip_prefix("group:")?;
        let (k, t) = spec.split_once(',')?;
        let records = k.trim().parse::<u64>().ok()?;
        let t = t.trim();
        let interval_ms = t.strip_suffix("ms").unwrap_or(t).trim().parse::<u64>().ok()?;
        (records > 0).then_some(FsyncPolicy::Group { records, interval_ms })
    }

    /// Short label for bench records / logs.
    pub fn label(&self) -> &'static str {
        match self {
            FsyncPolicy::Never => "flush",
            FsyncPolicy::Always => "always",
            FsyncPolicy::Group { .. } => "group",
        }
    }
}

/// Segmented event-log sizing + retention knobs.
#[derive(Debug, Clone)]
pub struct EventLogConfig {
    /// Seal the active segment once it exceeds this many bytes.
    pub segment_bytes: u64,
    /// 0 = keep everything; otherwise drop the oldest sealed segments
    /// once a shard's total segment bytes exceed this.
    pub retain_bytes: u64,
    /// 0 = keep everything; otherwise drop sealed segments whose last
    /// write is older than this many seconds. Retention is evaluated at
    /// every archive (snapshot rotation) and on every reopen — a shard
    /// idle for an entire process lifetime sheds aged segments at the
    /// next restart.
    pub retain_age_s: u64,
}

impl Default for EventLogConfig {
    fn default() -> EventLogConfig {
        EventLogConfig { segment_bytes: 4 << 20, retain_bytes: 0, retain_age_s: 0 }
    }
}

/// Store durability mode, selectable at `ServiceCore` construction and
/// threaded through the `balsam service` CLI flags.
#[derive(Debug, Clone)]
pub enum PersistMode {
    /// In-memory only (simulations, benches, tests): state dies with the
    /// process.
    Ephemeral,
    /// Per-shard write-ahead log + snapshots + event segments under
    /// `dir`; reopening the same dir recovers the full store.
    /// `snapshot_every` counts WAL records per shard between compactions
    /// (0 = never compact — events then stay in the WAL).
    Wal { dir: PathBuf, snapshot_every: u64, fsync: FsyncPolicy, events: EventLogConfig },
}

impl PersistMode {
    /// WAL mode with default snapshot / fsync / event-log settings.
    pub fn wal(dir: impl Into<PathBuf>) -> PersistMode {
        PersistMode::Wal {
            dir: dir.into(),
            snapshot_every: DEFAULT_SNAPSHOT_EVERY,
            fsync: FsyncPolicy::default(),
            events: EventLogConfig::default(),
        }
    }
}

/// One durable record: a full-row upsert or an event append.
#[derive(Debug, Clone)]
pub enum WalRecord {
    User(User),
    Site(Site),
    App(App),
    Job(Job),
    Session(Session),
    Batch(BatchJob),
    Titem(TransferItem),
    Event(Event),
}

impl WalRecord {
    pub fn to_json(&self) -> Json {
        let (t, r) = match self {
            WalRecord::User(x) => ("user", x.to_json()),
            WalRecord::Site(x) => ("site", x.to_json()),
            WalRecord::App(x) => ("app", x.to_json()),
            WalRecord::Job(x) => ("job", x.to_json()),
            WalRecord::Session(x) => ("session", x.to_json()),
            WalRecord::Batch(x) => ("batch", x.to_json()),
            WalRecord::Titem(x) => ("titem", x.to_json()),
            WalRecord::Event(x) => ("event", x.to_json()),
        };
        Json::obj(vec![("t", Json::str(t)), ("r", r)])
    }

    pub fn from_json(j: &Json) -> Option<WalRecord> {
        let t = j.get("t")?.as_str()?;
        let r = j.get("r")?;
        Some(match t {
            "user" => WalRecord::User(User::from_json(r)),
            "site" => WalRecord::Site(Site::from_json(r)),
            "app" => WalRecord::App(App::from_json(r)),
            "job" => WalRecord::Job(Job::from_json(r)),
            "session" => WalRecord::Session(Session::from_json(r)),
            "batch" => WalRecord::Batch(BatchJob::from_json(r)),
            "titem" => WalRecord::Titem(TransferItem::from_json(r)),
            "event" => WalRecord::Event(Event::from_json(r)),
            _ => return None,
        })
    }
}

/// Which log a record belongs to: `None` = global tables, `Some(site)` =
/// that site's shard.
pub type ShardKey = Option<SiteId>;

fn file_stem(key: ShardKey) -> String {
    match key {
        None => "global".to_string(),
        Some(site) => format!("site-{}", site.0),
    }
}

/// WAL file path for `key` under `dir` (exposed for tests / tooling).
pub fn wal_path(dir: &Path, key: ShardKey) -> PathBuf {
    dir.join(format!("{}.wal", file_stem(key)))
}

/// Snapshot file path for `key` under `dir`.
pub fn snap_path(dir: &Path, key: ShardKey) -> PathBuf {
    dir.join(format!("{}.snap", file_stem(key)))
}

/// Event-log segment path for `key` under `dir` (exposed for tests).
pub fn segment_path(dir: &Path, key: ShardKey, segno: u64) -> PathBuf {
    dir.join(format!("{}.events.{:04}", file_stem(key), segno))
}

/// Metadata for one event-log segment (the last entry is the active one).
#[derive(Debug, Clone)]
struct SegmentMeta {
    no: u64,
    /// Seq of the segment's first event (`u64::MAX` while still empty).
    first_seq: u64,
    bytes: u64,
}

/// Per-shard segmented event log state (behind the shard's WAL mutex).
#[derive(Debug, Default)]
struct EventLog {
    /// Sealed + active segments, ascending by number.
    segments: Vec<SegmentMeta>,
    /// Writer for the active (= last) segment; opened lazily.
    writer: Option<BufWriter<File>>,
    active_bytes: u64,
    /// Highest event seq safely archived to segments.
    archived_through: Option<u64>,
    /// Retention dropped this shard's events below this seq.
    truncated_before: Option<u64>,
}

struct WalFile {
    writer: BufWriter<File>,
    /// Duplicate handle used by group-commit leaders to fsync with the
    /// mutex released (committers keep appending into the next group).
    sync_fd: Arc<File>,
    /// Next LSN to allocate (per-shard, 1-based).
    next_lsn: u64,
    /// Records appended since the last snapshot compaction.
    since_snapshot: u64,
    /// Highest LSN written + flushed to the OS.
    appended_lsn: u64,
    /// Highest LSN known fsynced (tracked for Group/Always policies).
    durable_lsn: u64,
    /// A group fsync is in flight (the leader holds no lock meanwhile).
    sync_running: bool,
    /// Incremented on rotation so an in-flight leader's bookkeeping from
    /// the pre-rotation file is discarded.
    epoch: u64,
    /// WAL bytes written since open / last rotation.
    bytes_written: u64,
    /// WAL length at the last fsync — the bytes that survive power loss
    /// (exposed via [`Persist::durable_wal_len`] for crash-simulation
    /// tests; meaningful under `Group` / `Always` only).
    durable_bytes: u64,
    events: EventLog,
}

struct WalCell {
    wal: Mutex<WalFile>,
    cv: Condvar,
}

/// First-I/O-error latch: once set, every append fails fast and the
/// service layer surfaces 500s instead of diverging from the log.
struct Poison {
    flag: AtomicBool,
    msg: Mutex<Option<String>>,
}

impl Poison {
    fn new() -> Arc<Poison> {
        Arc::new(Poison { flag: AtomicBool::new(false), msg: Mutex::new(None) })
    }

    fn set(&self, msg: String) {
        let mut m = self.msg.lock().unwrap();
        if m.is_none() {
            eprintln!("persist: poisoned: {msg}");
            *m = Some(msg);
        }
        self.flag.store(true, Ordering::Release);
        // Alert surface: `/healthz` flips to 503 on the same latch, but a
        // scrape-only deployment sees it here.
        metrics::PERSIST_POISONED.set(1);
    }

    fn get(&self) -> Option<String> {
        if !self.flag.load(Ordering::Acquire) {
            return None;
        }
        self.msg.lock().unwrap().clone()
    }
}

/// Handle returned by [`Persist::append`] under [`FsyncPolicy::Group`]:
/// blocks until an fsync covers the append (leader/follower group
/// commit). MUST be awaited only after releasing the owning shard lock,
/// so later mutations can append into — and share — the commit group.
pub struct CommitWait {
    cell: Arc<WalCell>,
    lsn: u64,
    interval: Duration,
    poison: Arc<Poison>,
}

impl CommitWait {
    /// Block until this commit's batch is durable (or the log poisons).
    pub fn wait(self) -> Result<(), String> {
        let mut wf = self.cell.wal.lock().unwrap();
        loop {
            if let Some(e) = self.poison.get() {
                return Err(e);
            }
            if wf.durable_lsn >= self.lsn {
                return Ok(());
            }
            if wf.sync_running {
                // Follow the in-flight leader. The timeout is a
                // missed-wakeup guard: on expiry the loop re-checks and
                // leads as soon as no fsync is in flight.
                let (g, _) = self.cell.cv.wait_timeout(wf, self.interval).unwrap();
                wf = g;
                continue;
            }
            // Become the leader: fsync everything appended so far with
            // the mutex released.
            wf.sync_running = true;
            let target_lsn = wf.appended_lsn;
            let target_bytes = wf.bytes_written;
            let epoch = wf.epoch;
            let fd = wf.sync_fd.clone();
            // Records this fsync will newly cover — the group-commit batch.
            let batch = target_lsn.saturating_sub(wf.durable_lsn);
            drop(wf);
            let t_sync = metrics::clock();
            let res = fd.sync_data();
            wf = self.cell.wal.lock().unwrap();
            wf.sync_running = false;
            match res {
                Ok(()) => {
                    metrics::WAL_FSYNC_SECONDS.observe_since(t_sync);
                    metrics::WAL_GROUP_COMMIT_RECORDS.observe(batch as f64);
                    if wf.epoch == epoch {
                        wf.durable_lsn = wf.durable_lsn.max(target_lsn);
                        wf.durable_bytes = wf.durable_bytes.max(target_bytes);
                    }
                    self.cell.cv.notify_all();
                }
                Err(e) => {
                    let msg = format!("wal group fsync: {e}");
                    self.poison.set(msg.clone());
                    self.cell.cv.notify_all();
                    return Err(msg);
                }
            }
        }
    }
}

/// Outcome of one [`Persist::append`].
pub struct Appended {
    /// Group-commit wait handle; `None` when the append is already
    /// durable (or durability is not requested by the policy).
    pub wait: Option<CommitWait>,
    /// Set when this append triggered a snapshot rotation that archived
    /// events through the given seq — the caller drops them from its
    /// in-memory hot tail.
    pub archived_through: Option<u64>,
}

/// One shard's recovered state, in apply order.
pub struct RecoveredShard {
    pub key: ShardKey,
    pub records: Vec<WalRecord>,
    /// Highest event seq already archived to this shard's segments
    /// (those events are served from disk, not replayed into memory).
    pub archived_through: Option<u64>,
    /// Retention dropped this shard's events below this seq.
    pub truncated_before: Option<u64>,
}

/// Open WAL/snapshot/segment files for one store. One cell per shard
/// key; the store appends while holding the owning shard's write lock,
/// so per-shard record order equals apply order.
pub struct Persist {
    dir: PathBuf,
    snapshot_every: u64,
    fsync: FsyncPolicy,
    events_cfg: EventLogConfig,
    files: Mutex<BTreeMap<ShardKey, Arc<WalCell>>>,
    poison: Arc<Poison>,
}

impl std::fmt::Debug for Persist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Persist")
            .field("dir", &self.dir)
            .field("snapshot_every", &self.snapshot_every)
            .field("fsync", &self.fsync)
            .field("events", &self.events_cfg)
            .finish()
    }
}

/// Split a log byte stream into complete newline-terminated records.
/// Returns `(records, had_partial_tail)`; a final unterminated fragment
/// (crash mid-append) is excluded from the records.
fn split_records(bytes: &[u8]) -> (Vec<&[u8]>, bool) {
    if bytes.is_empty() {
        return (Vec::new(), false);
    }
    let mut segs: Vec<&[u8]> = bytes.split(|b| *b == b'\n').collect();
    let partial = !bytes.ends_with(b"\n");
    segs.pop(); // trailing empty segment, or the partial fragment
    (segs.into_iter().filter(|l| !l.is_empty()).collect(), partial)
}

/// Parse one log line: a WAL batch (`{"lsn": n, "batch": [...]}`) or a
/// snapshot row (`{"rec": {...}}`, lsn 0).
fn parse_line(line: &[u8]) -> Option<(u64, Vec<WalRecord>)> {
    let text = std::str::from_utf8(line).ok()?;
    let j = Json::parse(text).ok()?;
    let lsn = j.get("lsn").and_then(Json::as_u64).unwrap_or(0);
    if let Some(batch) = j.get("batch").and_then(Json::as_arr) {
        let mut recs = Vec::with_capacity(batch.len());
        for r in batch {
            recs.push(WalRecord::from_json(r)?);
        }
        return Some((lsn, recs));
    }
    let rec = WalRecord::from_json(j.get("rec")?)?;
    Some((lsn, vec![rec]))
}

/// Parse one event-segment line.
fn parse_event_line(line: &[u8]) -> Option<Event> {
    let text = std::str::from_utf8(line).ok()?;
    let j = Json::parse(text).ok()?;
    j.get("seq")?;
    Some(Event::from_json(&j))
}

fn open_append(path: &Path) -> crate::Result<(File, u64)> {
    let file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let len = file.metadata().map(|m| m.len()).unwrap_or(0);
    Ok((file, len))
}

/// fsync the persist directory itself: file creation and rename are
/// directory-metadata operations, so a snapshot rename or a fresh event
/// segment is power-loss-durable only once its dirent is synced too.
fn sync_dir(dir: &Path) -> std::io::Result<()> {
    File::open(dir)?.sync_all()
}

/// First newline-terminated line of `path` without reading the rest of
/// the file. `Ok(None)` = the file has no terminated first line (empty,
/// or a torn lone record).
fn read_first_line(path: &Path) -> crate::Result<Option<Vec<u8>>> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut line = Vec::new();
    BufReader::new(f)
        .read_until(b'\n', &mut line)
        .with_context(|| format!("read {}", path.display()))?;
    if line.last() == Some(&b'\n') {
        line.pop();
        Ok(Some(line))
    } else {
        Ok(None)
    }
}

impl Persist {
    /// Open (creating if needed) a persistence dir and recover its state.
    /// Returns the recovered shards, global tables first, in apply
    /// order. Feed them to the store, then start appending.
    pub fn open(
        dir: &Path,
        snapshot_every: u64,
        fsync: FsyncPolicy,
        events: EventLogConfig,
    ) -> crate::Result<(Persist, Vec<RecoveredShard>)> {
        fs::create_dir_all(dir).with_context(|| format!("create persist dir {}", dir.display()))?;
        let mut keys: BTreeSet<ShardKey> = BTreeSet::new();
        for entry in fs::read_dir(dir).with_context(|| format!("read {}", dir.display()))? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            let stem = match name.strip_suffix(".wal").or_else(|| name.strip_suffix(".snap")) {
                Some(s) => s,
                None => match name.find(".events.") {
                    Some(i) => &name[..i],
                    None => continue,
                },
            };
            if stem == "global" {
                keys.insert(None);
            } else if let Some(n) = stem.strip_prefix("site-").and_then(|s| s.parse::<u64>().ok()) {
                keys.insert(Some(SiteId(n)));
            }
        }
        let persist = Persist {
            dir: dir.to_path_buf(),
            snapshot_every,
            fsync,
            events_cfg: events,
            files: Mutex::new(BTreeMap::new()),
            poison: Poison::new(),
        };
        let mut recovered = Vec::new();
        // BTreeSet order puts None (global) first: site rows create their
        // shards before any shard rows are applied.
        for key in keys {
            let mut events = persist.recover_events(key)?;
            // Retention is otherwise only evaluated when a rotation
            // archives events: applying it here too lets an *idle* shard
            // (no further mutations) still shed aged/oversized segments
            // across restarts.
            persist.apply_retention(key, &mut events);
            let archived_through = events.archived_through;
            let truncated_before = events.truncated_before;
            let (records, next_lsn, since_snapshot) = persist.recover_key(key)?;
            persist.install_writer(key, next_lsn, since_snapshot, events)?;
            recovered.push(RecoveredShard { key, records, archived_through, truncated_before });
        }
        Ok((persist, recovered))
    }

    /// First recorded I/O failure, if the handle is poisoned.
    pub fn error(&self) -> Option<String> {
        self.poison.get()
    }

    /// Fault-injection hook (tests): poison the handle as if an append
    /// had failed — subsequent writes fail fast.
    pub fn poison(&self, msg: &str) {
        self.poison.set(msg.to_string());
        let files = self.files.lock().unwrap();
        for cell in files.values() {
            cell.cv.notify_all();
        }
    }

    /// WAL bytes covered by the last fsync for `key` — what survives a
    /// power loss at this instant (crash-simulation hook; meaningful
    /// under `Group` / `Always` policies).
    pub fn durable_wal_len(&self, key: ShardKey) -> Option<u64> {
        let cell = self.files.lock().unwrap().get(&key).cloned()?;
        let wf = cell.wal.lock().unwrap();
        Some(wf.durable_bytes)
    }

    /// Retention marker for `key`: events below the returned seq may
    /// have been dropped with their segments.
    pub fn truncated_before(&self, key: ShardKey) -> Option<u64> {
        let cell = self.files.lock().unwrap().get(&key).cloned()?;
        let wf = cell.wal.lock().unwrap();
        wf.events.truncated_before
    }

    /// Archived events of `key` with `seq >= since`, read from the
    /// segment files. Sealed segments are immutable and the active one is
    /// append-only, so no shard lock is needed: a concurrent archive can
    /// only expose a clean prefix (torn final line tolerated), and a
    /// segment deleted mid-read is a retention race — tolerated, because
    /// callers re-read the truncation marker *after* this returns.
    /// Unreadable bytes or a corrupt complete record are real storage
    /// damage and surface as an error, never as a silent gap.
    pub fn read_archived(&self, key: ShardKey, since: u64) -> Result<Vec<Event>, String> {
        let Some(cell) = self.files.lock().unwrap().get(&key).cloned() else {
            return Ok(Vec::new());
        };
        let (metas, archived) = {
            let wf = cell.wal.lock().unwrap();
            (wf.events.segments.clone(), wf.events.archived_through)
        };
        if archived.is_none() {
            return Ok(Vec::new());
        }
        let mut out = Vec::new();
        for (i, meta) in metas.iter().enumerate() {
            // Segments hold strictly increasing seqs: if the next segment
            // starts at or below `since`, this one has nothing relevant.
            if let Some(next) = metas.get(i + 1) {
                if next.first_seq != u64::MAX && next.first_seq <= since {
                    continue;
                }
            }
            let path = segment_path(&self.dir, key, meta.no);
            let bytes = match fs::read(&path) {
                Ok(b) => b,
                // Deleted between the meta snapshot and the read:
                // retention advanced; the caller's marker re-read covers
                // exactly the range that vanished.
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(format!("event segment read {}: {e}", path.display())),
            };
            let (lines, _partial) = split_records(&bytes);
            for line in lines {
                match parse_event_line(line) {
                    Some(e) if e.seq >= since => out.push(e),
                    Some(_) => {}
                    None => return Err(format!("corrupt event record in {}", path.display())),
                }
            }
        }
        Ok(out)
    }

    /// Recover one key's segmented event log: discover segments, truncate
    /// a torn active tail, and locate the archive high-water mark.
    fn recover_events(&self, key: ShardKey) -> crate::Result<EventLog> {
        let prefix = format!("{}.events.", file_stem(key));
        let mut nums: Vec<u64> = Vec::new();
        let dirents =
            fs::read_dir(&self.dir).with_context(|| format!("read {}", self.dir.display()))?;
        for entry in dirents {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(suffix) = name.strip_prefix(&prefix) {
                if let Ok(n) = suffix.parse::<u64>() {
                    nums.push(n);
                }
            }
        }
        nums.sort_unstable();
        let mut segments = Vec::new();
        let mut archived_through = None;
        let last_idx = nums.len().saturating_sub(1);
        for (i, &no) in nums.iter().enumerate() {
            let path = segment_path(&self.dir, key, no);
            if i != last_idx {
                // Sealed segments are immutable and were written
                // line-atomically: recover their metadata from the first
                // line + file length only, keeping startup cost O(number
                // of segments), not O(total archive bytes). Full
                // validation is deferred to the (loud) read path.
                let len =
                    fs::metadata(&path).with_context(|| format!("stat {}", path.display()))?.len();
                let first_seq = match read_first_line(&path)? {
                    Some(line) => {
                        parse_event_line(&line)
                            .ok_or_else(|| err!("corrupt event record in {}", path.display()))?
                            .seq
                    }
                    None if len == 0 => u64::MAX,
                    None => bail!("corrupt event segment {} (unterminated record)", path.display()),
                };
                segments.push(SegmentMeta { no, first_seq, bytes: len });
                continue;
            }
            // The final (active) segment is the only one a crash can
            // tear: read it in full, drop a torn tail, and take the
            // archive high-water mark from its last record.
            let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let valid_len = bytes.iter().rposition(|b| *b == b'\n').map(|p| p + 1).unwrap_or(0);
            if valid_len < bytes.len() {
                // Torn tail from a crash mid-archive: drop it so appends
                // resume on a record boundary. The events are still in
                // the WAL (archive happens before truncation).
                let f = OpenOptions::new()
                    .write(true)
                    .open(&path)
                    .with_context(|| format!("open {}", path.display()))?;
                f.set_len(valid_len as u64)
                    .with_context(|| format!("truncate {}", path.display()))?;
            }
            let (lines, _) = split_records(&bytes[..valid_len]);
            let mut first_seq = u64::MAX;
            if let Some(first) = lines.first() {
                first_seq = parse_event_line(first)
                    .ok_or_else(|| err!("corrupt event record in {}", path.display()))?
                    .seq;
            }
            if let Some(last) = lines.last() {
                let seq = parse_event_line(last)
                    .ok_or_else(|| err!("corrupt event record in {}", path.display()))?
                    .seq;
                archived_through = Some(seq);
            }
            segments.push(SegmentMeta { no, first_seq, bytes: valid_len as u64 });
        }
        if archived_through.is_none() && segments.len() > 1 {
            // The active segment was empty (crash between creation and
            // the first archive write): the high-water mark lives in the
            // sealed segment before it.
            let prev = &segments[segments.len() - 2];
            let path = segment_path(&self.dir, key, prev.no);
            let bytes = fs::read(&path).with_context(|| format!("read {}", path.display()))?;
            let (lines, _) = split_records(&bytes);
            if let Some(last) = lines.last() {
                let seq = parse_event_line(last)
                    .ok_or_else(|| err!("corrupt event record in {}", path.display()))?
                    .seq;
                archived_through = Some(seq);
            }
        }
        let truncated_before = match segments.first() {
            Some(m) if m.no > 1 && m.first_seq != u64::MAX => Some(m.first_seq),
            _ => None,
        };
        let active_bytes = segments.last().map(|m| m.bytes).unwrap_or(0);
        Ok(EventLog { segments, writer: None, active_bytes, archived_through, truncated_before })
    }

    /// Recover one key: snapshot records first, then the WAL tail above
    /// the snapshot's covered LSN. Returns (records, next_lsn,
    /// records_since_snapshot).
    fn recover_key(&self, key: ShardKey) -> crate::Result<(Vec<WalRecord>, u64, u64)> {
        let mut records = Vec::new();
        let mut snap_lsn = 0u64;
        let mut max_lsn = 0u64;
        let spath = snap_path(&self.dir, key);
        match fs::read(&spath) {
            Ok(bytes) => {
                let (lines, partial) = split_records(&bytes);
                if partial {
                    bail!("corrupt snapshot {} (unterminated record)", spath.display());
                }
                let mut it = lines.into_iter();
                if let Some(hdr) = it.next() {
                    let text = std::str::from_utf8(hdr)
                        .map_err(|_| err!("corrupt snapshot header in {}", spath.display()))?;
                    let j = Json::parse(text)
                        .map_err(|e| err!("corrupt snapshot header in {}: {e}", spath.display()))?;
                    snap_lsn = j
                        .get("snap_lsn")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| err!("snapshot {} missing snap_lsn", spath.display()))?;
                    max_lsn = snap_lsn;
                    for line in it {
                        let (_, recs) = parse_line(line)
                            .ok_or_else(|| err!("corrupt snapshot record in {}", spath.display()))?;
                        records.extend(recs);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => bail!("read {}: {e}", spath.display()),
        }
        let wpath = wal_path(&self.dir, key);
        let mut wal_count = 0u64;
        match fs::read(&wpath) {
            Ok(bytes) => {
                let mut pos = 0usize;
                let mut valid_len = 0usize;
                while pos < bytes.len() {
                    let Some(rel) = bytes[pos..].iter().position(|b| *b == b'\n') else {
                        break; // unterminated fragment: crash mid-append
                    };
                    let line = &bytes[pos..pos + rel];
                    let line_end = pos + rel + 1;
                    if line.is_empty() {
                        valid_len = line_end;
                        pos = line_end;
                        continue;
                    }
                    match parse_line(line) {
                        Some((lsn, recs)) => {
                            if lsn > snap_lsn {
                                wal_count += recs.len() as u64;
                                records.extend(recs);
                                max_lsn = max_lsn.max(lsn);
                            }
                            valid_len = line_end;
                            pos = line_end;
                        }
                        // A complete line that fails to parse is tolerated
                        // only in final position (torn batch tail);
                        // anywhere else it is real corruption.
                        None if line_end == bytes.len() => break,
                        None => bail!("corrupt WAL record in {} at byte {pos}", wpath.display()),
                    }
                }
                if valid_len < bytes.len() {
                    // Drop the torn tail now, so the reopened writer
                    // starts on a record boundary — otherwise the next
                    // append would concatenate onto the fragment and
                    // poison the log for the following recovery.
                    let f = OpenOptions::new()
                        .write(true)
                        .open(&wpath)
                        .with_context(|| format!("open {}", wpath.display()))?;
                    f.set_len(valid_len as u64)
                        .with_context(|| format!("truncate {}", wpath.display()))?;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => bail!("read {}: {e}", wpath.display()),
        }
        Ok((records, max_lsn + 1, wal_count))
    }

    /// Open `key`'s WAL file and build its cell (everything logged so
    /// far — `len` bytes — counts as the durable baseline).
    fn open_cell(
        &self,
        key: ShardKey,
        next_lsn: u64,
        since_snapshot: u64,
        events: EventLog,
    ) -> crate::Result<Arc<WalCell>> {
        let path = wal_path(&self.dir, key);
        let (file, len) = open_append(&path)?;
        let sync_fd =
            Arc::new(file.try_clone().with_context(|| format!("dup {}", path.display()))?);
        Ok(Arc::new(WalCell {
            wal: Mutex::new(WalFile {
                writer: BufWriter::new(file),
                sync_fd,
                next_lsn,
                since_snapshot,
                appended_lsn: next_lsn - 1,
                durable_lsn: next_lsn - 1,
                sync_running: false,
                epoch: 0,
                bytes_written: len,
                durable_bytes: len,
                events,
            }),
            cv: Condvar::new(),
        }))
    }

    fn install_writer(
        &self,
        key: ShardKey,
        next_lsn: u64,
        since_snapshot: u64,
        events: EventLog,
    ) -> crate::Result<()> {
        let cell = self.open_cell(key, next_lsn, since_snapshot, events)?;
        self.files.lock().unwrap().insert(key, cell);
        Ok(())
    }

    /// Get or lazily create the cell for `key`.
    fn cell(&self, key: ShardKey) -> Result<Arc<WalCell>, String> {
        let mut files = self.files.lock().unwrap();
        if let Some(c) = files.get(&key) {
            return Ok(c.clone());
        }
        match self.open_cell(key, 1, 0, EventLog::default()) {
            Ok(cell) => {
                files.insert(key, cell.clone());
                Ok(cell)
            }
            Err(e) => {
                let msg = format!("wal open {}: {e}", file_stem(key));
                self.poison.set(msg.clone());
                Err(msg)
            }
        }
    }

    /// Append `records` to `key`'s WAL; the caller holds the owning shard
    /// write lock, so record order matches apply order. When the
    /// per-shard record budget is exhausted, `snapshot` is invoked (under
    /// the same lock — it sees exactly the logged state); its events are
    /// archived to the segmented log and its rows become the snapshot.
    ///
    /// Returns the group-commit wait handle (await it AFTER releasing the
    /// shard lock) and the archive high-water mark when rotation ran. Any
    /// I/O error poisons the handle and fails this and all later appends.
    pub fn append(
        &self,
        key: ShardKey,
        records: &[WalRecord],
        snapshot: impl FnOnce() -> (Vec<WalRecord>, Vec<Event>),
    ) -> Result<Appended, String> {
        if records.is_empty() {
            return Ok(Appended { wait: None, archived_through: None });
        }
        if let Some(e) = self.poison.get() {
            return Err(e);
        }
        let cell = self.cell(key)?;
        let mut wf = cell.wal.lock().unwrap();
        // One line = one atomic batch: the whole mutation (rows + events)
        // commits or is rolled back together by torn-tail recovery.
        let lsn = wf.next_lsn;
        let line = Json::obj(vec![
            ("lsn", Json::num(lsn as f64)),
            ("batch", Json::Arr(records.iter().map(WalRecord::to_json).collect())),
        ]);
        wf.next_lsn += 1;
        let mut buf = line.to_string();
        buf.push('\n');
        let t_io = metrics::clock();
        let io = wf.writer.write_all(buf.as_bytes()).and_then(|_| wf.writer.flush());
        if let Err(e) = io {
            let msg = format!("wal append {}: {e}", file_stem(key));
            self.poison.set(msg.clone());
            cell.cv.notify_all();
            return Err(msg);
        }
        metrics::WAL_APPEND_SECONDS.observe_since(t_io);
        wf.appended_lsn = lsn;
        wf.bytes_written += buf.len() as u64;
        wf.since_snapshot += records.len() as u64;

        // Only `Always` fsyncs inline (under the log mutex — and the
        // caller's shard lock — by design: that policy trades the hot
        // path for per-append durability). `Group` NEVER fsyncs here:
        // every group append hands back a CommitWait that the store
        // awaits after releasing its shard lock, and that waiter-side
        // leader election keeps fsyncs off both locks.
        if matches!(self.fsync, FsyncPolicy::Always) {
            let t_sync = metrics::clock();
            match wf.sync_fd.sync_data() {
                Ok(()) => {
                    metrics::WAL_FSYNC_SECONDS.observe_since(t_sync);
                    wf.durable_lsn = lsn;
                    wf.durable_bytes = wf.bytes_written;
                    cell.cv.notify_all();
                }
                Err(e) => {
                    let msg = format!("wal fsync {}: {e}", file_stem(key));
                    self.poison.set(msg.clone());
                    cell.cv.notify_all();
                    return Err(msg);
                }
            }
        }

        let mut archived_through = None;
        if self.snapshot_every > 0 && wf.since_snapshot >= self.snapshot_every {
            archived_through = self.rotate(key, &mut wf, snapshot());
            cell.cv.notify_all();
            if let Some(e) = self.poison.get() {
                return Err(e);
            }
        }

        let wait = match self.fsync {
            FsyncPolicy::Group { interval_ms, .. } if wf.durable_lsn < lsn => Some(CommitWait {
                cell: cell.clone(),
                lsn,
                interval: Duration::from_millis(interval_ms.max(1)),
                poison: self.poison.clone(),
            }),
            _ => None,
        };
        Ok(Appended { wait, archived_through })
    }

    /// Append `events` to the active segment (fsynced), sealing / rolling
    /// / retaining segments as configured.
    fn archive_events(
        &self,
        key: ShardKey,
        el: &mut EventLog,
        events: &[Event],
    ) -> std::io::Result<Option<u64>> {
        if events.is_empty() {
            return Ok(el.archived_through);
        }
        if el.writer.is_none() {
            let reopen =
                el.segments.last().filter(|m| m.bytes < self.events_cfg.segment_bytes).cloned();
            match reopen {
                Some(meta) => {
                    // Reopen the under-sized active segment from a prior
                    // process life.
                    let f = OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(segment_path(&self.dir, key, meta.no))?;
                    el.active_bytes = meta.bytes;
                    el.writer = Some(BufWriter::new(f));
                }
                None => {
                    let no = el.segments.last().map(|m| m.no + 1).unwrap_or(1);
                    let f = OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(segment_path(&self.dir, key, no))?;
                    // Make the new segment's dirent durable before any
                    // event is considered archived out of the WAL.
                    sync_dir(&self.dir)?;
                    el.segments.push(SegmentMeta { no, first_seq: u64::MAX, bytes: 0 });
                    el.active_bytes = 0;
                    el.writer = Some(BufWriter::new(f));
                }
            }
        }
        let mut buf = String::new();
        for e in events {
            buf.push_str(&e.to_json().to_string());
            buf.push('\n');
        }
        let w = el.writer.as_mut().expect("active segment writer");
        w.write_all(buf.as_bytes())?;
        w.flush()?;
        w.get_ref().sync_data()?;
        el.active_bytes += buf.len() as u64;
        let meta = el.segments.last_mut().expect("active segment meta");
        meta.bytes = el.active_bytes;
        if meta.first_seq == u64::MAX {
            meta.first_seq = events[0].seq;
        }
        el.archived_through = events.last().map(|e| e.seq);
        if el.active_bytes >= self.events_cfg.segment_bytes {
            el.writer = None; // sealed; the next archive starts a new segment
        }
        self.apply_retention(key, el);
        Ok(el.archived_through)
    }

    /// Drop the oldest sealed segments that violate the size/age caps.
    /// The newest segment is never deleted — it anchors the segment
    /// numbering and the archive high-water mark across reopens.
    fn apply_retention(&self, key: ShardKey, el: &mut EventLog) {
        let cfg = &self.events_cfg;
        if cfg.retain_bytes == 0 && cfg.retain_age_s == 0 {
            return;
        }
        while el.segments.len() > 1 {
            let total: u64 = el.segments.iter().map(|m| m.bytes).sum();
            let oldest_no = el.segments[0].no;
            let path = segment_path(&self.dir, key, oldest_no);
            let over_bytes = cfg.retain_bytes > 0 && total > cfg.retain_bytes;
            let over_age = cfg.retain_age_s > 0
                && fs::metadata(&path)
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| t.elapsed().ok())
                    .map(|age| age.as_secs() > cfg.retain_age_s)
                    .unwrap_or(false);
            if !over_bytes && !over_age {
                break;
            }
            if let Err(e) = fs::remove_file(&path) {
                eprintln!("event-log retention: remove {}: {e}", path.display());
                break;
            }
            el.segments.remove(0);
            if let Some(first) = el.segments.first() {
                if first.first_seq != u64::MAX {
                    el.truncated_before = Some(first.first_seq);
                }
            }
        }
    }

    /// Snapshot rotation: archive the un-archived events to the segment
    /// log (fsynced), write a rows-only compacting snapshot, truncate the
    /// WAL. Returns the archive high-water mark when events were
    /// archived. An archive failure poisons the handle (continuing could
    /// duplicate events in the segments); snapshot / truncate failures
    /// are non-fatal — the WAL keeps the history and rotation retries at
    /// the next threshold, and recovery deduplicates WAL events already
    /// covered by the segments.
    fn rotate(
        &self,
        key: ShardKey,
        wf: &mut WalFile,
        snapshot: (Vec<WalRecord>, Vec<Event>),
    ) -> Option<u64> {
        let (rows, events) = snapshot;
        let archived = match self.archive_events(key, &mut wf.events, &events) {
            Ok(_) => events.last().map(|e| e.seq),
            Err(e) => {
                self.poison.set(format!("event archive {}: {e}", file_stem(key)));
                return None;
            }
        };
        let covered = wf.next_lsn - 1;
        let tmp = self.dir.join(format!("{}.snap.tmp", file_stem(key)));
        let snap = snap_path(&self.dir, key);
        let mut out = String::new();
        out.push_str(&Json::obj(vec![("snap_lsn", Json::num(covered as f64))]).to_string());
        out.push('\n');
        for rec in &rows {
            out.push_str(&Json::obj(vec![("rec", rec.to_json())]).to_string());
            out.push('\n');
        }
        let result = (|| -> std::io::Result<()> {
            let mut f = File::create(&tmp)?;
            f.write_all(out.as_bytes())?;
            f.sync_all()?;
            fs::rename(&tmp, &snap)?;
            // The rename is a directory-metadata op: sync the dirent
            // BEFORE truncating the WAL, or a power loss could persist
            // the truncation but not the snapshot it depends on.
            sync_dir(&self.dir)?;
            let fresh = File::create(wal_path(&self.dir, key))?;
            let sync_fd = fresh.try_clone()?;
            wf.writer = BufWriter::new(fresh);
            wf.sync_fd = Arc::new(sync_fd);
            wf.since_snapshot = 0;
            wf.bytes_written = 0;
            wf.durable_bytes = 0;
            // Everything logged so far now lives in the fsynced snapshot
            // + segments: group waiters are satisfied, and any in-flight
            // leader's stale bookkeeping is invalidated via the epoch.
            wf.durable_lsn = wf.appended_lsn;
            wf.epoch += 1;
            Ok(())
        })();
        if let Err(e) = result {
            eprintln!("wal snapshot rotation failed for {}: {e}", file_stem(key));
        }
        archived
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("balsam-persist-{}-{tag}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn open_flush(dir: &Path, snapshot_every: u64) -> (Persist, Vec<RecoveredShard>) {
        Persist::open(dir, snapshot_every, FsyncPolicy::Never, EventLogConfig::default()).unwrap()
    }

    fn no_snap() -> (Vec<WalRecord>, Vec<Event>) {
        (Vec::new(), Vec::new())
    }

    fn job(id: u64, state: JobState) -> Job {
        Job {
            id: JobId(id),
            site_id: SiteId(1),
            app_id: AppId(1),
            state,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "md_small".into(),
            parents: vec![],
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: 0.0,
        }
    }

    fn ev(seq: u64) -> Event {
        Event {
            seq,
            job_id: JobId(5),
            site_id: SiteId(1),
            ts: seq as f64,
            from: JobState::Created,
            to: JobState::Ready,
            data: String::new(),
        }
    }

    fn rec_strings(records: &[WalRecord]) -> Vec<String> {
        records.iter().map(|r| r.to_json().to_string()).collect()
    }

    #[test]
    fn record_json_roundtrip() {
        let recs = vec![
            WalRecord::User(User { id: UserId(1), name: "admin".into() }),
            WalRecord::Job(job(5, JobState::Ready)),
            WalRecord::Event(Event {
                seq: 3,
                job_id: JobId(5),
                site_id: SiteId(1),
                ts: 2.0,
                from: JobState::Created,
                to: JobState::Ready,
                data: "".into(),
            }),
        ];
        for r in &recs {
            let j = Json::parse(&r.to_json().to_string()).unwrap();
            let back = WalRecord::from_json(&j).unwrap();
            assert_eq!(back.to_json().to_string(), r.to_json().to_string());
        }
        assert!(WalRecord::from_json(&Json::obj(vec![("t", Json::str("nope"))])).is_none());
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!(FsyncPolicy::parse("never"), Some(FsyncPolicy::Never));
        assert_eq!(FsyncPolicy::parse("flush"), Some(FsyncPolicy::Never));
        assert_eq!(FsyncPolicy::parse("always"), Some(FsyncPolicy::Always));
        assert_eq!(
            FsyncPolicy::parse("group"),
            Some(FsyncPolicy::Group {
                records: FsyncPolicy::DEFAULT_GROUP_RECORDS,
                interval_ms: FsyncPolicy::DEFAULT_GROUP_INTERVAL_MS,
            })
        );
        assert_eq!(
            FsyncPolicy::parse("group:8,2ms"),
            Some(FsyncPolicy::Group { records: 8, interval_ms: 2 })
        );
        assert_eq!(
            FsyncPolicy::parse("group:128,50"),
            Some(FsyncPolicy::Group { records: 128, interval_ms: 50 })
        );
        assert_eq!(FsyncPolicy::parse("group:0,5"), None);
        assert_eq!(FsyncPolicy::parse("sometimes"), None);
        assert_eq!(FsyncPolicy::parse("group:"), None);
    }

    #[test]
    fn split_records_handles_partial_tail() {
        let (lines, partial) = split_records(b"a\nb\n");
        assert_eq!(lines, vec![b"a".as_slice(), b"b".as_slice()]);
        assert!(!partial);
        let (lines, partial) = split_records(b"a\nbroken");
        assert_eq!(lines, vec![b"a".as_slice()]);
        assert!(partial);
        let (lines, partial) = split_records(b"");
        assert!(lines.is_empty());
        assert!(!partial);
    }

    #[test]
    fn append_and_recover_roundtrip() {
        let dir = tmpdir("roundtrip");
        let key = Some(SiteId(1));
        let written = vec![
            WalRecord::Job(job(5, JobState::Ready)),
            WalRecord::Job(job(5, JobState::StagedIn)),
            WalRecord::Job(job(6, JobState::Created)),
        ];
        {
            let (p, recovered) = open_flush(&dir, 0);
            assert!(recovered.is_empty());
            p.append(key, &written, no_snap).unwrap();
            let user = [WalRecord::User(User { id: UserId(1), name: "admin".into() })];
            p.append(None, &user, no_snap).unwrap();
        }
        let (_p, recovered) = open_flush(&dir, 0);
        assert_eq!(recovered.len(), 2);
        assert_eq!(recovered[0].key, None);
        assert_eq!(recovered[1].key, key);
        assert_eq!(rec_strings(&recovered[1].records), rec_strings(&written));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_rotation_compacts_and_recovers() {
        let dir = tmpdir("rotate");
        let key = Some(SiteId(1));
        {
            let (p, _) = open_flush(&dir, 2);
            // Threshold 2: this append rotates, compacting to one row.
            let recs = [
                WalRecord::Job(job(5, JobState::Ready)),
                WalRecord::Job(job(5, JobState::StagedIn)),
            ];
            p.append(key, &recs, || (vec![WalRecord::Job(job(5, JobState::StagedIn))], Vec::new()))
                .unwrap();
            // Post-rotation append lands in the fresh WAL.
            p.append(key, &[WalRecord::Job(job(6, JobState::Created))], no_snap).unwrap();
        }
        assert!(snap_path(&dir, key).exists());
        let (_p, recovered) = open_flush(&dir, 2);
        assert_eq!(recovered.len(), 1);
        assert_eq!(
            rec_strings(&recovered[0].records),
            rec_strings(&[
                WalRecord::Job(job(5, JobState::StagedIn)),
                WalRecord::Job(job(6, JobState::Created)),
            ])
        );
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_archives_events_and_keeps_snapshot_event_free() {
        let dir = tmpdir("rotate-events");
        let key = Some(SiteId(1));
        {
            let (p, _) = open_flush(&dir, 2);
            p.append(
                key,
                &[WalRecord::Job(job(5, JobState::Ready)), WalRecord::Event(ev(0))],
                || (vec![WalRecord::Job(job(5, JobState::Ready))], vec![ev(0)]),
            )
            .unwrap();
        }
        let snap = fs::read_to_string(snap_path(&dir, key)).unwrap();
        assert!(!snap.contains("\"t\":\"event\""), "snapshot must hold rows only: {snap}");
        assert!(segment_path(&dir, key, 1).exists());
        let (p, recovered) = open_flush(&dir, 2);
        assert_eq!(recovered[0].archived_through, Some(0));
        // The archived event is served from the segment, not the WAL.
        let rec = rec_strings(&recovered[0].records);
        assert!(rec.iter().all(|s| !s.contains("\"t\":\"event\"")), "{rec:?}");
        let arch = p.read_archived(key, 0).unwrap();
        assert_eq!(arch.len(), 1);
        assert_eq!(arch[0].seq, 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn segments_roll_and_retention_truncates() {
        let dir = tmpdir("segments");
        let key = Some(SiteId(1));
        let cfg = EventLogConfig { segment_bytes: 1, retain_bytes: 0, retain_age_s: 0 };
        {
            let (p, _) = Persist::open(&dir, 1, FsyncPolicy::Never, cfg.clone()).unwrap();
            // Every append rotates (threshold 1) and every archive seals
            // its segment (1-byte cap): one segment per event.
            for seq in 0..4u64 {
                p.append(key, &[WalRecord::Event(ev(seq))], || (Vec::new(), vec![ev(seq)]))
                    .unwrap();
            }
            assert_eq!(p.read_archived(key, 0).unwrap().len(), 4);
            assert_eq!(p.read_archived(key, 2).unwrap().len(), 2);
            assert_eq!(p.truncated_before(key), None);
        }
        // Reopen with a byte cap: the next archive drops old segments.
        let cfg2 = EventLogConfig { segment_bytes: 1, retain_bytes: 100, retain_age_s: 0 };
        let (p, recovered) = Persist::open(&dir, 1, FsyncPolicy::Never, cfg2).unwrap();
        assert_eq!(recovered[0].archived_through, Some(3));
        p.append(key, &[WalRecord::Event(ev(4))], || (Vec::new(), vec![ev(4)])).unwrap();
        let t = p.truncated_before(key).expect("retention must set the truncation marker");
        assert!(t > 0, "oldest segments dropped");
        let remaining = p.read_archived(key, 0).unwrap();
        assert_eq!(remaining.first().unwrap().seq, t, "events from the marker on are intact");
        assert_eq!(remaining.last().unwrap().seq, 4);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn group_commit_acks_are_durable_and_tracked() {
        let dir = tmpdir("group");
        let key = Some(SiteId(1));
        let (p, _) = Persist::open(
            &dir,
            0,
            FsyncPolicy::Group { records: 2, interval_ms: 5 },
            EventLogConfig::default(),
        )
        .unwrap();
        for i in 0..5u64 {
            let rec = [WalRecord::Job(job(10 + i, JobState::Created))];
            let ap = p.append(key, &rec, no_snap).unwrap();
            if let Some(w) = ap.wait {
                w.wait().unwrap();
            }
        }
        // Every acknowledged append is covered by an fsync.
        let durable = p.durable_wal_len(key).unwrap();
        let len = fs::metadata(wal_path(&dir, key)).unwrap().len();
        assert_eq!(durable, len, "acknowledged tail must be fsynced");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn poisoned_handle_fails_fast() {
        let dir = tmpdir("poison");
        let key = Some(SiteId(1));
        let (p, _) = open_flush(&dir, 0);
        p.append(key, &[WalRecord::Job(job(5, JobState::Ready))], no_snap).unwrap();
        assert!(p.error().is_none());
        p.poison("injected disk failure");
        assert!(p.error().unwrap().contains("injected"));
        let err = p.append(key, &[WalRecord::Job(job(6, JobState::Ready))], no_snap).unwrap_err();
        assert!(err.contains("injected"));
        // The pre-poison record is still recoverable; the rejected one is
        // not (it was never written).
        drop(p);
        let (_p, recovered) = open_flush(&dir, 0);
        assert_eq!(recovered[0].records.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_record_is_dropped() {
        let dir = tmpdir("torn");
        let key = Some(SiteId(1));
        {
            let (p, _) = open_flush(&dir, 0);
            p.append(key, &[WalRecord::Job(job(5, JobState::Ready))], no_snap).unwrap();
        }
        // Simulate a crash mid-append: partial JSON, no trailing newline.
        let mut f = OpenOptions::new().append(true).open(wal_path(&dir, key)).unwrap();
        f.write_all(b"{\"lsn\":2,\"rec\":{\"t\":\"job\",\"r\":{\"id\":").unwrap();
        drop(f);
        {
            let (p, recovered) = open_flush(&dir, 0);
            assert_eq!(
                rec_strings(&recovered[0].records),
                rec_strings(&[WalRecord::Job(job(5, JobState::Ready))])
            );
            // The torn tail was truncated on open: appends start on a
            // record boundary and the log stays parseable.
            p.append(key, &[WalRecord::Job(job(6, JobState::Created))], no_snap).unwrap();
        }
        let (_p, recovered) = open_flush(&dir, 0);
        assert_eq!(recovered[0].records.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn lsn_continues_after_recovery() {
        let dir = tmpdir("lsn");
        let key = Some(SiteId(1));
        {
            let (p, _) = open_flush(&dir, 0);
            p.append(key, &[WalRecord::Job(job(5, JobState::Ready))], no_snap).unwrap();
        }
        {
            let (p, _) = open_flush(&dir, 0);
            p.append(key, &[WalRecord::Job(job(6, JobState::Ready))], no_snap).unwrap();
        }
        let (_p, recovered) = open_flush(&dir, 0);
        assert_eq!(recovered[0].records.len(), 2, "no records lost across reopen");
        let _ = fs::remove_dir_all(&dir);
    }
}
