//! Token auth: HMAC-SHA256-signed bearer tokens (JWT-in-spirit).
//!
//! The paper's service issues JWT access tokens after an OAuth2 device
//! flow (§3.1). We reproduce the transport-level contract: a compact
//! signed token identifying the user in every request, validated without
//! database lookups. The OAuth2 *flow* itself (browser redirects, device
//! codes) is out of scope — tokens are issued directly, which matches the
//! paper's own evaluation setup ("user login endpoints were disabled and
//! JWT authentication tokens were securely generated for each Balsam
//! site", §4.1.2).

use crate::util::sha256::{hex, hmac_sha256};

use super::models::UserId;

/// Issues and validates signed bearer tokens.
#[derive(Debug, Clone)]
pub struct TokenAuthority {
    secret: Vec<u8>,
}

impl TokenAuthority {
    pub fn new(secret: &[u8]) -> TokenAuthority {
        TokenAuthority { secret: secret.to_vec() }
    }

    /// Issue a token of the form `balsam.<uid>.<hex signature>`.
    pub fn issue(&self, user: UserId) -> String {
        let payload = format!("balsam.{}", user.0);
        format!("{payload}.{}", self.sign(&payload))
    }

    /// Validate a token; return the authenticated user.
    pub fn validate(&self, token: &str) -> Option<UserId> {
        let (payload, sig) = token.rsplit_once('.')?;
        if !payload.starts_with("balsam.") {
            return None;
        }
        let expect = self.sign(payload);
        // Constant-time comparison.
        if sig.len() != expect.len() {
            return None;
        }
        let mut diff = 0u8;
        for (a, b) in sig.bytes().zip(expect.bytes()) {
            diff |= a ^ b;
        }
        if diff != 0 {
            return None;
        }
        payload.strip_prefix("balsam.")?.parse().ok().map(UserId)
    }

    fn sign(&self, payload: &str) -> String {
        hex(&hmac_sha256(&self.secret, payload.as_bytes()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_validate_roundtrip() {
        let auth = TokenAuthority::new(b"s3cret");
        let tok = auth.issue(UserId(42));
        assert_eq!(auth.validate(&tok), Some(UserId(42)));
    }

    #[test]
    fn tampered_uid_rejected() {
        let auth = TokenAuthority::new(b"s3cret");
        let tok = auth.issue(UserId(42));
        let forged = tok.replace("balsam.42", "balsam.43");
        assert_eq!(auth.validate(&forged), None);
    }

    #[test]
    fn tampered_signature_rejected() {
        let auth = TokenAuthority::new(b"s3cret");
        let mut tok = auth.issue(UserId(1));
        let last = tok.pop().unwrap();
        tok.push(if last == '0' { '1' } else { '0' });
        assert_eq!(auth.validate(&tok), None);
    }

    #[test]
    fn wrong_secret_rejected() {
        let a = TokenAuthority::new(b"alpha");
        let b = TokenAuthority::new(b"beta");
        let tok = a.issue(UserId(7));
        assert_eq!(b.validate(&tok), None);
    }

    #[test]
    fn garbage_rejected() {
        let auth = TokenAuthority::new(b"s3cret");
        assert_eq!(auth.validate(""), None);
        assert_eq!(auth.validate("balsam.1"), None);
        assert_eq!(auth.validate("x.y.z"), None);
    }
}
