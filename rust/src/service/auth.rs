//! Token auth: HMAC-SHA256-signed bearer tokens (JWT-in-spirit).
//!
//! The paper's service issues JWT access tokens after an OAuth2 device
//! flow (§3.1). We reproduce the transport-level contract: a compact
//! signed token identifying the user in every request, validated without
//! database lookups. The OAuth2 *flow* itself (browser redirects, device
//! codes) is out of scope — tokens are issued directly, which matches the
//! paper's own evaluation setup ("user login endpoints were disabled and
//! JWT authentication tokens were securely generated for each Balsam
//! site", §4.1.2).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::sha256::{hex, hmac_sha256};

use super::models::UserId;

/// Issues and validates signed bearer tokens.
#[derive(Debug, Clone)]
pub struct TokenAuthority {
    secret: Vec<u8>,
}

impl TokenAuthority {
    pub fn new(secret: &[u8]) -> TokenAuthority {
        TokenAuthority { secret: secret.to_vec() }
    }

    /// Issue a token of the form `balsam.<uid>.<hex signature>`.
    pub fn issue(&self, user: UserId) -> String {
        let payload = format!("balsam.{}", user.0);
        format!("{payload}.{}", self.sign(&payload))
    }

    /// Validate a token; return the authenticated user.
    pub fn validate(&self, token: &str) -> Option<UserId> {
        let (payload, sig) = token.rsplit_once('.')?;
        if !payload.starts_with("balsam.") {
            return None;
        }
        let expect = self.sign(payload);
        // Constant-time comparison.
        if sig.len() != expect.len() {
            return None;
        }
        let mut diff = 0u8;
        for (a, b) in sig.bytes().zip(expect.bytes()) {
            diff |= a ^ b;
        }
        if diff != 0 {
            return None;
        }
        payload.strip_prefix("balsam.")?.parse().ok().map(UserId)
    }

    fn sign(&self, payload: &str) -> String {
        hex(&hmac_sha256(&self.secret, payload.as_bytes()))
    }
}

/// Per-principal token-bucket rate limiter (the gateway's admission
/// quota, paper §3.1's multi-tenant service boundary).
///
/// Each authenticated [`UserId`] gets an independent bucket holding up
/// to `burst` tokens, refilled continuously at `rps` tokens/second; one
/// request spends one token. An empty bucket means the request is
/// refused with 429 + `Retry-After` (the caller computes the hint via
/// the returned deficit). Buckets are lazily created full, so a quiet
/// principal always has its full burst available.
///
/// The map is guarded by one `Mutex` — admission is a ~100ns critical
/// section (one hash lookup + float math), orders of magnitude below
/// the request work it gates, so a sharded or lock-free map would be
/// speculative complexity here.
#[derive(Debug)]
pub struct RateLimiter {
    /// Sustained refill rate, tokens (requests) per second.
    rps: f64,
    /// Bucket capacity: the tolerated burst above the sustained rate.
    burst: f64,
    /// Principals exempt from limiting (e.g. the admin user when the
    /// `--rate-limit-admin-exempt` knob is on).
    exempt: Vec<UserId>,
    /// `user → (tokens, last refill instant)`.
    buckets: Mutex<HashMap<UserId, (f64, Instant)>>,
}

/// Outcome of one admission check.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    /// Token spent; process the request.
    Admit,
    /// Bucket empty; refuse 429 with this `Retry-After` hint (seconds,
    /// ≥ 1: the time until one token refills, rounded up).
    Throttle(u64),
}

impl RateLimiter {
    /// A limiter admitting `rps` sustained requests/second with bursts
    /// up to `burst`. Both are clamped to ≥ 1 (a zero rate is expressed
    /// by not installing a limiter at all).
    pub fn new(rps: u64, burst: u64) -> RateLimiter {
        RateLimiter {
            rps: rps.max(1) as f64,
            burst: burst.max(1) as f64,
            exempt: Vec::new(),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Exempt a principal (admin) from limiting.
    pub fn exempt(mut self, user: UserId) -> RateLimiter {
        self.exempt.push(user);
        self
    }

    /// Admit or throttle one request from `user`, now.
    pub fn check(&self, user: UserId) -> Admission {
        self.check_at(user, Instant::now())
    }

    /// Clock-injected admission (tests drive time explicitly).
    pub fn check_at(&self, user: UserId, now: Instant) -> Admission {
        if self.exempt.contains(&user) {
            return Admission::Admit;
        }
        let mut buckets = self.buckets.lock().unwrap_or_else(|e| e.into_inner());
        let (tokens, last) = buckets.entry(user).or_insert((self.burst, now));
        let elapsed = now.saturating_duration_since(*last).as_secs_f64();
        *tokens = (*tokens + elapsed * self.rps).min(self.burst);
        *last = now;
        if *tokens >= 1.0 {
            *tokens -= 1.0;
            Admission::Admit
        } else {
            // Seconds until one whole token refills, rounded up.
            let wait = (1.0 - *tokens) / self.rps;
            Admission::Throttle((wait.ceil() as u64).max(1))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issue_validate_roundtrip() {
        let auth = TokenAuthority::new(b"s3cret");
        let tok = auth.issue(UserId(42));
        assert_eq!(auth.validate(&tok), Some(UserId(42)));
    }

    #[test]
    fn tampered_uid_rejected() {
        let auth = TokenAuthority::new(b"s3cret");
        let tok = auth.issue(UserId(42));
        let forged = tok.replace("balsam.42", "balsam.43");
        assert_eq!(auth.validate(&forged), None);
    }

    #[test]
    fn tampered_signature_rejected() {
        let auth = TokenAuthority::new(b"s3cret");
        let mut tok = auth.issue(UserId(1));
        let last = tok.pop().unwrap();
        tok.push(if last == '0' { '1' } else { '0' });
        assert_eq!(auth.validate(&tok), None);
    }

    #[test]
    fn wrong_secret_rejected() {
        let a = TokenAuthority::new(b"alpha");
        let b = TokenAuthority::new(b"beta");
        let tok = a.issue(UserId(7));
        assert_eq!(b.validate(&tok), None);
    }

    #[test]
    fn garbage_rejected() {
        let auth = TokenAuthority::new(b"s3cret");
        assert_eq!(auth.validate(""), None);
        assert_eq!(auth.validate("balsam.1"), None);
        assert_eq!(auth.validate("x.y.z"), None);
    }

    #[test]
    fn bucket_admits_burst_then_throttles() {
        let rl = RateLimiter::new(10, 3);
        let t0 = Instant::now();
        for _ in 0..3 {
            assert_eq!(rl.check_at(UserId(1), t0), Admission::Admit);
        }
        match rl.check_at(UserId(1), t0) {
            Admission::Throttle(s) => assert!(s >= 1),
            other => panic!("expected throttle, got {other:?}"),
        }
    }

    #[test]
    fn bucket_refills_at_rps() {
        let rl = RateLimiter::new(10, 1);
        let t0 = Instant::now();
        assert_eq!(rl.check_at(UserId(1), t0), Admission::Admit);
        assert!(matches!(rl.check_at(UserId(1), t0), Admission::Throttle(_)));
        // 10 rps → one token back after 100ms.
        let t1 = t0 + std::time::Duration::from_millis(150);
        assert_eq!(rl.check_at(UserId(1), t1), Admission::Admit);
    }

    #[test]
    fn principals_have_independent_buckets() {
        let rl = RateLimiter::new(1, 1);
        let t0 = Instant::now();
        assert_eq!(rl.check_at(UserId(1), t0), Admission::Admit);
        assert!(matches!(rl.check_at(UserId(1), t0), Admission::Throttle(_)));
        // A different principal still has its full burst.
        assert_eq!(rl.check_at(UserId(2), t0), Admission::Admit);
    }

    #[test]
    fn exempt_principal_is_never_throttled() {
        let rl = RateLimiter::new(1, 1).exempt(UserId(0));
        let t0 = Instant::now();
        for _ in 0..100 {
            assert_eq!(rl.check_at(UserId(0), t0), Admission::Admit);
        }
    }

    #[test]
    fn retry_after_hint_reflects_refill_deficit() {
        // 1 rps, burst 1: after spending the token the deficit is a full
        // token → 1s hint.
        let rl = RateLimiter::new(1, 1);
        let t0 = Instant::now();
        assert_eq!(rl.check_at(UserId(9), t0), Admission::Admit);
        assert_eq!(rl.check_at(UserId(9), t0), Admission::Throttle(1));
    }
}
