//! The Balsam relational data model (paper §3.1, REST API schema [3]).

use std::collections::BTreeSet;

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
        pub struct $name(pub u64);

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}", self.0)
            }
        }
    };
}

id_type!(
    /// Root entity: every Site belongs to a User (multi-tenancy).
    UserId
);
id_type!(
    /// A user-owned execution endpoint (hostname + site directory).
    SiteId
);
id_type!(
    /// An indexed ApplicationDefinition at a Site.
    AppId
);
id_type!(
    /// A fine-grained task: one invocation of an App at a Site.
    JobId
);
id_type!(
    /// A pilot-job resource allocation at a Site.
    BatchJobId
);
id_type!(
    /// A standalone unit of data transfer between a Site and a remote endpoint.
    TransferItemId
);
id_type!(
    /// A launcher's lease on acquired jobs, kept alive by heartbeats.
    SessionId
);
id_type!(
    /// A Globus-like transfer-task id (site-local handle).
    XferTaskId
);

/// Persistent job lifecycle states (paper §3.1 "Jobs carry persistent
/// states"; names follow the Balsam REST API enumeration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum JobState {
    Created,
    AwaitingParents,
    /// Waiting for stage-in transfers.
    Ready,
    /// Input data has arrived at the site.
    StagedIn,
    /// Site-side preprocessing done; runnable by a launcher.
    Preprocessed,
    Running,
    RunDone,
    /// Site-side postprocessing done; stage-out may begin.
    Postprocessed,
    /// Round trip complete (results delivered to the client endpoint).
    JobFinished,
    RunError,
    /// Launcher died / allocation expired while running.
    RunTimeout,
    /// Reset by the service or site for another attempt.
    RestartReady,
    Failed,
}

impl JobState {
    pub const ALL: [JobState; 13] = [
        JobState::Created,
        JobState::AwaitingParents,
        JobState::Ready,
        JobState::StagedIn,
        JobState::Preprocessed,
        JobState::Running,
        JobState::RunDone,
        JobState::Postprocessed,
        JobState::JobFinished,
        JobState::RunError,
        JobState::RunTimeout,
        JobState::RestartReady,
        JobState::Failed,
    ];

    /// Terminal states: no further transitions.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::JobFinished | JobState::Failed)
    }

    /// States from which a launcher may acquire the job for execution.
    pub fn is_runnable(self) -> bool {
        matches!(self, JobState::Preprocessed | JobState::RestartReady)
    }

    pub fn name(self) -> &'static str {
        match self {
            JobState::Created => "CREATED",
            JobState::AwaitingParents => "AWAITING_PARENTS",
            JobState::Ready => "READY",
            JobState::StagedIn => "STAGED_IN",
            JobState::Preprocessed => "PREPROCESSED",
            JobState::Running => "RUNNING",
            JobState::RunDone => "RUN_DONE",
            JobState::Postprocessed => "POSTPROCESSED",
            JobState::JobFinished => "JOB_FINISHED",
            JobState::RunError => "RUN_ERROR",
            JobState::RunTimeout => "RUN_TIMEOUT",
            JobState::RestartReady => "RESTART_READY",
            JobState::Failed => "FAILED",
        }
    }

    pub fn from_name(s: &str) -> Option<JobState> {
        JobState::ALL.iter().copied().find(|st| st.name() == s)
    }
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[derive(Debug, Clone)]
pub struct User {
    pub id: UserId,
    pub name: String,
}

#[derive(Debug, Clone)]
pub struct Site {
    pub id: SiteId,
    pub owner: UserId,
    /// e.g. "theta", "summit", "cori" — must match a facility name.
    pub name: String,
    pub hostname: String,
    pub path: String,
}

/// Server-side index of a site's ApplicationDefinition (paper §3.1: the
/// service stores only metadata; the executable template lives at the
/// site, so maliciously submitted App data cannot alter local execution).
#[derive(Debug, Clone)]
pub struct App {
    pub id: AppId,
    pub site_id: SiteId,
    pub name: String,
    pub command_template: String,
    pub parameters: Vec<String>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Direction {
    In,
    Out,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TransferState {
    Pending,
    Active,
    Done,
    Error,
}

/// A file/directory that must be staged in or out for a Job.
#[derive(Debug, Clone)]
pub struct TransferItem {
    pub id: TransferItemId,
    pub job_id: JobId,
    pub site_id: SiteId,
    pub direction: Direction,
    /// Remote endpoint name (e.g. "APS", "ALS") — protocol-specific URI in
    /// the real system, facility name in the simulator.
    pub remote: String,
    pub size_bytes: u64,
    pub state: TransferState,
    /// Globus-like task UUID registered by the site transfer module.
    pub task_id: Option<XferTaskId>,
}

#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub site_id: SiteId,
    pub app_id: AppId,
    pub state: JobState,
    pub params: Vec<(String, String)>,
    pub tags: Vec<(String, String)>,
    pub num_nodes: u32,
    /// Workload class consumed by the execution backend (e.g. "md_small").
    pub workload: String,
    pub parents: Vec<JobId>,
    pub attempts: u32,
    pub max_attempts: u32,
    /// Session currently holding this job, if any.
    pub session: Option<SessionId>,
    pub created_at: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum BatchJobState {
    /// Created via API, not yet submitted to the local scheduler.
    Pending,
    Queued,
    Running,
    Finished,
    /// Deleted before starting (e.g. elastic-queue wait timeout).
    Deleted,
}

/// Pilot-job execution mode (paper §4.5: `mpi` spawns one app-run per job;
/// `serial` packs single-node jobs into one master per node).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobMode {
    Mpi,
    Serial,
}

/// A resource allocation request / pilot job (paper §3.1 "Balsam BatchJob").
#[derive(Debug, Clone)]
pub struct BatchJob {
    pub id: BatchJobId,
    pub site_id: SiteId,
    pub num_nodes: u32,
    pub wall_time_s: f64,
    pub mode: JobMode,
    pub queue: String,
    pub project: String,
    pub state: BatchJobState,
    /// Local scheduler id once submitted.
    pub local_id: Option<u64>,
    pub created_at: f64,
    pub started_at: Option<f64>,
    pub ended_at: Option<f64>,
}

/// A launcher's lease (paper §3.1 "Session"): guarantees exclusive job
/// acquisition and enables crash recovery via heartbeat expiry.
#[derive(Debug, Clone)]
pub struct Session {
    pub id: SessionId,
    pub site_id: SiteId,
    pub batch_job_id: Option<BatchJobId>,
    pub heartbeat_at: f64,
    pub acquired: BTreeSet<JobId>,
    pub ended: bool,
}

/// One job lifecycle event (paper §4.1.4: "The Balsam service stores Balsam
/// Job events with timestamps recorded at the job execution site").
#[derive(Debug, Clone)]
pub struct Event {
    /// Global, dense sequence number (total order across all site shards;
    /// `ListEvents { since }` pages on it).
    pub seq: u64,
    pub job_id: JobId,
    pub site_id: SiteId,
    pub ts: f64,
    pub from: JobState,
    pub to: JobState,
    pub data: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_names_roundtrip() {
        for s in JobState::ALL {
            assert_eq!(JobState::from_name(s.name()), Some(s));
        }
        assert_eq!(JobState::from_name("NOPE"), None);
    }

    #[test]
    fn terminal_and_runnable_classification() {
        assert!(JobState::JobFinished.is_terminal());
        assert!(JobState::Failed.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Preprocessed.is_runnable());
        assert!(JobState::RestartReady.is_runnable());
        assert!(!JobState::Running.is_runnable());
        assert!(!JobState::StagedIn.is_runnable());
    }

    #[test]
    fn id_display() {
        assert_eq!(JobId(42).to_string(), "42");
    }
}
