//! The Balsam relational data model (paper §3.1, REST API schema [3]).
//!
//! Every row type carries a `to_json` / `from_json` codec pair: the HTTP
//! gateway uses them for wire payloads and the persistence layer
//! ([`super::persist`]) uses them for WAL/snapshot records, so a row
//! always has exactly one serialized shape.

use std::collections::BTreeSet;

use crate::util::json::{
    get_str, get_u64, ids_json, kv_from_json, kv_to_json, opt_num, u64s_from_json, Json,
};

macro_rules! id_type {
    ($(#[$doc:meta])* $name:ident) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
        pub struct $name(
            /// Raw numeric id (issued by `Store::fresh_id`, dense across
            /// all entity kinds).
            pub u64,
        );

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}", self.0)
            }
        }
    };
}

id_type!(
    /// Root entity: every Site belongs to a User (multi-tenancy).
    UserId
);
id_type!(
    /// A user-owned execution endpoint (hostname + site directory).
    SiteId
);
id_type!(
    /// An indexed ApplicationDefinition at a Site.
    AppId
);
id_type!(
    /// A fine-grained task: one invocation of an App at a Site.
    JobId
);
id_type!(
    /// A pilot-job resource allocation at a Site.
    BatchJobId
);
id_type!(
    /// A standalone unit of data transfer between a Site and a remote endpoint.
    TransferItemId
);
id_type!(
    /// A launcher's lease on acquired jobs, kept alive by heartbeats.
    SessionId
);
id_type!(
    /// A Globus-like transfer-task id (site-local handle).
    XferTaskId
);

/// Persistent job lifecycle states (paper §3.1 "Jobs carry persistent
/// states"; names follow the Balsam REST API enumeration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum JobState {
    /// Just inserted; initial routing not yet applied.
    Created,
    /// Blocked on unfinished parent jobs (DAG edge).
    AwaitingParents,
    /// Waiting for stage-in transfers.
    Ready,
    /// Input data has arrived at the site.
    StagedIn,
    /// Site-side preprocessing done; runnable by a launcher.
    Preprocessed,
    /// Executing under a launcher session.
    Running,
    /// The application run exited successfully.
    RunDone,
    /// Site-side postprocessing done; stage-out may begin.
    Postprocessed,
    /// Round trip complete (results delivered to the client endpoint).
    JobFinished,
    /// The application run exited with an error.
    RunError,
    /// Launcher died / allocation expired while running.
    RunTimeout,
    /// Reset by the service or site for another attempt.
    RestartReady,
    /// Terminal failure (retry budget exhausted or parent failed).
    Failed,
}

impl JobState {
    /// Every state, in canonical (paper) order.
    pub const ALL: [JobState; 13] = [
        JobState::Created,
        JobState::AwaitingParents,
        JobState::Ready,
        JobState::StagedIn,
        JobState::Preprocessed,
        JobState::Running,
        JobState::RunDone,
        JobState::Postprocessed,
        JobState::JobFinished,
        JobState::RunError,
        JobState::RunTimeout,
        JobState::RestartReady,
        JobState::Failed,
    ];

    /// Terminal states: no further transitions.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::JobFinished | JobState::Failed)
    }

    /// States from which a launcher may acquire the job for execution.
    pub fn is_runnable(self) -> bool {
        matches!(self, JobState::Preprocessed | JobState::RestartReady)
    }

    /// Canonical wire/WAL name (the Balsam REST API enumeration string).
    pub fn name(self) -> &'static str {
        match self {
            JobState::Created => "CREATED",
            JobState::AwaitingParents => "AWAITING_PARENTS",
            JobState::Ready => "READY",
            JobState::StagedIn => "STAGED_IN",
            JobState::Preprocessed => "PREPROCESSED",
            JobState::Running => "RUNNING",
            JobState::RunDone => "RUN_DONE",
            JobState::Postprocessed => "POSTPROCESSED",
            JobState::JobFinished => "JOB_FINISHED",
            JobState::RunError => "RUN_ERROR",
            JobState::RunTimeout => "RUN_TIMEOUT",
            JobState::RestartReady => "RESTART_READY",
            JobState::Failed => "FAILED",
        }
    }

    /// Inverse of [`JobState::name`]; `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<JobState> {
        JobState::ALL.iter().copied().find(|st| st.name() == s)
    }
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A tenant of the service (paper §3.1 multi-tenancy root).
#[derive(Debug, Clone)]
pub struct User {
    /// Identity (authorization compares owner ids).
    pub id: UserId,
    /// Display name; `"admin"` is recovered as the service identity.
    pub name: String,
}

/// A user-owned execution endpoint (one facility deployment).
#[derive(Debug, Clone)]
pub struct Site {
    /// Identity; also the shard key for everything at this site.
    pub id: SiteId,
    /// Owning user — the only non-admin allowed to touch this site.
    pub owner: UserId,
    /// e.g. "theta", "summit", "cori" — must match a facility name.
    pub name: String,
    /// Login hostname of the site.
    pub hostname: String,
    /// Site directory path at the facility.
    pub path: String,
}

/// Server-side index of a site's ApplicationDefinition (paper §3.1: the
/// service stores only metadata; the executable template lives at the
/// site, so maliciously submitted App data cannot alter local execution).
#[derive(Debug, Clone)]
pub struct App {
    /// Identity.
    pub id: AppId,
    /// Site the definition is indexed at.
    pub site_id: SiteId,
    /// App name, unique per site; jobs reference it by name.
    pub name: String,
    /// Shell template expanded at the site (server stores metadata only).
    pub command_template: String,
    /// Names of the template's parameters.
    pub parameters: Vec<String>,
}

/// Which way a transfer item moves data relative to the site.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Direction {
    /// Stage-in: remote endpoint -> site (before preprocessing).
    In,
    /// Stage-out: site -> remote endpoint (after postprocessing).
    Out,
}

/// Lifecycle of one transfer item.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TransferState {
    /// Awaiting pickup by the site transfer module.
    Pending,
    /// Bundled into an in-flight transfer task.
    Active,
    /// Data delivered; the owning job may advance.
    Done,
    /// The carrying transfer task failed.
    Error,
}

/// A file/directory that must be staged in or out for a Job.
#[derive(Debug, Clone)]
pub struct TransferItem {
    /// Identity.
    pub id: TransferItemId,
    /// Job whose data this item carries.
    pub job_id: JobId,
    /// Site (shard) the item belongs to — the owning job's site.
    pub site_id: SiteId,
    /// Stage-in or stage-out.
    pub direction: Direction,
    /// Remote endpoint name (e.g. "APS", "ALS") — protocol-specific URI in
    /// the real system, facility name in the simulator.
    pub remote: String,
    /// Payload size (drives simulated transfer time and task batching).
    pub size_bytes: u64,
    /// Current lifecycle state.
    pub state: TransferState,
    /// Globus-like task UUID registered by the site transfer module.
    pub task_id: Option<XferTaskId>,
}

/// A fine-grained task: one invocation of an App at a Site (paper §3.1).
#[derive(Debug, Clone)]
pub struct Job {
    /// Identity.
    pub id: JobId,
    /// Execution site (shard key).
    pub site_id: SiteId,
    /// The registered App this job runs.
    pub app_id: AppId,
    /// Current lifecycle state (see [`JobState`]).
    pub state: JobState,
    /// App parameter bindings, `(name, value)`.
    pub params: Vec<(String, String)>,
    /// Free-form labels for filtering, `(key, value)`.
    pub tags: Vec<(String, String)>,
    /// Node footprint of one run.
    pub num_nodes: u32,
    /// Workload class consumed by the execution backend (e.g. "md_small").
    pub workload: String,
    /// DAG dependencies (may live at other sites).
    pub parents: Vec<JobId>,
    /// Runs started so far (incremented on RUNNING).
    pub attempts: u32,
    /// Retry budget; exhausting it fails the job.
    pub max_attempts: u32,
    /// Session currently holding this job, if any.
    pub session: Option<SessionId>,
    /// Service-clock creation time (seconds).
    pub created_at: f64,
}

/// Lifecycle of a pilot allocation at the local batch scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum BatchJobState {
    /// Created via API, not yet submitted to the local scheduler.
    Pending,
    /// Submitted; waiting in the local queue.
    Queued,
    /// The allocation is running (its launcher may be live).
    Running,
    /// The allocation ended.
    Finished,
    /// Deleted before starting (e.g. elastic-queue wait timeout).
    Deleted,
}

/// Pilot-job execution mode (paper §4.5: `mpi` spawns one app-run per job;
/// `serial` packs single-node jobs into one master per node).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobMode {
    /// One multi-node app run per job.
    Mpi,
    /// Single-node jobs packed many-per-node under one master.
    Serial,
}

/// A resource allocation request / pilot job (paper §3.1 "Balsam BatchJob").
#[derive(Debug, Clone)]
pub struct BatchJob {
    /// Identity.
    pub id: BatchJobId,
    /// Site the allocation is requested at (shard key).
    pub site_id: SiteId,
    /// Allocation width in nodes.
    pub num_nodes: u32,
    /// Requested wall time, seconds.
    pub wall_time_s: f64,
    /// Launcher packing mode inside the allocation.
    pub mode: JobMode,
    /// Local scheduler queue.
    pub queue: String,
    /// Local scheduler project/account.
    pub project: String,
    /// Observed scheduler state.
    pub state: BatchJobState,
    /// Local scheduler id once submitted.
    pub local_id: Option<u64>,
    /// Service-clock creation time (seconds).
    pub created_at: f64,
    /// When the allocation started running, if it has.
    pub started_at: Option<f64>,
    /// When the allocation finished/was deleted, if it has.
    pub ended_at: Option<f64>,
}

/// A launcher's lease (paper §3.1 "Session"): guarantees exclusive job
/// acquisition and enables crash recovery via heartbeat expiry.
#[derive(Debug, Clone)]
pub struct Session {
    /// Identity.
    pub id: SessionId,
    /// Site the launcher runs at (shard key).
    pub site_id: SiteId,
    /// Pilot allocation backing this launcher, if any.
    pub batch_job_id: Option<BatchJobId>,
    /// Service-clock time of the last lease refresh.
    pub heartbeat_at: f64,
    /// Jobs exclusively held by this session.
    pub acquired: BTreeSet<JobId>,
    /// Set once the session ended (gracefully or by lease expiry).
    pub ended: bool,
}

/// One job lifecycle event (paper §4.1.4: "The Balsam service stores Balsam
/// Job events with timestamps recorded at the job execution site").
///
/// The `to_json`/`from_json` codec below is shared by three consumers:
/// HTTP wire payloads, WAL batch records, and the lines of the segmented
/// per-shard event-log files (`site-<id>.events.NNNN`) — an event has
/// exactly one serialized shape everywhere it rests.
#[derive(Debug, Clone)]
pub struct Event {
    /// Global, dense sequence number (total order across all site shards;
    /// `ListEvents { since }` and `WatchEvents` page on it).
    pub seq: u64,
    /// Job whose transition this records.
    pub job_id: JobId,
    /// Site (shard) the job lives at.
    pub site_id: SiteId,
    /// Service-clock timestamp of the transition (seconds).
    pub ts: f64,
    /// State the job left.
    pub from: JobState,
    /// State the job entered.
    pub to: JobState,
    /// Free-form annotation supplied with the transition.
    pub data: String,
}

impl Direction {
    /// Canonical wire/WAL name.
    pub fn name(self) -> &'static str {
        match self {
            Direction::In => "in",
            Direction::Out => "out",
        }
    }

    /// Inverse of [`Direction::name`]; `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<Direction> {
        match s {
            "in" => Some(Direction::In),
            "out" => Some(Direction::Out),
            _ => None,
        }
    }
}

impl TransferState {
    /// Canonical wire/WAL name.
    pub fn name(self) -> &'static str {
        match self {
            TransferState::Pending => "pending",
            TransferState::Active => "active",
            TransferState::Done => "done",
            TransferState::Error => "error",
        }
    }

    /// Inverse of [`TransferState::name`]; `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<TransferState> {
        match s {
            "pending" => Some(TransferState::Pending),
            "active" => Some(TransferState::Active),
            "done" => Some(TransferState::Done),
            "error" => Some(TransferState::Error),
            _ => None,
        }
    }
}

impl BatchJobState {
    /// Canonical wire/WAL name.
    pub fn name(self) -> &'static str {
        match self {
            BatchJobState::Pending => "pending",
            BatchJobState::Queued => "queued",
            BatchJobState::Running => "running",
            BatchJobState::Finished => "finished",
            BatchJobState::Deleted => "deleted",
        }
    }

    /// Inverse of [`BatchJobState::name`]; `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<BatchJobState> {
        match s {
            "pending" => Some(BatchJobState::Pending),
            "queued" => Some(BatchJobState::Queued),
            "running" => Some(BatchJobState::Running),
            "finished" => Some(BatchJobState::Finished),
            "deleted" => Some(BatchJobState::Deleted),
            _ => None,
        }
    }
}

impl JobMode {
    /// Canonical wire/WAL name.
    pub fn name(self) -> &'static str {
        match self {
            JobMode::Mpi => "mpi",
            JobMode::Serial => "serial",
        }
    }

    /// Inverse of [`JobMode::name`]; `None` for unknown strings.
    pub fn from_name(s: &str) -> Option<JobMode> {
        match s {
            "mpi" => Some(JobMode::Mpi),
            "serial" => Some(JobMode::Serial),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Row codecs (wire payloads + WAL/snapshot records)
// ---------------------------------------------------------------------------

impl User {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("name", Json::str(self.name.clone())),
        ])
    }

    /// Decode [`User::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> User {
        User { id: UserId(get_u64(j, "id")), name: get_str(j, "name") }
    }
}

impl Site {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("owner", Json::num(self.owner.0 as f64)),
            ("name", Json::str(self.name.clone())),
            ("hostname", Json::str(self.hostname.clone())),
            ("path", Json::str(self.path.clone())),
        ])
    }

    /// Decode [`Site::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> Site {
        Site {
            id: SiteId(get_u64(j, "id")),
            owner: UserId(get_u64(j, "owner")),
            name: get_str(j, "name"),
            hostname: get_str(j, "hostname"),
            path: get_str(j, "path"),
        }
    }
}

impl App {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("site_id", Json::num(self.site_id.0 as f64)),
            ("name", Json::str(self.name.clone())),
            ("command_template", Json::str(self.command_template.clone())),
            (
                "parameters",
                Json::Arr(self.parameters.iter().map(|p| Json::str(p.clone())).collect()),
            ),
        ])
    }

    /// Decode [`App::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> App {
        App {
            id: AppId(get_u64(j, "id")),
            site_id: SiteId(get_u64(j, "site_id")),
            name: get_str(j, "name"),
            command_template: get_str(j, "command_template"),
            parameters: j
                .get("parameters")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        }
    }
}

impl Job {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("site_id", Json::num(self.site_id.0 as f64)),
            ("app_id", Json::num(self.app_id.0 as f64)),
            ("state", Json::str(self.state.name())),
            ("params", kv_to_json(&self.params)),
            ("tags", kv_to_json(&self.tags)),
            ("num_nodes", Json::num(self.num_nodes as f64)),
            ("workload", Json::str(self.workload.clone())),
            ("parents", ids_json(self.parents.iter().copied(), |p| p.0)),
            ("attempts", Json::num(self.attempts as f64)),
            ("max_attempts", Json::num(self.max_attempts as f64)),
            ("session", opt_num(self.session.map(|s| s.0))),
            ("created_at", Json::num(self.created_at)),
        ])
    }

    /// Decode [`Job::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> Job {
        Job {
            id: JobId(get_u64(j, "id")),
            site_id: SiteId(get_u64(j, "site_id")),
            app_id: AppId(get_u64(j, "app_id")),
            state: j
                .get("state")
                .and_then(Json::as_str)
                .and_then(JobState::from_name)
                .unwrap_or(JobState::Created),
            params: j.get("params").map(kv_from_json).unwrap_or_default(),
            tags: j.get("tags").map(kv_from_json).unwrap_or_default(),
            num_nodes: j.get("num_nodes").and_then(Json::as_u64).unwrap_or(1) as u32,
            workload: get_str(j, "workload"),
            parents: j
                .get("parents")
                .map(u64s_from_json)
                .unwrap_or_default()
                .into_iter()
                .map(JobId)
                .collect(),
            attempts: get_u64(j, "attempts") as u32,
            max_attempts: j.get("max_attempts").and_then(Json::as_u64).unwrap_or(3) as u32,
            session: j.get("session").and_then(Json::as_u64).map(SessionId),
            created_at: j.get("created_at").and_then(Json::as_f64).unwrap_or(0.0),
        }
    }
}

impl TransferItem {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("job_id", Json::num(self.job_id.0 as f64)),
            ("site_id", Json::num(self.site_id.0 as f64)),
            ("direction", Json::str(self.direction.name())),
            ("remote", Json::str(self.remote.clone())),
            ("size_bytes", Json::num(self.size_bytes as f64)),
            ("state", Json::str(self.state.name())),
            ("task_id", opt_num(self.task_id.map(|t| t.0))),
        ])
    }

    /// Decode [`TransferItem::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> TransferItem {
        TransferItem {
            id: TransferItemId(get_u64(j, "id")),
            job_id: JobId(get_u64(j, "job_id")),
            site_id: SiteId(get_u64(j, "site_id")),
            direction: j
                .get("direction")
                .and_then(Json::as_str)
                .and_then(Direction::from_name)
                .unwrap_or(Direction::In),
            remote: get_str(j, "remote"),
            size_bytes: get_u64(j, "size_bytes"),
            state: j
                .get("state")
                .and_then(Json::as_str)
                .and_then(TransferState::from_name)
                .unwrap_or(TransferState::Pending),
            task_id: j.get("task_id").and_then(Json::as_u64).map(XferTaskId),
        }
    }
}

impl BatchJob {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("site_id", Json::num(self.site_id.0 as f64)),
            ("num_nodes", Json::num(self.num_nodes as f64)),
            ("wall_time_s", Json::num(self.wall_time_s)),
            ("mode", Json::str(self.mode.name())),
            ("queue", Json::str(self.queue.clone())),
            ("project", Json::str(self.project.clone())),
            ("state", Json::str(self.state.name())),
            ("local_id", opt_num(self.local_id)),
            ("created_at", Json::num(self.created_at)),
            ("started_at", self.started_at.map(Json::num).unwrap_or(Json::Null)),
            ("ended_at", self.ended_at.map(Json::num).unwrap_or(Json::Null)),
        ])
    }

    /// Decode [`BatchJob::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> BatchJob {
        BatchJob {
            id: BatchJobId(get_u64(j, "id")),
            site_id: SiteId(get_u64(j, "site_id")),
            num_nodes: get_u64(j, "num_nodes") as u32,
            wall_time_s: j.get("wall_time_s").and_then(Json::as_f64).unwrap_or(0.0),
            mode: j
                .get("mode")
                .and_then(Json::as_str)
                .and_then(JobMode::from_name)
                .unwrap_or(JobMode::Mpi),
            queue: get_str(j, "queue"),
            project: get_str(j, "project"),
            state: j
                .get("state")
                .and_then(Json::as_str)
                .and_then(BatchJobState::from_name)
                .unwrap_or(BatchJobState::Pending),
            local_id: j.get("local_id").and_then(Json::as_u64),
            created_at: j.get("created_at").and_then(Json::as_f64).unwrap_or(0.0),
            started_at: j.get("started_at").and_then(Json::as_f64),
            ended_at: j.get("ended_at").and_then(Json::as_f64),
        }
    }
}

impl Session {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id.0 as f64)),
            ("site_id", Json::num(self.site_id.0 as f64)),
            ("batch_job_id", opt_num(self.batch_job_id.map(|b| b.0))),
            ("heartbeat_at", Json::num(self.heartbeat_at)),
            ("acquired", ids_json(self.acquired.iter().copied(), |j| j.0)),
            ("ended", Json::Bool(self.ended)),
        ])
    }

    /// Decode [`Session::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> Session {
        Session {
            id: SessionId(get_u64(j, "id")),
            site_id: SiteId(get_u64(j, "site_id")),
            batch_job_id: j.get("batch_job_id").and_then(Json::as_u64).map(BatchJobId),
            heartbeat_at: j.get("heartbeat_at").and_then(Json::as_f64).unwrap_or(0.0),
            acquired: j
                .get("acquired")
                .map(u64s_from_json)
                .unwrap_or_default()
                .into_iter()
                .map(JobId)
                .collect(),
            ended: j.get("ended").and_then(Json::as_bool).unwrap_or(false),
        }
    }
}

impl Event {
    /// The canonical serialized shape (HTTP wire payloads and WAL /
    /// snapshot records use this same encoding).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seq", Json::num(self.seq as f64)),
            ("job_id", Json::num(self.job_id.0 as f64)),
            ("site_id", Json::num(self.site_id.0 as f64)),
            ("ts", Json::num(self.ts)),
            ("from", Json::str(self.from.name())),
            ("to", Json::str(self.to.name())),
            ("data", Json::str(self.data.clone())),
        ])
    }

    /// Decode [`Event::to_json`] output; absent fields take zero-ish
    /// defaults (lenient for wire/version skew).
    pub fn from_json(j: &Json) -> Event {
        Event {
            seq: get_u64(j, "seq"),
            job_id: JobId(get_u64(j, "job_id")),
            site_id: SiteId(get_u64(j, "site_id")),
            ts: j.get("ts").and_then(Json::as_f64).unwrap_or(0.0),
            from: j
                .get("from")
                .and_then(Json::as_str)
                .and_then(JobState::from_name)
                .unwrap_or(JobState::Created),
            to: j
                .get("to")
                .and_then(Json::as_str)
                .and_then(JobState::from_name)
                .unwrap_or(JobState::Created),
            data: get_str(j, "data"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_names_roundtrip() {
        for s in JobState::ALL {
            assert_eq!(JobState::from_name(s.name()), Some(s));
        }
        assert_eq!(JobState::from_name("NOPE"), None);
    }

    #[test]
    fn terminal_and_runnable_classification() {
        assert!(JobState::JobFinished.is_terminal());
        assert!(JobState::Failed.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Preprocessed.is_runnable());
        assert!(JobState::RestartReady.is_runnable());
        assert!(!JobState::Running.is_runnable());
        assert!(!JobState::StagedIn.is_runnable());
    }

    #[test]
    fn id_display() {
        assert_eq!(JobId(42).to_string(), "42");
    }

    #[test]
    fn row_codecs_roundtrip() {
        let job = Job {
            id: JobId(7),
            site_id: SiteId(2),
            app_id: AppId(3),
            state: JobState::Running,
            params: vec![("h5".into(), "x.h5".into())],
            tags: vec![("experiment".into(), "XPCS".into())],
            num_nodes: 4,
            workload: "md_small".into(),
            parents: vec![JobId(1), JobId(2)],
            attempts: 1,
            max_attempts: 3,
            session: Some(SessionId(9)),
            created_at: 1.5,
        };
        let back = Job::from_json(&Json::parse(&job.to_json().to_string()).unwrap());
        assert_eq!(back.to_json().to_string(), job.to_json().to_string());

        let sess = Session {
            id: SessionId(9),
            site_id: SiteId(2),
            batch_job_id: Some(BatchJobId(4)),
            heartbeat_at: 3.25,
            acquired: [JobId(7), JobId(8)].into_iter().collect(),
            ended: false,
        };
        let back = Session::from_json(&Json::parse(&sess.to_json().to_string()).unwrap());
        assert_eq!(back.to_json().to_string(), sess.to_json().to_string());

        let ev = Event {
            seq: 12,
            job_id: JobId(7),
            site_id: SiteId(2),
            ts: 4.5,
            from: JobState::Ready,
            to: JobState::StagedIn,
            data: "globus".into(),
        };
        let back = Event::from_json(&Json::parse(&ev.to_json().to_string()).unwrap());
        assert_eq!(back.to_json().to_string(), ev.to_json().to_string());
    }

    #[test]
    fn enum_names_roundtrip() {
        for d in [Direction::In, Direction::Out] {
            assert_eq!(Direction::from_name(d.name()), Some(d));
        }
        for t in [
            TransferState::Pending,
            TransferState::Active,
            TransferState::Done,
            TransferState::Error,
        ] {
            assert_eq!(TransferState::from_name(t.name()), Some(t));
        }
        for b in [
            BatchJobState::Pending,
            BatchJobState::Queued,
            BatchJobState::Running,
            BatchJobState::Finished,
            BatchJobState::Deleted,
        ] {
            assert_eq!(BatchJobState::from_name(b.name()), Some(b));
        }
        for m in [JobMode::Mpi, JobMode::Serial] {
            assert_eq!(JobMode::from_name(m.name()), Some(m));
        }
        assert_eq!(Direction::from_name("sideways"), None);
    }
}
