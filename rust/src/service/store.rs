//! Relational in-memory store (substrate replacing PostgreSQL).
//!
//! Tables are `BTreeMap<Id, Row>` with maintained secondary indexes on the
//! hot query paths the paper calls out: *"runnable Jobs are appropriately
//! indexed in the underlying PostgreSQL database [so] the response time of
//! this endpoint is largely consistent with respect to increasing number
//! of submitted Jobs"* (§4.5). Index coherence is asserted in tests and
//! property-checked in `rust/tests/prop_coordinator.rs`.

use std::collections::{BTreeMap, BTreeSet};

use super::models::*;

/// All service tables + indexes. Mutations MUST go through the provided
/// methods so indexes stay coherent.
#[derive(Debug, Default)]
pub struct Store {
    next_id: u64,
    pub users: BTreeMap<UserId, User>,
    pub sites: BTreeMap<SiteId, Site>,
    pub apps: BTreeMap<AppId, App>,
    jobs: BTreeMap<JobId, Job>,
    pub batch_jobs: BTreeMap<BatchJobId, BatchJob>,
    titems: BTreeMap<TransferItemId, TransferItem>,
    pub sessions: BTreeMap<SessionId, Session>,
    pub events: Vec<Event>,

    // Secondary indexes (hot paths).
    jobs_by_site_state: BTreeMap<(SiteId, JobState), BTreeSet<JobId>>,
    children_by_parent: BTreeMap<JobId, Vec<JobId>>,
    titems_by_site: BTreeMap<(SiteId, Direction, TransferState), BTreeSet<TransferItemId>>,
    titems_by_job: BTreeMap<JobId, Vec<TransferItemId>>,
}

impl Store {
    pub fn new() -> Store {
        Store::default()
    }

    pub fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    // ----- jobs ---------------------------------------------------------

    pub fn insert_job(&mut self, job: Job) {
        self.jobs_by_site_state.entry((job.site_id, job.state)).or_default().insert(job.id);
        for &p in &job.parents {
            self.children_by_parent.entry(p).or_default().push(job.id);
        }
        self.jobs.insert(job.id, job);
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    pub fn jobs_iter(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    pub fn job_count(&self) -> usize {
        self.jobs.len()
    }

    pub fn children_of(&self, parent: JobId) -> &[JobId] {
        self.children_by_parent.get(&parent).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Move a job to `to`, updating indexes and appending an event.
    /// The caller is responsible for having checked transition legality.
    pub fn set_job_state(&mut self, id: JobId, to: JobState, ts: f64, data: &str) {
        let job = self.jobs.get_mut(&id).expect("set_job_state: unknown job");
        let from = job.state;
        if from == to {
            return;
        }
        job.state = to;
        let site = job.site_id;
        if let Some(set) = self.jobs_by_site_state.get_mut(&(site, from)) {
            set.remove(&id);
        }
        self.jobs_by_site_state.entry((site, to)).or_default().insert(id);
        self.events.push(Event { job_id: id, site_id: site, ts, from, to, data: data.to_string() });
    }

    pub fn job_mut(&mut self, id: JobId) -> Option<&mut Job> {
        // NOTE: callers must not mutate `state` or `site_id` through this —
        // use set_job_state. Exposed for session/attempt bookkeeping.
        self.jobs.get_mut(&id)
    }

    /// Ids of jobs at `site` in `state` (index lookup, O(log n)).
    pub fn jobs_in_state(&self, site: SiteId, state: JobState) -> Vec<JobId> {
        self.jobs_by_site_state
            .get(&(site, state))
            .map(|s| s.iter().copied().collect())
            .unwrap_or_default()
    }

    pub fn count_in_state(&self, site: SiteId, state: JobState) -> usize {
        self.jobs_by_site_state.get(&(site, state)).map(BTreeSet::len).unwrap_or(0)
    }

    // ----- transfer items -------------------------------------------------

    pub fn insert_titem(&mut self, item: TransferItem) {
        self.titems_by_site
            .entry((item.site_id, item.direction, item.state))
            .or_default()
            .insert(item.id);
        self.titems_by_job.entry(item.job_id).or_default().push(item.id);
        self.titems.insert(item.id, item);
    }

    pub fn titem(&self, id: TransferItemId) -> Option<&TransferItem> {
        self.titems.get(&id)
    }

    pub fn titems_iter(&self) -> impl Iterator<Item = &TransferItem> {
        self.titems.values()
    }

    pub fn titems_for_job(&self, job: JobId) -> Vec<&TransferItem> {
        self.titems_by_job
            .get(&job)
            .map(|v| v.iter().map(|id| &self.titems[id]).collect())
            .unwrap_or_default()
    }

    pub fn titems_in_state(
        &self,
        site: SiteId,
        dir: Direction,
        state: TransferState,
        limit: usize,
    ) -> Vec<TransferItemId> {
        self.titems_by_site
            .get(&(site, dir, state))
            .map(|s| s.iter().take(limit).copied().collect())
            .unwrap_or_default()
    }

    pub fn set_titem_state(
        &mut self,
        id: TransferItemId,
        state: TransferState,
        task_id: Option<XferTaskId>,
    ) {
        let item = self.titems.get_mut(&id).expect("set_titem_state: unknown item");
        let old = item.state;
        if let Some(t) = task_id {
            item.task_id = Some(t);
        }
        if old == state {
            return;
        }
        let key_old = (item.site_id, item.direction, old);
        let key_new = (item.site_id, item.direction, state);
        item.state = state;
        if let Some(set) = self.titems_by_site.get_mut(&key_old) {
            set.remove(&id);
        }
        self.titems_by_site.entry(key_new).or_default().insert(id);
    }

    /// Are all transfer items of `job` in `dir` Done?
    pub fn transfers_complete(&self, job: JobId, dir: Direction) -> bool {
        self.titems_for_job(job)
            .iter()
            .filter(|t| t.direction == dir)
            .all(|t| t.state == TransferState::Done)
    }

    // ----- diagnostics ----------------------------------------------------

    /// Full index-coherence check (used by tests/properties).
    pub fn check_indexes(&self) -> Result<(), String> {
        for (key, set) in &self.jobs_by_site_state {
            for id in set {
                let j = self.jobs.get(id).ok_or(format!("index {key:?} has ghost job {id}"))?;
                if (j.site_id, j.state) != *key {
                    return Err(format!("job {id} indexed under {key:?} but is {:?}", (j.site_id, j.state)));
                }
            }
        }
        for j in self.jobs.values() {
            let ok = self
                .jobs_by_site_state
                .get(&(j.site_id, j.state))
                .map(|s| s.contains(&j.id))
                .unwrap_or(false);
            if !ok {
                return Err(format!("job {} missing from index", j.id));
            }
        }
        for (key, set) in &self.titems_by_site {
            for id in set {
                let t = self.titems.get(id).ok_or(format!("ghost titem {id}"))?;
                if (t.site_id, t.direction, t.state) != *key {
                    return Err(format!("titem {id} mis-indexed"));
                }
            }
        }
        for t in self.titems.values() {
            let ok = self
                .titems_by_site
                .get(&(t.site_id, t.direction, t.state))
                .map(|s| s.contains(&t.id))
                .unwrap_or(false);
            if !ok {
                return Err(format!("titem {} missing from index", t.id));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_job(store: &mut Store, site: SiteId, state: JobState) -> JobId {
        let id = JobId(store.fresh_id());
        store.insert_job(Job {
            id,
            site_id: site,
            app_id: AppId(1),
            state: JobState::Created,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "md_small".into(),
            parents: vec![],
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: 0.0,
        });
        if state != JobState::Created {
            store.set_job_state(id, state, 1.0, "");
        }
        id
    }

    #[test]
    fn state_index_tracks_transitions() {
        let mut s = Store::new();
        let site = SiteId(1);
        let a = mk_job(&mut s, site, JobState::Ready);
        let b = mk_job(&mut s, site, JobState::Ready);
        assert_eq!(s.jobs_in_state(site, JobState::Ready), vec![a, b]);
        s.set_job_state(a, JobState::StagedIn, 2.0, "");
        assert_eq!(s.jobs_in_state(site, JobState::Ready), vec![b]);
        assert_eq!(s.jobs_in_state(site, JobState::StagedIn), vec![a]);
        assert_eq!(s.count_in_state(site, JobState::StagedIn), 1);
        s.check_indexes().unwrap();
    }

    #[test]
    fn events_appended_per_transition() {
        let mut s = Store::new();
        let site = SiteId(1);
        let a = mk_job(&mut s, site, JobState::Ready);
        s.set_job_state(a, JobState::StagedIn, 5.0, "globus");
        assert_eq!(s.events.len(), 2);
        let e = &s.events[1];
        assert_eq!((e.from, e.to, e.ts), (JobState::Ready, JobState::StagedIn, 5.0));
        assert_eq!(e.data, "globus");
    }

    #[test]
    fn noop_transition_is_silent() {
        let mut s = Store::new();
        let a = mk_job(&mut s, SiteId(1), JobState::Ready);
        let before = s.events.len();
        s.set_job_state(a, JobState::Ready, 9.0, "");
        assert_eq!(s.events.len(), before);
    }

    #[test]
    fn titem_index_and_completion() {
        let mut s = Store::new();
        let site = SiteId(1);
        let j = mk_job(&mut s, site, JobState::Ready);
        let t1 = TransferItemId(s.fresh_id());
        let t2 = TransferItemId(s.fresh_id());
        for (id, dir) in [(t1, Direction::In), (t2, Direction::Out)] {
            s.insert_titem(TransferItem {
                id,
                job_id: j,
                site_id: site,
                direction: dir,
                remote: "APS".into(),
                size_bytes: 100,
                state: TransferState::Pending,
                task_id: None,
            });
        }
        assert_eq!(s.titems_in_state(site, Direction::In, TransferState::Pending, 10), vec![t1]);
        assert!(!s.transfers_complete(j, Direction::In));
        s.set_titem_state(t1, TransferState::Active, Some(XferTaskId(7)));
        s.set_titem_state(t1, TransferState::Done, None);
        assert!(s.transfers_complete(j, Direction::In));
        assert!(!s.transfers_complete(j, Direction::Out));
        assert_eq!(s.titem(t1).unwrap().task_id, Some(XferTaskId(7)));
        s.check_indexes().unwrap();
    }

    #[test]
    fn limit_respected() {
        let mut s = Store::new();
        let site = SiteId(1);
        let j = mk_job(&mut s, site, JobState::Ready);
        for _ in 0..10 {
            let id = TransferItemId(s.fresh_id());
            s.insert_titem(TransferItem {
                id,
                job_id: j,
                site_id: site,
                direction: Direction::In,
                remote: "APS".into(),
                size_bytes: 1,
                state: TransferState::Pending,
                task_id: None,
            });
        }
        assert_eq!(s.titems_in_state(site, Direction::In, TransferState::Pending, 3).len(), 3);
    }

    #[test]
    fn children_index() {
        let mut s = Store::new();
        let p = mk_job(&mut s, SiteId(1), JobState::Ready);
        let c = JobId(s.fresh_id());
        s.insert_job(Job {
            id: c,
            site_id: SiteId(1),
            app_id: AppId(1),
            state: JobState::AwaitingParents,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "md_small".into(),
            parents: vec![p],
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: 0.0,
        });
        assert_eq!(s.children_of(p), &[c]);
    }
}
