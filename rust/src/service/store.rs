//! Concurrent relational store (substrate replacing PostgreSQL).
//!
//! The paper's service scalability result (§4.5) requires the central API
//! to sustain hundreds of concurrent launcher sessions with flat response
//! times. The store is therefore **sharded by site**: every site owns one
//! shard (jobs, sessions, batch jobs, transfer items and its slice of the
//! event log) behind its own `RwLock`, so launcher traffic for different
//! sites never contends. The read-mostly global tables (users, sites,
//! apps) sit behind a separate `RwLock`, and id-by-id routing tables map
//! entity ids to their shard. Ids and event sequence numbers come from
//! atomics, so every public method takes `&self` — [`super::core::ServiceCore`]
//! dispatches fully concurrently.
//!
//! Hot query paths stay indexed exactly as the paper calls out: *"runnable
//! Jobs are appropriately indexed in the underlying PostgreSQL database
//! [so] the response time of this endpoint is largely consistent with
//! respect to increasing number of submitted Jobs"* (§4.5). Index
//! coherence is asserted in tests, property-checked in
//! `rust/tests/prop_coordinator.rs`, and stress-checked under ≥8 client
//! threads in `rust/tests/stress_concurrency.rs`.
//!
//! Locking discipline: a method holds at most one shard lock at a time,
//! and never a shard lock together with the shards-map, routes, or global
//! lock — so there is no lock-order cycle and no deadlock. Compound
//! operations that must be atomic (session acquire, legality-checked
//! transitions plus their service-side consequences, transfer-completion
//! job advancement) execute entirely under a single shard write lock.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use super::api::{ApiError, EventsPage};
use super::models::*;
use super::persist::{CommitWait, Persist, PersistMode, ShardKey, WalRecord};
use super::state;
use crate::util::metrics;

/// Read-mostly global tables: identity and topology.
#[derive(Debug, Default)]
struct Global {
    users: BTreeMap<UserId, User>,
    sites: BTreeMap<SiteId, Site>,
    apps: BTreeMap<AppId, App>,
}

/// Insert-only routing tables: which shard owns an entity, plus the
/// cross-site DAG children index (a child may live at a different site
/// than its parent).
#[derive(Debug, Default)]
struct Routes {
    job_site: BTreeMap<JobId, SiteId>,
    session_site: BTreeMap<SessionId, SiteId>,
    titem_site: BTreeMap<TransferItemId, SiteId>,
    batch_site: BTreeMap<BatchJobId, SiteId>,
    children: BTreeMap<JobId, Vec<JobId>>,
}

/// Condvar parking lot for long-poll event subscribers ([`Store::wait_events`]).
///
/// One mutex guards all three facts — the published horizon, the closed
/// flag, and the open generation — so a notification can never be lost
/// between a watcher's predicate check and its wait, shutdown wakes every
/// parked watcher exactly once, and a *stale* gateway's close (carrying an
/// old generation) cannot shut the channel out from under a newer gateway
/// serving the same store.
#[derive(Debug, Default)]
struct WatchState {
    /// Highest *published* event horizon (the exclusive upper bound of
    /// committed event sequence numbers).
    horizon: u64,
    /// Closed: all waits return immediately (gateway shutdown).
    closed: bool,
    /// Bumped by every [`Store::open_watchers`]; closes are tagged with
    /// the generation they belong to and ignored when outdated.
    generation: u64,
}

#[derive(Debug, Default)]
struct EventWatch {
    state: Mutex<WatchState>,
    cv: Condvar,
}

/// One site's slice of the database plus its secondary indexes.
#[derive(Debug, Default)]
struct Shard {
    jobs: BTreeMap<JobId, Job>,
    sessions: BTreeMap<SessionId, Session>,
    batch_jobs: BTreeMap<BatchJobId, BatchJob>,
    titems: BTreeMap<TransferItemId, TransferItem>,
    /// Hot tail of the event log: events not yet archived to the
    /// segmented per-shard event files (everything, in ephemeral mode).
    events: Vec<Event>,
    /// Memory holds every shard event with `seq >=` this; older events
    /// are served from the persist layer's segments.
    events_trimmed_before: u64,
    jobs_by_state: BTreeMap<JobState, BTreeSet<JobId>>,
    titems_by_state: BTreeMap<(Direction, TransferState), BTreeSet<TransferItemId>>,
    titems_by_job: BTreeMap<JobId, Vec<TransferItemId>>,
}

impl Shard {
    /// Move a job to `to`, updating indexes and appending an event.
    /// The caller is responsible for having checked transition legality.
    fn set_job_state(&mut self, seq: &AtomicU64, id: JobId, to: JobState, ts: f64, data: &str) {
        let (from, site) = {
            let job = self.jobs.get_mut(&id).expect("set_job_state: unknown job");
            let from = job.state;
            if from == to {
                return;
            }
            job.state = to;
            (from, job.site_id)
        };
        if let Some(set) = self.jobs_by_state.get_mut(&from) {
            set.remove(&id);
        }
        self.jobs_by_state.entry(to).or_default().insert(id);
        self.events.push(Event {
            seq: seq.fetch_add(1, Ordering::Relaxed),
            job_id: id,
            site_id: site,
            ts,
            from,
            to,
            data: data.to_string(),
        });
    }

    /// Are all transfer items of `job` in `dir` Done?
    fn transfers_complete(&self, job: JobId, dir: Direction) -> bool {
        self.titems_by_job
            .get(&job)
            .map(|v| {
                v.iter().all(|tid| {
                    let t = &self.titems[tid];
                    t.direction != dir || t.state == TransferState::Done
                })
            })
            .unwrap_or(true)
    }

    fn release_from_session(&mut self, id: JobId) {
        let sid = match self.jobs.get_mut(&id) {
            Some(j) => j.session.take(),
            None => None,
        };
        if let Some(sid) = sid {
            if let Some(s) = self.sessions.get_mut(&sid) {
                s.acquired.remove(&id);
            }
        }
    }

    /// Created/AwaitingParents -> Ready (stage-in pending) or straight to
    /// Preprocessed when the job carries no input data.
    fn advance_past_parents(&mut self, seq: &AtomicU64, id: JobId, now: f64) {
        let has_stage_in = self
            .titems_by_job
            .get(&id)
            .map(|v| v.iter().any(|t| self.titems[t].direction == Direction::In))
            .unwrap_or(false);
        if has_stage_in {
            self.set_job_state(seq, id, JobState::Ready, now, "");
        } else {
            self.set_job_state(seq, id, JobState::StagedIn, now, "no stage-in data");
            self.set_job_state(seq, id, JobState::Preprocessed, now, "");
        }
    }

    /// Service-side consequences of a transition. Jobs that reached a
    /// terminal state are pushed to `terminals` for cross-shard DAG
    /// propagation by the caller (children may live in other shards).
    fn post_transition(
        &mut self,
        seq: &AtomicU64,
        id: JobId,
        to: JobState,
        now: f64,
        terminals: &mut Vec<JobId>,
    ) {
        match to {
            JobState::Running => {
                if let Some(j) = self.jobs.get_mut(&id) {
                    j.attempts += 1;
                }
            }
            JobState::RunDone => {
                self.release_from_session(id);
            }
            JobState::RunError | JobState::RunTimeout => {
                self.release_from_session(id);
                let (attempts, max) =
                    self.jobs.get(&id).map(|j| (j.attempts, j.max_attempts)).unwrap_or((0, 0));
                if attempts < max {
                    self.set_job_state(seq, id, JobState::RestartReady, now, "retry");
                } else {
                    self.set_job_state(seq, id, JobState::Failed, now, "retry budget exhausted");
                    terminals.push(id);
                }
            }
            JobState::Postprocessed => {
                // Jobs without stage-out data complete immediately.
                if self.transfers_complete(id, Direction::Out) {
                    self.set_job_state(seq, id, JobState::JobFinished, now, "no stage-out data");
                    terminals.push(id);
                }
            }
            JobState::JobFinished | JobState::Failed => {
                terminals.push(id);
            }
            _ => {}
        }
    }

    /// Legality-checked transition plus its consequences, atomically under
    /// the caller-held shard write lock.
    fn transition(
        &mut self,
        seq: &AtomicU64,
        id: JobId,
        to: JobState,
        now: f64,
        data: &str,
    ) -> Result<Vec<JobId>, ApiError> {
        let from = self
            .jobs
            .get(&id)
            .map(|j| j.state)
            .ok_or_else(|| ApiError::NotFound(format!("job {id}")))?;
        if !state::legal(from, to) {
            return Err(ApiError::IllegalTransition { job: id, from, to });
        }
        self.set_job_state(seq, id, to, now, data);
        let mut terminals = Vec::new();
        self.post_transition(seq, id, to, now, &mut terminals);
        Ok(terminals)
    }

    fn set_titem_state(&mut self, id: TransferItemId, state: TransferState, task_id: Option<XferTaskId>) {
        let item = self.titems.get_mut(&id).expect("set_titem_state: unknown item");
        let old = item.state;
        if let Some(t) = task_id {
            item.task_id = Some(t);
        }
        if old == state {
            return;
        }
        let key_old = (item.direction, old);
        let key_new = (item.direction, state);
        item.state = state;
        if let Some(set) = self.titems_by_state.get_mut(&key_old) {
            set.remove(&id);
        }
        self.titems_by_state.entry(key_new).or_default().insert(id);
    }

    /// A stage-in/out item completed: advance the owning job if all items
    /// in that direction are now done.
    fn complete_titem(&mut self, seq: &AtomicU64, id: TransferItemId, now: f64, terminals: &mut Vec<JobId>) {
        let (job_id, dir) = {
            let t = &self.titems[&id];
            (t.job_id, t.direction)
        };
        let job_state = self.jobs.get(&job_id).map(|j| j.state);
        match (dir, job_state) {
            (Direction::In, Some(JobState::Ready)) => {
                if self.transfers_complete(job_id, Direction::In) {
                    self.set_job_state(seq, job_id, JobState::StagedIn, now, "stage-in complete");
                    self.set_job_state(seq, job_id, JobState::Preprocessed, now, "");
                }
            }
            (Direction::Out, Some(JobState::Postprocessed)) => {
                if self.transfers_complete(job_id, Direction::Out) {
                    self.set_job_state(seq, job_id, JobState::JobFinished, now, "stage-out complete");
                    terminals.push(job_id);
                }
            }
            _ => {}
        }
    }

    /// FIFO acquisition over runnable states under one write lock, so two
    /// sessions racing on the same site can never double-acquire a job.
    /// RestartReady first: recovering work is older than fresh work.
    fn acquire(&mut self, session: SessionId, now: f64, max_nodes: u32, max_jobs: usize) -> Vec<Job> {
        self.sessions.get_mut(&session).expect("acquire: unknown session").heartbeat_at = now;
        let mut picked: Vec<JobId> = Vec::new();
        let mut nodes_left = max_nodes;
        for st in [JobState::RestartReady, JobState::Preprocessed] {
            let ids: Vec<JobId> =
                self.jobs_by_state.get(&st).map(|s| s.iter().copied().collect()).unwrap_or_default();
            for id in ids {
                if picked.len() >= max_jobs {
                    break;
                }
                let j = &self.jobs[&id];
                if j.session.is_some() || j.num_nodes > nodes_left {
                    continue;
                }
                nodes_left -= j.num_nodes;
                picked.push(id);
            }
        }
        let mut out = Vec::with_capacity(picked.len());
        for id in picked {
            if let Some(j) = self.jobs.get_mut(&id) {
                j.session = Some(session);
            }
            self.sessions.get_mut(&session).unwrap().acquired.insert(id);
            out.push(self.jobs[&id].clone());
        }
        out
    }

    /// Mark a session ended, release its jobs, recover running ones.
    fn end_session(
        &mut self,
        seq: &AtomicU64,
        sid: SessionId,
        now: f64,
        reason: &str,
        terminals: &mut Vec<JobId>,
    ) {
        let acquired: Vec<JobId> = match self.sessions.get_mut(&sid) {
            Some(s) => {
                s.ended = true;
                s.acquired.iter().copied().collect()
            }
            None => return,
        };
        for id in acquired {
            self.release_from_session(id);
            if self.jobs.get(&id).map(|j| j.state) == Some(JobState::Running) {
                self.set_job_state(seq, id, JobState::RunTimeout, now, reason);
                self.post_transition(seq, id, JobState::RunTimeout, now, terminals);
            }
        }
    }
}

/// All service tables + indexes, sharded by site. Mutations MUST go
/// through the provided methods so indexes stay coherent.
///
/// In [`PersistMode::Wal`] every mutating method appends the touched rows
/// (plus any events it generated) to the owning shard's write-ahead log
/// *before releasing the shard write lock*, so log order equals apply
/// order per shard; [`Store::open`] replays snapshot + WAL tail to
/// rebuild shards, routing tables and the id / event-sequence counters
/// exactly.
#[derive(Debug, Default)]
pub struct Store {
    next_id: AtomicU64,
    event_seq: AtomicU64,
    global: RwLock<Global>,
    routes: RwLock<Routes>,
    shards: RwLock<BTreeMap<SiteId, Arc<RwLock<Shard>>>>,
    persist: Option<Arc<Persist>>,
    watch: EventWatch,
}

impl Store {
    /// Ephemeral (in-memory only) store.
    pub fn new() -> Store {
        Store::default()
    }

    /// Open a store in `mode`, recovering any prior durable state.
    pub fn open(mode: &PersistMode) -> crate::Result<Store> {
        match mode {
            PersistMode::Ephemeral => Ok(Store::new()),
            PersistMode::Wal { dir, snapshot_every, fsync, events } => {
                let (persist, recovered) =
                    Persist::open(dir, *snapshot_every, *fsync, events.clone())?;
                let mut store = Store::new();
                // Replay with `persist` unset: recovery must not re-log.
                for shard in recovered {
                    let archived = shard.archived_through;
                    for rec in shard.records {
                        if let WalRecord::Event(e) = &rec {
                            if archived.is_some_and(|a| e.seq <= a) {
                                // Already durable in a segment (crash
                                // between archive and WAL truncation):
                                // count the seq, keep it out of memory.
                                store.event_seq.fetch_max(e.seq + 1, Ordering::Relaxed);
                                continue;
                            }
                        }
                        store.replay(rec);
                    }
                    if let Some(a) = archived {
                        store.event_seq.fetch_max(a + 1, Ordering::Relaxed);
                        if let Some(site) = shard.key {
                            let sh = store.shard_or_create(site);
                            sh.write().unwrap().events_trimmed_before = a + 1;
                        }
                    }
                }
                store.persist = Some(Arc::new(persist));
                Ok(store)
            }
        }
    }

    // ----- persistence ----------------------------------------------------

    /// Counters learn recovered ids: `fresh_id` must never re-issue one.
    fn bump_id(&self, id: u64) {
        self.next_id.fetch_max(id, Ordering::Relaxed);
    }

    /// Apply one recovered record: a row upsert (indexes + routes kept
    /// coherent, `check_indexes`-clean by construction) or an event
    /// append carrying its original global sequence number.
    fn replay(&self, rec: WalRecord) {
        match rec {
            WalRecord::User(u) => {
                self.bump_id(u.id.0);
                self.global.write().unwrap().users.insert(u.id, u);
            }
            WalRecord::Site(s) => {
                self.bump_id(s.id.0);
                let id = s.id;
                self.global.write().unwrap().sites.insert(id, s);
                self.shards.write().unwrap().entry(id).or_default();
            }
            WalRecord::App(a) => {
                self.bump_id(a.id.0);
                self.global.write().unwrap().apps.insert(a.id, a);
            }
            WalRecord::Job(job) => {
                self.bump_id(job.id.0);
                {
                    let mut r = self.routes.write().unwrap();
                    if !r.job_site.contains_key(&job.id) {
                        r.job_site.insert(job.id, job.site_id);
                        for &p in &job.parents {
                            r.children.entry(p).or_default().push(job.id);
                        }
                    }
                }
                let sh = self.shard_or_create(job.site_id);
                let mut sh = sh.write().unwrap();
                let old_state = sh.jobs.get(&job.id).map(|j| j.state);
                if let Some(old) = old_state {
                    if old != job.state {
                        if let Some(set) = sh.jobs_by_state.get_mut(&old) {
                            set.remove(&job.id);
                        }
                    }
                }
                sh.jobs_by_state.entry(job.state).or_default().insert(job.id);
                sh.jobs.insert(job.id, job);
            }
            WalRecord::Session(s) => {
                self.bump_id(s.id.0);
                self.routes.write().unwrap().session_site.insert(s.id, s.site_id);
                let sh = self.shard_or_create(s.site_id);
                sh.write().unwrap().sessions.insert(s.id, s);
            }
            WalRecord::Batch(b) => {
                self.bump_id(b.id.0);
                self.routes.write().unwrap().batch_site.insert(b.id, b.site_id);
                let sh = self.shard_or_create(b.site_id);
                sh.write().unwrap().batch_jobs.insert(b.id, b);
            }
            WalRecord::Titem(t) => {
                self.bump_id(t.id.0);
                self.routes.write().unwrap().titem_site.insert(t.id, t.site_id);
                let sh = self.shard_or_create(t.site_id);
                let mut sh = sh.write().unwrap();
                let old_key = sh.titems.get(&t.id).map(|o| (o.direction, o.state));
                match old_key {
                    Some(key) => {
                        if key != (t.direction, t.state) {
                            if let Some(set) = sh.titems_by_state.get_mut(&key) {
                                set.remove(&t.id);
                            }
                        }
                    }
                    None => sh.titems_by_job.entry(t.job_id).or_default().push(t.id),
                }
                sh.titems_by_state.entry((t.direction, t.state)).or_default().insert(t.id);
                sh.titems.insert(t.id, t);
            }
            WalRecord::Event(e) => {
                self.event_seq.fetch_max(e.seq + 1, Ordering::Relaxed);
                let sh = self.shard_or_create(e.site_id);
                sh.write().unwrap().events.push(e);
            }
        }
    }

    /// Append shard-scoped records while the shard write guard is held.
    /// Returns the group-commit wait handle, which the caller MUST await
    /// via [`Store::await_commit`] only after releasing the shard lock —
    /// that is what lets later mutations join the same commit group. When
    /// the append triggered a snapshot rotation, the freshly archived
    /// events are trimmed from the in-memory hot tail (they are served
    /// from the segment files from now on).
    fn wal_shard(
        &self,
        site: SiteId,
        sh: &mut Shard,
        records: Vec<WalRecord>,
    ) -> Option<CommitWait> {
        let p = self.persist.as_ref()?;
        let appended = p.append(Some(site), &records, || Self::shard_snapshot(sh));
        match appended {
            Ok(appended) => {
                if let Some(thru) = appended.archived_through {
                    sh.events.retain(|e| e.seq > thru);
                    sh.events_trimmed_before = thru + 1;
                }
                appended.wait
            }
            // Poisoned: recorded inside Persist, surfaced per-request by
            // the service layer via Store::persist_error.
            Err(_) => None,
        }
    }

    /// Full compacted row state of one shard plus its un-archived events
    /// (the snapshot holds live rows only; events go to the segmented
    /// event log, so rotation cost is O(live rows)).
    fn shard_snapshot(sh: &Shard) -> (Vec<WalRecord>, Vec<Event>) {
        let mut rows = Vec::new();
        rows.extend(sh.jobs.values().cloned().map(WalRecord::Job));
        rows.extend(sh.sessions.values().cloned().map(WalRecord::Session));
        rows.extend(sh.batch_jobs.values().cloned().map(WalRecord::Batch));
        rows.extend(sh.titems.values().cloned().map(WalRecord::Titem));
        (rows, sh.events.clone())
    }

    /// Append a global-table record. The returned wait handle is awaited
    /// by the caller after the global lock is released.
    fn wal_global(&self, record: WalRecord) -> Option<CommitWait> {
        let p = self.persist.as_ref()?;
        let g = self.global.read().unwrap();
        let appended = p.append(None, std::slice::from_ref(&record), || {
            let mut rows = Vec::new();
            rows.extend(g.users.values().cloned().map(WalRecord::User));
            rows.extend(g.sites.values().cloned().map(WalRecord::Site));
            rows.extend(g.apps.values().cloned().map(WalRecord::App));
            (rows, Vec::new())
        });
        match appended {
            Ok(a) => a.wait,
            Err(_) => None,
        }
    }

    /// Block until a group-commit fsync covers the given append (no-op
    /// for the other fsync policies). Call only after every lock the
    /// mutation held has been released.
    fn await_commit(wait: Option<CommitWait>) {
        if let Some(w) = wait {
            // A fsync failure poisons the persist handle; it is surfaced
            // as a 500 by the service layer, so the result is advisory.
            let _ = w.wait();
        }
    }

    /// [`Store::await_commit`] plus watcher notification. Every mutating
    /// method finishes through this, so a long-poll subscriber parked in
    /// [`Store::wait_events`] wakes the moment an event it asked for is
    /// applied — and, under group commit, only after the commit that
    /// produced it is durable (the notify runs after the fsync wait).
    fn commit_notify(&self, wait: Option<CommitWait>) {
        Self::await_commit(wait);
        self.notify_events();
    }

    // ----- event watchers -------------------------------------------------

    /// The next global event sequence number to be allocated — equivalently
    /// the exclusive upper bound of every event that exists. A subscriber
    /// holding cursor `since` has something to read iff
    /// `event_horizon() > since`.
    pub fn event_horizon(&self) -> u64 {
        self.event_seq.load(Ordering::Relaxed)
    }

    /// Publish the current horizon to parked watchers. No-op (no lock
    /// contention beyond one uncontended mutex) when no event was appended
    /// since the last publish.
    fn notify_events(&self) {
        let seq = self.event_horizon();
        let mut g = self.watch.state.lock().unwrap();
        if seq > g.horizon {
            g.horizon = seq;
            self.watch.cv.notify_all();
        }
    }

    /// Park the calling thread until an event with `seq >= since` has been
    /// committed, `timeout` elapses, or [`Store::close_watchers`] runs.
    /// Returns `true` when the horizon moved past `since` — the caller
    /// re-reads its event page (with a site filter the fresh event may
    /// belong to another shard, so long-poll callers loop on the result).
    pub fn wait_events(&self, since: u64, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut g = self.watch.state.lock().unwrap();
        // Sync the published horizon: it lags the real counter until the
        // first post-recovery mutation publishes, and a watcher must not
        // park behind events that already exist.
        let seq = self.event_seq.load(Ordering::Relaxed);
        if seq > g.horizon {
            g.horizon = seq;
        }
        // Park/wake accounting: `parked` flips once per call, on the
        // first actual condvar wait — an immediate answer (events already
        // exist, zero timeout) is not a park, and a woken watcher that
        // returns `true` after having parked counts as a wake (timeouts
        // and shutdown drains do not).
        let mut parked = false;
        loop {
            if g.closed || g.horizon > since {
                if parked {
                    metrics::WATCH_PARKED.dec();
                    if g.horizon > since {
                        metrics::WATCH_WAKE_TOTAL.inc();
                    }
                }
                return g.horizon > since;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                if parked {
                    metrics::WATCH_PARKED.dec();
                }
                return false;
            }
            if !parked {
                parked = true;
                metrics::WATCH_PARK_TOTAL.inc();
                metrics::WATCH_PARKED.inc();
            }
            g = self.watch.cv.wait_timeout(g, left).unwrap().0;
        }
    }

    /// Wake every parked watcher and make all future [`Store::wait_events`]
    /// calls return immediately — *unless* a newer
    /// [`Store::open_watchers`] generation has superseded `generation`
    /// (two gateways overlapping on one store during a restart: the old
    /// server's stop hook must not shut the channel the new server is
    /// serving on). Called on gateway shutdown via the HTTP server's stop
    /// hook: an armed long-poll subscription must never outlive the
    /// server that carries it.
    pub fn close_watchers(&self, generation: u64) {
        let mut g = self.watch.state.lock().unwrap();
        if g.generation == generation {
            g.closed = true;
            self.watch.cv.notify_all();
        }
    }

    /// Arm (or re-arm) the watch channel and return its new generation —
    /// the token a matching [`Store::close_watchers`] must present.
    /// Called when a gateway starts serving this store, so a previously
    /// stopped server does not permanently degrade a later server's long
    /// polls into immediate empty returns (client-side busy polling).
    pub fn open_watchers(&self) -> u64 {
        let mut g = self.watch.state.lock().unwrap();
        g.generation += 1;
        g.closed = false;
        g.generation
    }

    /// Whether the watch channel is currently closed (a gateway's stop
    /// hook ran and no newer gateway re-armed it). The health endpoint
    /// reports 503 in this state: the process is draining, new long polls
    /// would return immediately instead of parking.
    pub fn watchers_closed(&self) -> bool {
        self.watch.state.lock().unwrap().closed
    }

    /// Append this store's per-shard gauges to a Prometheus text scrape:
    /// the in-memory hot-tail event depth per site shard
    /// (`balsam_events_hot_depth{site="N"}`). Computed at scrape time —
    /// the shard set is dynamic, so these series are not statics in
    /// [`crate::util::metrics`] (its `family_names` still catalogs the
    /// family). Takes each shard read lock briefly; never called on the
    /// request hot path.
    pub fn render_metrics(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(
            out,
            "# HELP balsam_events_hot_depth In-memory hot-tail events held per site shard."
        );
        let _ = writeln!(out, "# TYPE balsam_events_hot_depth gauge");
        let shards = self.shards.read().unwrap();
        for (site, sh) in shards.iter() {
            let depth = sh.read().unwrap().events.len();
            let _ = writeln!(out, "balsam_events_hot_depth{{site=\"{}\"}} {depth}", site.0);
        }
    }

    /// First persist-layer I/O failure, if any (the store is poisoned:
    /// in-memory state may be ahead of the durable log, and all further
    /// appends fail fast).
    pub fn persist_error(&self) -> Option<String> {
        self.persist.as_ref().and_then(|p| p.error())
    }

    /// Fault-injection hook (tests): poison the persist handle as if a
    /// WAL write had failed.
    pub fn poison_persist(&self, msg: &str) {
        if let Some(p) = &self.persist {
            p.poison(msg);
        }
    }

    /// WAL bytes covered by the last fsync for `key` — what survives a
    /// power loss at this instant (crash-simulation hook for tests).
    pub fn wal_durable_len(&self, key: ShardKey) -> Option<u64> {
        self.persist.as_ref().and_then(|p| p.durable_wal_len(key))
    }

    /// Events appended to `sh` since index `ev0`, as WAL records.
    fn event_records(sh: &Shard, ev0: usize) -> Vec<WalRecord> {
        sh.events[ev0..].iter().cloned().map(WalRecord::Event).collect()
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    // ----- shard plumbing -------------------------------------------------

    fn shard(&self, site: SiteId) -> Option<Arc<RwLock<Shard>>> {
        self.shards.read().unwrap().get(&site).cloned()
    }

    fn shard_or_create(&self, site: SiteId) -> Arc<RwLock<Shard>> {
        if let Some(s) = self.shards.read().unwrap().get(&site) {
            return s.clone();
        }
        self.shards.write().unwrap().entry(site).or_default().clone()
    }

    fn all_shards(&self) -> Vec<Arc<RwLock<Shard>>> {
        self.shards.read().unwrap().values().cloned().collect()
    }

    fn all_shards_keyed(&self) -> Vec<(SiteId, Arc<RwLock<Shard>>)> {
        self.shards.read().unwrap().iter().map(|(k, v)| (*k, v.clone())).collect()
    }

    fn shard_of_job(&self, id: JobId) -> Option<Arc<RwLock<Shard>>> {
        let site = self.routes.read().unwrap().job_site.get(&id).copied()?;
        self.shard(site)
    }

    fn shard_of_session(&self, id: SessionId) -> Option<Arc<RwLock<Shard>>> {
        let site = self.routes.read().unwrap().session_site.get(&id).copied()?;
        self.shard(site)
    }

    fn shard_of_titem(&self, id: TransferItemId) -> Option<Arc<RwLock<Shard>>> {
        let site = self.routes.read().unwrap().titem_site.get(&id).copied()?;
        self.shard(site)
    }

    fn shard_of_batch(&self, id: BatchJobId) -> Option<Arc<RwLock<Shard>>> {
        let site = self.routes.read().unwrap().batch_site.get(&id).copied()?;
        self.shard(site)
    }

    // ----- global tables (users / sites / apps) ---------------------------

    pub fn insert_user(&self, user: User) {
        let rec = self.persist.is_some().then(|| WalRecord::User(user.clone()));
        self.global.write().unwrap().users.insert(user.id, user);
        if let Some(rec) = rec {
            self.commit_notify(self.wal_global(rec));
        }
    }

    pub fn user_exists(&self, id: UserId) -> bool {
        self.global.read().unwrap().users.contains_key(&id)
    }

    /// Lowest-id user with `name` (recovered-admin lookup on reopen).
    pub fn user_named(&self, name: &str) -> Option<UserId> {
        self.global.read().unwrap().users.values().find(|u| u.name == name).map(|u| u.id)
    }

    /// Register a site and eagerly create its shard.
    pub fn insert_site(&self, site: Site) {
        let id = site.id;
        let rec = self.persist.is_some().then(|| WalRecord::Site(site.clone()));
        self.global.write().unwrap().sites.insert(id, site);
        self.shards.write().unwrap().entry(id).or_default();
        if let Some(rec) = rec {
            self.commit_notify(self.wal_global(rec));
        }
    }

    pub fn site(&self, id: SiteId) -> Option<Site> {
        self.global.read().unwrap().sites.get(&id).cloned()
    }

    pub fn insert_app(&self, app: App) {
        let rec = self.persist.is_some().then(|| WalRecord::App(app.clone()));
        self.global.write().unwrap().apps.insert(app.id, app);
        if let Some(rec) = rec {
            self.commit_notify(self.wal_global(rec));
        }
    }

    /// Resolve a registered App by (site, name).
    pub fn app_for(&self, site: SiteId, name: &str) -> Option<AppId> {
        self.global
            .read()
            .unwrap()
            .apps
            .values()
            .find(|a| a.site_id == site && a.name == name)
            .map(|a| a.id)
    }

    pub fn apps_len(&self) -> usize {
        self.global.read().unwrap().apps.len()
    }

    // ----- jobs -----------------------------------------------------------

    pub fn insert_job(&self, job: Job) {
        {
            let mut r = self.routes.write().unwrap();
            r.job_site.insert(job.id, job.site_id);
            for &p in &job.parents {
                r.children.entry(p).or_default().push(job.id);
            }
        }
        let site = job.site_id;
        let sh = self.shard_or_create(site);
        let mut sh = sh.write().unwrap();
        sh.jobs_by_state.entry(job.state).or_default().insert(job.id);
        let rec = self.persist.is_some().then(|| WalRecord::Job(job.clone()));
        sh.jobs.insert(job.id, job);
        let wait = rec.and_then(|rec| self.wal_shard(site, &mut sh, vec![rec]));
        drop(sh);
        self.commit_notify(wait);
    }

    pub fn job(&self, id: JobId) -> Option<Job> {
        let sh = self.shard_of_job(id)?;
        let sh = sh.read().unwrap();
        sh.jobs.get(&id).cloned()
    }

    /// Snapshot of every job across all shards, ordered by id.
    pub fn jobs_snapshot(&self) -> Vec<Job> {
        let mut out = Vec::new();
        for sh in self.all_shards() {
            out.extend(sh.read().unwrap().jobs.values().cloned());
        }
        out.sort_by_key(|j| j.id);
        out
    }

    pub fn job_count(&self) -> usize {
        self.all_shards().iter().map(|sh| sh.read().unwrap().jobs.len()).sum()
    }

    /// Children of `parent` across all shards (DAG edges may cross sites).
    pub fn children_of(&self, parent: JobId) -> Vec<JobId> {
        self.routes.read().unwrap().children.get(&parent).cloned().unwrap_or_default()
    }

    /// Owning site of `id` (routing-table lookup: no shard lock, no row
    /// clone — the cheap existence + authorization probe).
    pub fn job_site(&self, id: JobId) -> Option<SiteId> {
        self.routes.read().unwrap().job_site.get(&id).copied()
    }

    /// Unchecked state move (no legality check, no service consequences).
    /// Exposed for index property tests; the service path is [`Store::transition`].
    pub fn set_job_state(&self, id: JobId, to: JobState, ts: f64, data: &str) {
        let sh = self.shard_of_job(id).expect("set_job_state: unknown job");
        let mut sh = sh.write().unwrap();
        let ev0 = sh.events.len();
        sh.set_job_state(&self.event_seq, id, to, ts, data);
        let mut wait = None;
        if self.persist.is_some() && sh.events.len() > ev0 {
            let job = sh.jobs.get(&id).expect("set_job_state: unknown job").clone();
            let site = job.site_id;
            let mut recs = vec![WalRecord::Job(job)];
            recs.extend(Self::event_records(&sh, ev0));
            wait = self.wal_shard(site, &mut sh, recs);
        }
        drop(sh);
        self.commit_notify(wait);
    }

    /// Legality-checked transition + service-side consequences, atomic
    /// under the owning shard's write lock. Returns the jobs that reached
    /// a terminal state (input to DAG propagation).
    pub fn transition(&self, id: JobId, to: JobState, now: f64, data: &str) -> Result<Vec<JobId>, ApiError> {
        let sh = self.shard_of_job(id).ok_or_else(|| ApiError::NotFound(format!("job {id}")))?;
        let mut sh = sh.write().unwrap();
        let prior_session = sh.jobs.get(&id).and_then(|j| j.session);
        let ev0 = sh.events.len();
        let terminals = sh.transition(&self.event_seq, id, to, now, data)?;
        let mut wait = None;
        if self.persist.is_some() {
            let job = sh.jobs.get(&id).expect("transitioned job").clone();
            let site = job.site_id;
            let mut recs = vec![WalRecord::Job(job)];
            // The consequences may have released the job from its session.
            if let Some(sid) = prior_session {
                if let Some(s) = sh.sessions.get(&sid) {
                    recs.push(WalRecord::Session(s.clone()));
                }
            }
            recs.extend(Self::event_records(&sh, ev0));
            wait = self.wal_shard(site, &mut sh, recs);
        }
        drop(sh);
        self.commit_notify(wait);
        Ok(terminals)
    }

    /// Apply an ordered sequence of legality-checked transitions (the
    /// launcher bulk-sync protocol), coalescing consecutive same-shard
    /// updates under one shard write lock and ONE WAL commit — a whole
    /// SessionSync batch costs one group fsync per shard run instead of
    /// one per update. Per-update rejections (unknown job, illegal edge)
    /// are collected, never fatal. Returns `(rejected, terminals)`.
    pub fn transition_batch(
        &self,
        updates: &[(JobId, JobState, String)],
        now: f64,
    ) -> (Vec<JobId>, Vec<JobId>) {
        let sites: Vec<Option<SiteId>> = {
            let routes = self.routes.read().unwrap();
            updates.iter().map(|u| routes.job_site.get(&u.0).copied()).collect()
        };
        let mut rejected = Vec::new();
        let mut terminals = Vec::new();
        let mut i = 0usize;
        while i < updates.len() {
            let Some(site) = sites[i] else {
                rejected.push(updates[i].0);
                i += 1;
                continue;
            };
            let Some(shard) = self.shard(site) else {
                rejected.push(updates[i].0);
                i += 1;
                continue;
            };
            let mut sh = shard.write().unwrap();
            let ev0 = sh.events.len();
            let mut touched: Vec<JobId> = Vec::new();
            let mut sessions: Vec<SessionId> = Vec::new();
            while i < updates.len() && sites[i] == Some(site) {
                let u = &updates[i];
                let prior_session = sh.jobs.get(&u.0).and_then(|j| j.session);
                match sh.transition(&self.event_seq, u.0, u.1, now, &u.2) {
                    Ok(mut t) => {
                        touched.push(u.0);
                        sessions.extend(prior_session);
                        terminals.append(&mut t);
                    }
                    Err(_) => rejected.push(u.0),
                }
                i += 1;
            }
            let mut wait = None;
            if self.persist.is_some() && !touched.is_empty() {
                touched.sort_unstable();
                touched.dedup();
                sessions.sort_unstable();
                sessions.dedup();
                let mut recs = Vec::new();
                for id in &touched {
                    if let Some(j) = sh.jobs.get(id) {
                        recs.push(WalRecord::Job(j.clone()));
                    }
                }
                // The consequences may have released jobs from sessions.
                for sid in &sessions {
                    if let Some(s) = sh.sessions.get(sid) {
                        recs.push(WalRecord::Session(s.clone()));
                    }
                }
                recs.extend(Self::event_records(&sh, ev0));
                wait = self.wal_shard(site, &mut sh, recs);
            }
            drop(sh);
            self.commit_notify(wait);
        }
        (rejected, terminals)
    }

    /// Initial routing of a freshly inserted job: AwaitingParents while any
    /// parent is unfinished, else advance past parents immediately.
    ///
    /// The state is re-checked under the shard write lock: a job that has
    /// already left Created/AwaitingParents (e.g. two parents finishing on
    /// different gateway threads both propagating to the same child) is
    /// left untouched, so concurrent propagation can never regress a job
    /// that another thread already advanced.
    pub fn advance_new_job(&self, id: JobId, now: f64, parents_pending: bool) {
        if let Some(sh) = self.shard_of_job(id) {
            let mut sh = sh.write().unwrap();
            let st = sh.jobs.get(&id).map(|j| j.state);
            match st {
                Some(JobState::Created) | Some(JobState::AwaitingParents) => {}
                _ => return,
            }
            let ev0 = sh.events.len();
            if parents_pending {
                if st == Some(JobState::Created) {
                    sh.set_job_state(&self.event_seq, id, JobState::AwaitingParents, now, "");
                }
            } else {
                sh.advance_past_parents(&self.event_seq, id, now);
            }
            let mut wait = None;
            if self.persist.is_some() && sh.events.len() > ev0 {
                let job = sh.jobs.get(&id).expect("advanced job").clone();
                let site = job.site_id;
                let mut recs = vec![WalRecord::Job(job)];
                recs.extend(Self::event_records(&sh, ev0));
                wait = self.wal_shard(site, &mut sh, recs);
            }
            drop(sh);
            self.commit_notify(wait);
        }
    }

    /// Mutate a job in place. Callers must not touch `state` or `site_id`
    /// through this (use [`Store::transition`]) — exposed for session /
    /// bench bookkeeping.
    pub fn with_job_mut<T>(&self, id: JobId, f: impl FnOnce(&mut Job) -> T) -> Option<T> {
        let sh = self.shard_of_job(id)?;
        let mut sh = sh.write().unwrap();
        let out = sh.jobs.get_mut(&id).map(f);
        let mut wait = None;
        if out.is_some() && self.persist.is_some() {
            let job = sh.jobs.get(&id).expect("mutated job").clone();
            let site = job.site_id;
            wait = self.wal_shard(site, &mut sh, vec![WalRecord::Job(job)]);
        }
        drop(sh);
        self.commit_notify(wait);
        out
    }

    /// Ids of jobs at `site` in `state` (index lookup).
    pub fn jobs_in_state(&self, site: SiteId, state: JobState) -> Vec<JobId> {
        match self.shard(site) {
            Some(sh) => sh
                .read()
                .unwrap()
                .jobs_by_state
                .get(&state)
                .map(|s| s.iter().copied().collect())
                .unwrap_or_default(),
            None => Vec::new(),
        }
    }

    /// Full rows of jobs at `site` in `state` (one lock acquisition).
    pub fn jobs_in_state_full(&self, site: SiteId, state: JobState) -> Vec<Job> {
        match self.shard(site) {
            Some(sh) => {
                let sh = sh.read().unwrap();
                sh.jobs_by_state
                    .get(&state)
                    .map(|s| s.iter().map(|id| sh.jobs[id].clone()).collect())
                    .unwrap_or_default()
            }
            None => Vec::new(),
        }
    }

    pub fn count_in_state(&self, site: SiteId, state: JobState) -> usize {
        match self.shard(site) {
            Some(sh) => {
                sh.read().unwrap().jobs_by_state.get(&state).map(BTreeSet::len).unwrap_or(0)
            }
            None => 0,
        }
    }

    /// Per-state counts at `site` in `JobState::ALL` order, from one
    /// consistent shard snapshot.
    pub fn counts_by_state(&self, site: SiteId) -> Vec<(JobState, usize)> {
        let Some(sh) = self.shard(site) else { return Vec::new() };
        let sh = sh.read().unwrap();
        JobState::ALL
            .iter()
            .map(|&s| (s, sh.jobs_by_state.get(&s).map(BTreeSet::len).unwrap_or(0)))
            .collect()
    }

    /// Backlog aggregates for the Backlog API, from one consistent shard
    /// snapshot: (backlog_jobs, runnable_nodes, inflight_nodes, batch_nodes).
    pub fn backlog_parts(&self, site: SiteId) -> (usize, u32, u32, u32) {
        let Some(sh) = self.shard(site) else { return (0, 0, 0, 0) };
        let sh = sh.read().unwrap();
        let count =
            |st: JobState| sh.jobs_by_state.get(&st).map(BTreeSet::len).unwrap_or(0);
        let nodes = |st: JobState| -> u32 {
            sh.jobs_by_state
                .get(&st)
                .map(|s| s.iter().map(|id| sh.jobs[id].num_nodes).sum())
                .unwrap_or(0)
        };
        let backlog_states = [
            JobState::Created,
            JobState::AwaitingParents,
            JobState::Ready,
            JobState::StagedIn,
            JobState::Preprocessed,
            JobState::RestartReady,
        ];
        let backlog_jobs = backlog_states.iter().map(|&s| count(s)).sum();
        let runnable = nodes(JobState::Preprocessed) + nodes(JobState::RestartReady);
        let inflight = nodes(JobState::Ready) + nodes(JobState::StagedIn);
        let batch = sh
            .batch_jobs
            .values()
            .filter(|b| {
                b.site_id == site
                    && matches!(
                        b.state,
                        BatchJobState::Pending | BatchJobState::Queued | BatchJobState::Running
                    )
            })
            .map(|b| b.num_nodes)
            .sum();
        (backlog_jobs, runnable, inflight, batch)
    }

    // ----- sessions -------------------------------------------------------

    pub fn insert_session(&self, session: Session) {
        self.routes.write().unwrap().session_site.insert(session.id, session.site_id);
        let site = session.site_id;
        let sh = self.shard_or_create(site);
        let mut sh = sh.write().unwrap();
        let rec = self.persist.is_some().then(|| WalRecord::Session(session.clone()));
        sh.sessions.insert(session.id, session);
        let wait = rec.and_then(|rec| self.wal_shard(site, &mut sh, vec![rec]));
        drop(sh);
        self.commit_notify(wait);
    }

    pub fn session(&self, id: SessionId) -> Option<Session> {
        let sh = self.shard_of_session(id)?;
        let sh = sh.read().unwrap();
        sh.sessions.get(&id).cloned()
    }

    pub fn session_site(&self, id: SessionId) -> Option<SiteId> {
        self.routes.read().unwrap().session_site.get(&id).copied()
    }

    /// Snapshot of every session across all shards, ordered by id.
    pub fn sessions_snapshot(&self) -> Vec<Session> {
        let mut out = Vec::new();
        for sh in self.all_shards() {
            out.extend(sh.read().unwrap().sessions.values().cloned());
        }
        out.sort_by_key(|s| s.id);
        out
    }

    /// Mutate a session in place (bench/test bookkeeping only).
    pub fn with_session_mut<T>(&self, id: SessionId, f: impl FnOnce(&mut Session) -> T) -> Option<T> {
        let sh = self.shard_of_session(id)?;
        let mut sh = sh.write().unwrap();
        let out = sh.sessions.get_mut(&id).map(f);
        let mut wait = None;
        if out.is_some() && self.persist.is_some() {
            let s = sh.sessions.get(&id).expect("mutated session").clone();
            let site = s.site_id;
            wait = self.wal_shard(site, &mut sh, vec![WalRecord::Session(s)]);
        }
        drop(sh);
        self.commit_notify(wait);
        out
    }

    pub fn heartbeat(&self, session: SessionId, now: f64) -> Result<(), ApiError> {
        let sh = self
            .shard_of_session(session)
            .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
        let mut sh = sh.write().unwrap();
        {
            let s = sh
                .sessions
                .get_mut(&session)
                .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
            if s.ended {
                return Err(ApiError::BadRequest(format!("session {session} ended")));
            }
            s.heartbeat_at = now;
        }
        let mut wait = None;
        if self.persist.is_some() {
            let s = sh.sessions.get(&session).expect("heartbeated session").clone();
            let site = s.site_id;
            wait = self.wal_shard(site, &mut sh, vec![WalRecord::Session(s)]);
        }
        drop(sh);
        self.commit_notify(wait);
        Ok(())
    }

    /// Atomically pick + mark runnable jobs for `session` (implicit
    /// heartbeat), so concurrent sessions at one site never overlap.
    pub fn acquire(
        &self,
        session: SessionId,
        now: f64,
        max_nodes: u32,
        max_jobs: usize,
    ) -> Result<Vec<Job>, ApiError> {
        let sh = self
            .shard_of_session(session)
            .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
        let mut sh = sh.write().unwrap();
        let ended = sh
            .sessions
            .get(&session)
            .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?
            .ended;
        if ended {
            return Err(ApiError::BadRequest(format!("session {session} ended")));
        }
        let out = sh.acquire(session, now, max_nodes, max_jobs);
        let mut wait = None;
        if self.persist.is_some() {
            let s = sh.sessions.get(&session).expect("acquiring session").clone();
            let site = s.site_id;
            let mut recs = Vec::with_capacity(out.len() + 1);
            recs.push(WalRecord::Session(s));
            recs.extend(out.iter().cloned().map(WalRecord::Job));
            wait = self.wal_shard(site, &mut sh, recs);
        }
        drop(sh);
        self.commit_notify(wait);
        Ok(out)
    }

    /// End a session, releasing its jobs and recovering running ones.
    /// Returns jobs that reached a terminal state during recovery.
    pub fn end_session(&self, session: SessionId, now: f64, reason: &str) -> Result<Vec<JobId>, ApiError> {
        let sh = self
            .shard_of_session(session)
            .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
        let mut sh = sh.write().unwrap();
        if !sh.sessions.contains_key(&session) {
            return Err(ApiError::NotFound(format!("session {session}")));
        }
        let acquired: Vec<JobId> = sh
            .sessions
            .get(&session)
            .map(|s| s.acquired.iter().copied().collect())
            .unwrap_or_default();
        let ev0 = sh.events.len();
        let mut terminals = Vec::new();
        sh.end_session(&self.event_seq, session, now, reason, &mut terminals);
        let mut wait = None;
        if self.persist.is_some() {
            let s = sh.sessions.get(&session).expect("ended session").clone();
            let site = s.site_id;
            let mut recs = vec![WalRecord::Session(s)];
            for id in &acquired {
                if let Some(j) = sh.jobs.get(id) {
                    recs.push(WalRecord::Job(j.clone()));
                }
            }
            recs.extend(Self::event_records(&sh, ev0));
            wait = self.wal_shard(site, &mut sh, recs);
        }
        drop(sh);
        self.commit_notify(wait);
        Ok(terminals)
    }

    /// Expire sessions whose heartbeat is older than `lease_timeout_s`
    /// (the fault-tolerance core, §4.4). Returns newly-terminal jobs.
    pub fn expire_stale(&self, now: f64, lease_timeout_s: f64) -> Vec<JobId> {
        let mut terminals = Vec::new();
        for (site, shard) in self.all_shards_keyed() {
            let mut sh = shard.write().unwrap();
            let stale: Vec<SessionId> = sh
                .sessions
                .values()
                .filter(|s| !s.ended && now - s.heartbeat_at > lease_timeout_s)
                .map(|s| s.id)
                .collect();
            if stale.is_empty() {
                continue;
            }
            let ev0 = sh.events.len();
            let mut touched: Vec<JobId> = Vec::new();
            for sid in &stale {
                if self.persist.is_some() {
                    if let Some(s) = sh.sessions.get(sid) {
                        touched.extend(s.acquired.iter().copied());
                    }
                }
                sh.end_session(&self.event_seq, *sid, now, "session lease expired", &mut terminals);
            }
            let mut wait = None;
            if self.persist.is_some() {
                let mut recs = Vec::new();
                for sid in &stale {
                    if let Some(s) = sh.sessions.get(sid) {
                        recs.push(WalRecord::Session(s.clone()));
                    }
                }
                for id in &touched {
                    if let Some(j) = sh.jobs.get(id) {
                        recs.push(WalRecord::Job(j.clone()));
                    }
                }
                recs.extend(Self::event_records(&sh, ev0));
                wait = self.wal_shard(site, &mut sh, recs);
            }
            drop(sh);
            self.commit_notify(wait);
        }
        terminals
    }

    // ----- batch jobs -----------------------------------------------------

    pub fn insert_batch_job(&self, bj: BatchJob) {
        self.routes.write().unwrap().batch_site.insert(bj.id, bj.site_id);
        let site = bj.site_id;
        let sh = self.shard_or_create(site);
        let mut sh = sh.write().unwrap();
        let rec = self.persist.is_some().then(|| WalRecord::Batch(bj.clone()));
        sh.batch_jobs.insert(bj.id, bj);
        let wait = rec.and_then(|rec| self.wal_shard(site, &mut sh, vec![rec]));
        drop(sh);
        self.commit_notify(wait);
    }

    pub fn batch_job(&self, id: BatchJobId) -> Option<BatchJob> {
        let sh = self.shard_of_batch(id)?;
        let sh = sh.read().unwrap();
        sh.batch_jobs.get(&id).cloned()
    }

    /// Snapshot of every batch job across all shards, ordered by id.
    pub fn batch_jobs_snapshot(&self) -> Vec<BatchJob> {
        let mut out = Vec::new();
        for sh in self.all_shards() {
            out.extend(sh.read().unwrap().batch_jobs.values().cloned());
        }
        out.sort_by_key(|b| b.id);
        out
    }

    pub fn batch_jobs_for_site(&self, site: SiteId) -> Vec<BatchJob> {
        match self.shard(site) {
            Some(sh) => sh.read().unwrap().batch_jobs.values().cloned().collect(),
            None => Vec::new(),
        }
    }

    /// Mutate a batch job in place (test bookkeeping only).
    pub fn with_batch_job_mut<T>(&self, id: BatchJobId, f: impl FnOnce(&mut BatchJob) -> T) -> Option<T> {
        let sh = self.shard_of_batch(id)?;
        let mut sh = sh.write().unwrap();
        let out = sh.batch_jobs.get_mut(&id).map(f);
        let mut wait = None;
        if out.is_some() && self.persist.is_some() {
            let bj = sh.batch_jobs.get(&id).expect("mutated batch job").clone();
            let site = bj.site_id;
            wait = self.wal_shard(site, &mut sh, vec![WalRecord::Batch(bj)]);
        }
        drop(sh);
        self.commit_notify(wait);
        out
    }

    /// Scheduler-driven batch-job status sync with timestamp bookkeeping.
    pub fn update_batch_job(
        &self,
        id: BatchJobId,
        state: BatchJobState,
        local_id: Option<u64>,
        now: f64,
    ) -> Result<(), ApiError> {
        let sh = self.shard_of_batch(id).ok_or_else(|| ApiError::NotFound(format!("batchjob {id}")))?;
        let mut sh = sh.write().unwrap();
        let bj = sh
            .batch_jobs
            .get_mut(&id)
            .ok_or_else(|| ApiError::NotFound(format!("batchjob {id}")))?;
        bj.state = state;
        if let Some(l) = local_id {
            bj.local_id = Some(l);
        }
        match state {
            BatchJobState::Running if bj.started_at.is_none() => bj.started_at = Some(now),
            BatchJobState::Finished | BatchJobState::Deleted if bj.ended_at.is_none() => {
                bj.ended_at = Some(now)
            }
            _ => {}
        }
        let mut wait = None;
        if self.persist.is_some() {
            let row = sh.batch_jobs.get(&id).expect("updated batch job").clone();
            let site = row.site_id;
            wait = self.wal_shard(site, &mut sh, vec![WalRecord::Batch(row)]);
        }
        drop(sh);
        self.commit_notify(wait);
        Ok(())
    }

    // ----- transfer items -------------------------------------------------

    pub fn insert_titem(&self, item: TransferItem) {
        self.routes.write().unwrap().titem_site.insert(item.id, item.site_id);
        let site = item.site_id;
        let sh = self.shard_or_create(site);
        let mut sh = sh.write().unwrap();
        sh.titems_by_state.entry((item.direction, item.state)).or_default().insert(item.id);
        sh.titems_by_job.entry(item.job_id).or_default().push(item.id);
        let rec = self.persist.is_some().then(|| WalRecord::Titem(item.clone()));
        sh.titems.insert(item.id, item);
        let wait = rec.and_then(|rec| self.wal_shard(site, &mut sh, vec![rec]));
        drop(sh);
        self.commit_notify(wait);
    }

    pub fn titem(&self, id: TransferItemId) -> Option<TransferItem> {
        let sh = self.shard_of_titem(id)?;
        let sh = sh.read().unwrap();
        sh.titems.get(&id).cloned()
    }

    /// Snapshot of every transfer item across all shards, ordered by id.
    pub fn titems_snapshot(&self) -> Vec<TransferItem> {
        let mut out = Vec::new();
        for sh in self.all_shards() {
            out.extend(sh.read().unwrap().titems.values().cloned());
        }
        out.sort_by_key(|t| t.id);
        out
    }

    pub fn titems_for_job(&self, job: JobId) -> Vec<TransferItem> {
        let Some(sh) = self.shard_of_job(job) else { return Vec::new() };
        let sh = sh.read().unwrap();
        sh.titems_by_job
            .get(&job)
            .map(|v| v.iter().map(|id| sh.titems[id].clone()).collect())
            .unwrap_or_default()
    }

    pub fn titems_in_state(
        &self,
        site: SiteId,
        dir: Direction,
        state: TransferState,
        limit: usize,
    ) -> Vec<TransferItemId> {
        match self.shard(site) {
            Some(sh) => sh
                .read()
                .unwrap()
                .titems_by_state
                .get(&(dir, state))
                .map(|s| s.iter().take(limit).copied().collect())
                .unwrap_or_default(),
            None => Vec::new(),
        }
    }

    /// Pending items whose owning job is in the matching stage (stage-in
    /// while READY, stage-out once POSTPROCESSED), from one consistent
    /// shard snapshot. `limit == 0` means unlimited.
    pub fn pending_actionable_titems(
        &self,
        site: SiteId,
        dir: Direction,
        gate: JobState,
        limit: usize,
    ) -> Vec<TransferItem> {
        let limit = if limit == 0 { usize::MAX } else { limit };
        let Some(sh) = self.shard(site) else { return Vec::new() };
        let sh = sh.read().unwrap();
        let Some(ids) = sh.titems_by_state.get(&(dir, TransferState::Pending)) else {
            return Vec::new();
        };
        ids.iter()
            .map(|id| &sh.titems[id])
            .filter(|t| sh.jobs.get(&t.job_id).map(|j| j.state == gate).unwrap_or(false))
            .take(limit)
            .cloned()
            .collect()
    }

    /// Unchecked single-item state set (index maintenance only). The
    /// service path is [`Store::update_titems`].
    pub fn set_titem_state(&self, id: TransferItemId, state: TransferState, task_id: Option<XferTaskId>) {
        let sh = self.shard_of_titem(id).expect("set_titem_state: unknown item");
        let mut sh = sh.write().unwrap();
        sh.set_titem_state(id, state, task_id);
        let mut wait = None;
        if self.persist.is_some() {
            let t = sh.titems.get(&id).expect("updated titem").clone();
            let site = t.site_id;
            wait = self.wal_shard(site, &mut sh, vec![WalRecord::Titem(t)]);
        }
        drop(sh);
        self.commit_notify(wait);
    }

    /// Bulk transfer-item status sync: validate every id, then apply the
    /// updates in order, coalescing consecutive same-shard runs under
    /// one shard write lock and ONE WAL commit — a whole
    /// SyncTransferItems batch costs one group fsync per shard run, not
    /// one per item. Advances owning jobs on completion; returns jobs
    /// that reached a terminal state (stage-out done).
    pub fn update_titems(
        &self,
        updates: &[(TransferItemId, TransferState, Option<XferTaskId>)],
        now: f64,
    ) -> Result<Vec<JobId>, ApiError> {
        let sites: Vec<SiteId> = {
            let routes = self.routes.read().unwrap();
            let mut sites = Vec::with_capacity(updates.len());
            for (id, _, _) in updates {
                match routes.titem_site.get(id) {
                    Some(s) => sites.push(*s),
                    None => return Err(ApiError::NotFound(format!("transfer item {id}"))),
                }
            }
            sites
        };
        let mut terminals = Vec::new();
        let mut i = 0usize;
        while i < updates.len() {
            let site = sites[i];
            let Some(shard) = self.shard(site) else {
                i += 1;
                continue;
            };
            let mut sh = shard.write().unwrap();
            let ev0 = sh.events.len();
            let mut touched_items: Vec<TransferItemId> = Vec::new();
            let mut touched_jobs: Vec<JobId> = Vec::new();
            while i < updates.len() && sites[i] == site {
                let (id, state, task_id) = updates[i];
                sh.set_titem_state(id, state, task_id);
                touched_items.push(id);
                if state == TransferState::Done {
                    if let Some(job_id) = sh.titems.get(&id).map(|t| t.job_id) {
                        touched_jobs.push(job_id);
                    }
                    sh.complete_titem(&self.event_seq, id, now, &mut terminals);
                }
                i += 1;
            }
            let mut wait = None;
            if self.persist.is_some() {
                touched_items.dedup();
                touched_jobs.sort_unstable();
                touched_jobs.dedup();
                let mut recs = Vec::new();
                for id in &touched_items {
                    if let Some(t) = sh.titems.get(id) {
                        recs.push(WalRecord::Titem(t.clone()));
                    }
                }
                // Completions may have advanced the owning jobs.
                for jid in &touched_jobs {
                    if let Some(j) = sh.jobs.get(jid) {
                        recs.push(WalRecord::Job(j.clone()));
                    }
                }
                recs.extend(Self::event_records(&sh, ev0));
                wait = self.wal_shard(site, &mut sh, recs);
            }
            drop(sh);
            self.commit_notify(wait);
        }
        Ok(terminals)
    }

    /// Are all transfer items of `job` in `dir` Done?
    pub fn transfers_complete(&self, job: JobId, dir: Direction) -> bool {
        match self.shard_of_job(job) {
            Some(sh) => sh.read().unwrap().transfers_complete(job, dir),
            None => true,
        }
    }

    // ----- events ---------------------------------------------------------

    /// Merged event log across all shards, ordered by global sequence:
    /// the in-memory hot tail plus (in WAL mode) the cold history read
    /// back from the per-shard event segments.
    ///
    /// Phase 1 holds all shard read guards simultaneously (acquired in
    /// site order) so the memory cut is consistent and gap-free: a
    /// sequence number is allocated and committed under its shard's write
    /// lock, so once every read guard is held, no event below the
    /// observed maximum can still be in flight — a `since` pager never
    /// skips events. This is the one deliberate exception to the
    /// one-lock-at-a-time rule; it cannot deadlock because writers only
    /// ever hold a single shard lock and readers acquire in a fixed
    /// order.
    ///
    /// Phase 2 reads the cold segments with NO locks held (segment data
    /// below each shard's captured trim point is immutable), so a large
    /// archive scan never stalls mutations. Events at or above the
    /// captured trim point are dropped from the archive read — they are
    /// already in the memory cut, even if a concurrent rotation archives
    /// them mid-scan. Archive read failures are loud ([`ApiError`]-level
    /// at the public API), never a silent gap.
    fn events_cut(&self, since: u64) -> Result<EventsPage, String> {
        let shards = self.all_shards_keyed();
        let mut out = Vec::new();
        let mut cold: Vec<(SiteId, u64)> = Vec::new();
        {
            let guards: Vec<_> = shards.iter().map(|(k, s)| (*k, s.read().unwrap())).collect();
            for (site, g) in &guards {
                out.extend(g.events.iter().filter(|e| e.seq >= since).cloned());
                if since < g.events_trimmed_before {
                    cold.push((*site, g.events_trimmed_before));
                }
            }
        }
        let mut truncated_before: Option<u64> = None;
        for (site, upper) in cold {
            if let Some(t) = self.merge_cold_events(site, since, upper, &mut out)? {
                truncated_before = Some(truncated_before.map_or(t, |x| x.max(t)));
            }
        }
        out.sort_by_key(|e| e.seq);
        Ok(EventsPage { truncated_before, events: out })
    }

    /// Merge one shard's cold-archive events (`since <= seq < trim`) into
    /// `out` and return the shard's retention marker, if the request
    /// reaches below retained history. The marker is re-read AFTER the
    /// archive scan: retention may delete segments mid-read (tolerated as
    /// missing files), and the post-read marker covers exactly what could
    /// have vanished — the page is complete from it on. Shared by the
    /// global cut ([`Store::events_page`]) and the per-site subscription
    /// path so the two can never drift apart.
    fn merge_cold_events(
        &self,
        site: SiteId,
        since: u64,
        trim: u64,
        out: &mut Vec<Event>,
    ) -> Result<Option<u64>, String> {
        let Some(p) = &self.persist else { return Ok(None) };
        if since >= trim {
            return Ok(None);
        }
        let archived = p.read_archived(Some(site), since)?;
        out.extend(archived.into_iter().filter(|e| e.seq < trim));
        match p.truncated_before(Some(site)) {
            Some(t) if since < t => Ok(Some(t)),
            _ => Ok(None),
        }
    }

    /// Merged event log across all shards, ordered by global sequence.
    /// Panics if the segmented archive is unreadable (corrupt storage) —
    /// the fallible paged path is [`Store::events_page`].
    pub fn events(&self) -> Vec<Event> {
        self.events_cut(0).expect("event segments unreadable").events
    }

    /// Events with sequence number >= `since`, ordered. Panics like
    /// [`Store::events`]; the service path is [`Store::events_page`].
    pub fn events_since(&self, since: usize) -> Vec<Event> {
        self.events_cut(since as u64).expect("event segments unreadable").events
    }

    /// Events with sequence number >= `since` plus the retention marker:
    /// `truncated_before = Some(n)` means events below `n` may have been
    /// dropped by event-log retention and the page is complete from `n`.
    /// An unreadable/corrupt archive is an error, never a silent gap.
    pub fn events_page(&self, since: u64) -> Result<EventsPage, ApiError> {
        self.events_cut(since).map_err(ApiError::Internal)
    }

    /// [`Store::events_page`] optionally restricted to one site's shard.
    /// The per-site path (the subscription hot path) reads a single shard
    /// lock instead of taking the global consistent cut across every
    /// shard — a hanging watcher re-checking its page never stalls other
    /// sites' traffic.
    pub fn events_page_for(
        &self,
        site: Option<SiteId>,
        since: u64,
    ) -> Result<EventsPage, ApiError> {
        match site {
            None => self.events_page(since),
            Some(site) => self.site_events_cut(site, since).map_err(ApiError::Internal),
        }
    }

    /// [`Store::events_page_for`] with a page-size credit: at most `max`
    /// events are returned (0 = unlimited), keeping the *oldest* so the
    /// subscriber's `last.seq + 1` cursor advances without gaps — the
    /// credit-based flow control behind `WatchEvents { max_events }`. A
    /// slow subscriber bounds what the server buffers per response and
    /// simply pages more often; the retention marker is unaffected (it
    /// describes history below `since`, not the capped tail).
    pub fn events_page_limited(
        &self,
        site: Option<SiteId>,
        since: u64,
        max: usize,
    ) -> Result<EventsPage, ApiError> {
        let mut page = self.events_page_for(site, since)?;
        if max > 0 && page.events.len() > max {
            page.events.truncate(max);
        }
        Ok(page)
    }

    /// One shard's events with `seq >= since`: the in-memory hot tail plus
    /// (in WAL mode) the cold history from that shard's event segments.
    /// Gap-free for the same reason as [`Store::events_cut`] — a sequence
    /// number is allocated and committed under this shard's write lock, so
    /// the read guard sees every event below the observed maximum.
    fn site_events_cut(&self, site: SiteId, since: u64) -> Result<EventsPage, String> {
        let Some(shard) = self.shard(site) else {
            return Ok(EventsPage::default());
        };
        let (mut out, trim) = {
            let g = shard.read().unwrap();
            let mem: Vec<Event> = g.events.iter().filter(|e| e.seq >= since).cloned().collect();
            (mem, g.events_trimmed_before)
        };
        let truncated_before = self.merge_cold_events(site, since, trim, &mut out)?;
        out.sort_by_key(|e| e.seq);
        Ok(EventsPage { truncated_before, events: out })
    }

    // ----- diagnostics ----------------------------------------------------

    /// Full index-coherence check across every shard (tests/properties).
    pub fn check_indexes(&self) -> Result<(), String> {
        for (site, shard) in self.all_shards_keyed() {
            let sh = shard.read().unwrap();
            for (state, set) in &sh.jobs_by_state {
                for id in set {
                    let j = sh
                        .jobs
                        .get(id)
                        .ok_or(format!("index {:?} has ghost job {id}", (site, state)))?;
                    if j.state != *state || j.site_id != site {
                        return Err(format!(
                            "job {id} indexed under {:?} but is {:?}",
                            (site, state),
                            (j.site_id, j.state)
                        ));
                    }
                }
            }
            for j in sh.jobs.values() {
                let ok = sh
                    .jobs_by_state
                    .get(&j.state)
                    .map(|s| s.contains(&j.id))
                    .unwrap_or(false);
                if !ok {
                    return Err(format!("job {} missing from index", j.id));
                }
            }
            for (key, set) in &sh.titems_by_state {
                for id in set {
                    let t = sh.titems.get(id).ok_or(format!("ghost titem {id}"))?;
                    if (t.direction, t.state) != *key || t.site_id != site {
                        return Err(format!("titem {id} mis-indexed"));
                    }
                }
            }
            for t in sh.titems.values() {
                let ok = sh
                    .titems_by_state
                    .get(&(t.direction, t.state))
                    .map(|s| s.contains(&t.id))
                    .unwrap_or(false);
                if !ok {
                    return Err(format!("titem {} missing from index", t.id));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_job(store: &Store, site: SiteId, state: JobState) -> JobId {
        let id = JobId(store.fresh_id());
        store.insert_job(Job {
            id,
            site_id: site,
            app_id: AppId(1),
            state: JobState::Created,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "md_small".into(),
            parents: vec![],
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: 0.0,
        });
        if state != JobState::Created {
            store.set_job_state(id, state, 1.0, "");
        }
        id
    }

    #[test]
    fn state_index_tracks_transitions() {
        let s = Store::new();
        let site = SiteId(1);
        let a = mk_job(&s, site, JobState::Ready);
        let b = mk_job(&s, site, JobState::Ready);
        assert_eq!(s.jobs_in_state(site, JobState::Ready), vec![a, b]);
        s.set_job_state(a, JobState::StagedIn, 2.0, "");
        assert_eq!(s.jobs_in_state(site, JobState::Ready), vec![b]);
        assert_eq!(s.jobs_in_state(site, JobState::StagedIn), vec![a]);
        assert_eq!(s.count_in_state(site, JobState::StagedIn), 1);
        s.check_indexes().unwrap();
    }

    #[test]
    fn events_appended_per_transition() {
        let s = Store::new();
        let site = SiteId(1);
        let a = mk_job(&s, site, JobState::Ready);
        s.set_job_state(a, JobState::StagedIn, 5.0, "globus");
        let evs = s.events();
        assert_eq!(evs.len(), 2);
        let e = &evs[1];
        assert_eq!((e.from, e.to, e.ts), (JobState::Ready, JobState::StagedIn, 5.0));
        assert_eq!(e.data, "globus");
    }

    #[test]
    fn noop_transition_is_silent() {
        let s = Store::new();
        let a = mk_job(&s, SiteId(1), JobState::Ready);
        let before = s.events().len();
        s.set_job_state(a, JobState::Ready, 9.0, "");
        assert_eq!(s.events().len(), before);
    }

    #[test]
    fn titem_index_and_completion() {
        let s = Store::new();
        let site = SiteId(1);
        let j = mk_job(&s, site, JobState::Ready);
        let t1 = TransferItemId(s.fresh_id());
        let t2 = TransferItemId(s.fresh_id());
        for (id, dir) in [(t1, Direction::In), (t2, Direction::Out)] {
            s.insert_titem(TransferItem {
                id,
                job_id: j,
                site_id: site,
                direction: dir,
                remote: "APS".into(),
                size_bytes: 100,
                state: TransferState::Pending,
                task_id: None,
            });
        }
        assert_eq!(s.titems_in_state(site, Direction::In, TransferState::Pending, 10), vec![t1]);
        assert!(!s.transfers_complete(j, Direction::In));
        s.set_titem_state(t1, TransferState::Active, Some(XferTaskId(7)));
        s.set_titem_state(t1, TransferState::Done, None);
        assert!(s.transfers_complete(j, Direction::In));
        assert!(!s.transfers_complete(j, Direction::Out));
        assert_eq!(s.titem(t1).unwrap().task_id, Some(XferTaskId(7)));
        s.check_indexes().unwrap();
    }

    #[test]
    fn limit_respected() {
        let s = Store::new();
        let site = SiteId(1);
        let j = mk_job(&s, site, JobState::Ready);
        for _ in 0..10 {
            let id = TransferItemId(s.fresh_id());
            s.insert_titem(TransferItem {
                id,
                job_id: j,
                site_id: site,
                direction: Direction::In,
                remote: "APS".into(),
                size_bytes: 1,
                state: TransferState::Pending,
                task_id: None,
            });
        }
        assert_eq!(s.titems_in_state(site, Direction::In, TransferState::Pending, 3).len(), 3);
    }

    #[test]
    fn children_index() {
        let s = Store::new();
        let p = mk_job(&s, SiteId(1), JobState::Ready);
        let c = JobId(s.fresh_id());
        s.insert_job(Job {
            id: c,
            site_id: SiteId(1),
            app_id: AppId(1),
            state: JobState::AwaitingParents,
            params: vec![],
            tags: vec![],
            num_nodes: 1,
            workload: "md_small".into(),
            parents: vec![p],
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: 0.0,
        });
        assert_eq!(s.children_of(p), vec![c]);
    }

    #[test]
    fn shards_isolate_sites() {
        let s = Store::new();
        let a = mk_job(&s, SiteId(1), JobState::Ready);
        let b = mk_job(&s, SiteId(2), JobState::Ready);
        assert_eq!(s.jobs_in_state(SiteId(1), JobState::Ready), vec![a]);
        assert_eq!(s.jobs_in_state(SiteId(2), JobState::Ready), vec![b]);
        assert_eq!(s.job_count(), 2);
        assert_eq!(s.jobs_snapshot().len(), 2);
        s.check_indexes().unwrap();
    }

    #[test]
    fn event_seq_totally_orders_across_shards() {
        let s = Store::new();
        let a = mk_job(&s, SiteId(1), JobState::Created);
        let b = mk_job(&s, SiteId(2), JobState::Created);
        s.set_job_state(a, JobState::Ready, 1.0, "");
        s.set_job_state(b, JobState::Ready, 2.0, "");
        s.set_job_state(a, JobState::StagedIn, 3.0, "");
        let evs = s.events();
        assert_eq!(evs.len(), 3);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "dense global order");
        }
        assert_eq!(evs[0].job_id, a);
        assert_eq!(evs[1].job_id, b);
        assert_eq!(s.events_since(1).len(), 2);
    }

    #[test]
    fn wal_mode_survives_reopen() {
        use crate::service::persist::{EventLogConfig, FsyncPolicy};
        let dir = std::env::temp_dir().join(format!("balsam-store-wal-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mode = PersistMode::Wal {
            dir: dir.clone(),
            snapshot_every: 4,
            fsync: FsyncPolicy::Group { records: 2, interval_ms: 5 },
            events: EventLogConfig { segment_bytes: 256, retain_bytes: 0, retain_age_s: 0 },
        };
        let (jobs0, evs0) = {
            let s = Store::open(&mode).unwrap();
            s.insert_site(Site {
                id: SiteId(1),
                owner: UserId(1),
                name: "theta".into(),
                hostname: "h".into(),
                path: "/p".into(),
            });
            let a = mk_job(&s, SiteId(1), JobState::Ready);
            let b = mk_job(&s, SiteId(1), JobState::Ready);
            // Enough transitions to force at least one snapshot rotation.
            s.set_job_state(a, JobState::StagedIn, 2.0, "globus");
            s.set_job_state(a, JobState::Preprocessed, 2.5, "");
            s.set_job_state(b, JobState::StagedIn, 3.0, "");
            (s.jobs_snapshot(), s.events())
        };
        let s2 = Store::open(&mode).unwrap();
        s2.check_indexes().unwrap();
        let jstr = |jobs: &[Job]| -> Vec<String> { jobs.iter().map(|j| j.to_json().to_string()).collect() };
        let estr = |evs: &[Event]| -> Vec<String> { evs.iter().map(|e| e.to_json().to_string()).collect() };
        assert_eq!(jstr(&s2.jobs_snapshot()), jstr(&jobs0));
        assert_eq!(estr(&s2.events()), estr(&evs0));
        // The global event sequence continues with no gap.
        let last = evs0.last().unwrap().seq;
        let a = jobs0[0].id;
        s2.set_job_state(a, JobState::Running, 4.0, "");
        assert_eq!(s2.events().last().unwrap().seq, last + 1);
        // Fresh ids never collide with recovered ones.
        let max_id = jobs0.iter().map(|j| j.id.0).max().unwrap();
        assert!(s2.fresh_id() > max_id);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wait_events_wakes_on_commit_and_times_out_when_idle() {
        let s = std::sync::Arc::new(Store::new());
        let a = mk_job(&s, SiteId(1), JobState::Ready);
        let horizon = s.event_horizon();
        // Nothing beyond the horizon yet: a bounded wait times out.
        let t0 = Instant::now();
        assert!(!s.wait_events(horizon, Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        // But a cursor behind the horizon returns immediately.
        assert!(s.wait_events(horizon - 1, Duration::from_millis(0)));
        // A mutation committed on another thread wakes a parked watcher.
        let s2 = s.clone();
        let writer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            s2.set_job_state(a, JobState::StagedIn, 1.0, "");
        });
        assert!(s.wait_events(horizon, Duration::from_secs(10)), "watcher never woke");
        assert_eq!(s.events_page_for(Some(SiteId(1)), horizon).unwrap().events.len(), 1);
        writer.join().unwrap();
    }

    #[test]
    fn close_watchers_unparks_and_stays_closed() {
        let s = std::sync::Arc::new(Store::new());
        let horizon = s.event_horizon();
        let generation = s.open_watchers();
        let s2 = s.clone();
        let parked = std::thread::spawn(move || s2.wait_events(horizon, Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(30));
        let t0 = Instant::now();
        s.close_watchers(generation);
        // The parked watcher returns promptly (no event arrived: false).
        assert!(!parked.join().unwrap());
        assert!(t0.elapsed() < Duration::from_secs(5));
        // Closed is sticky: later waits return without parking.
        let t0 = Instant::now();
        s.wait_events(horizon, Duration::from_secs(30));
        assert!(t0.elapsed() < Duration::from_secs(1));
        // Re-opening (a fresh gateway over the same store) restores real
        // parking instead of leaving long polls permanently degraded.
        let next_generation = s.open_watchers();
        let t0 = Instant::now();
        assert!(!s.wait_events(horizon, Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        // A STALE close (the old gateway's stop hook firing after the new
        // gateway armed) must not shut the new generation's channel.
        s.close_watchers(generation);
        let t0 = Instant::now();
        assert!(!s.wait_events(horizon, Duration::from_millis(30)));
        assert!(t0.elapsed() >= Duration::from_millis(25), "stale close degraded the channel");
        // The matching generation still closes it.
        s.close_watchers(next_generation);
        let t0 = Instant::now();
        s.wait_events(horizon, Duration::from_secs(30));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn site_filtered_event_pages_split_by_shard() {
        let s = Store::new();
        let a = mk_job(&s, SiteId(1), JobState::Ready);
        let b = mk_job(&s, SiteId(2), JobState::Ready);
        s.set_job_state(a, JobState::StagedIn, 1.0, "");
        s.set_job_state(b, JobState::StagedIn, 2.0, "");
        let all = s.events_page_for(None, 0).unwrap().events;
        let s1 = s.events_page_for(Some(SiteId(1)), 0).unwrap().events;
        let s2 = s.events_page_for(Some(SiteId(2)), 0).unwrap().events;
        assert_eq!(all.len(), s1.len() + s2.len());
        assert!(s1.iter().all(|e| e.site_id == SiteId(1)));
        assert!(s2.iter().all(|e| e.site_id == SiteId(2)));
        // Unknown site: an empty page, not an error.
        assert!(s.events_page_for(Some(SiteId(99)), 0).unwrap().events.is_empty());
    }

    #[test]
    fn concurrent_inserts_and_transitions_stay_coherent() {
        let s = std::sync::Arc::new(Store::new());
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let site = SiteId(t % 2 + 1);
                    for _ in 0..50 {
                        let id = mk_job(&s, site, JobState::Ready);
                        s.set_job_state(id, JobState::StagedIn, 1.0, "");
                        s.set_job_state(id, JobState::Preprocessed, 1.0, "");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.job_count(), 200);
        assert_eq!(
            s.count_in_state(SiteId(1), JobState::Preprocessed)
                + s.count_in_state(SiteId(2), JobState::Preprocessed),
            200
        );
        // Every event got a unique sequence number.
        let evs = s.events();
        let mut seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        seqs.dedup();
        assert_eq!(seqs.len(), evs.len());
        s.check_indexes().unwrap();
    }
}
