//! The Balsam central service (paper §3.1).
//!
//! A centrally-hosted, multi-tenant bookkeeping service: the root of the
//! relational data model (Users → Sites → Apps → Jobs, plus BatchJobs,
//! TransferItems, Sessions and the EventLog), fronted by a typed REST-ish
//! API. The service is *passive*: sites and clients drive all state
//! changes; the only autonomous behaviour is session-lease expiry, which
//! recovers jobs from ungracefully-terminated launchers (§4.4).
//!
//! In simulated mode the service is called in-process; in real-time mode
//! the same [`core::ServiceCore`] sits behind the HTTP gateway
//! ([`http_gw`]) and is exercised over sockets, like the hosted AWS
//! deployment in the paper.

// The wire-facing modules (every `ApiRequest`/`ApiResponse` variant and
// every row type/field crosses the HTTP and WAL boundaries) carry
// `missing_docs` at warn level: with clippy's `-D warnings` and the CI
// `RUSTDOCFLAGS="-D warnings" cargo doc` step this makes an undocumented
// new public wire item a build failure, not a doc-rot vector.
#[warn(missing_docs)]
pub mod models;
pub mod state;
pub mod store;
pub mod persist;
#[warn(missing_docs)]
pub mod api;
#[warn(missing_docs)]
pub mod codec;
pub mod core;
pub mod auth;
pub mod http_gw;

pub use api::{ApiConn, ApiError, ApiRequest, ApiResponse, EventsPage, JobCreate, JobFilter};
pub use codec::{wire_from_env, Wire};
pub use core::ServiceCore;
pub use models::*;
pub use persist::{EventLogConfig, FsyncPolicy, PersistMode};
