//! Service handler logic: multi-tenant bookkeeping over the [`Store`].
//!
//! The service is passive (client-driven) except for session-lease expiry:
//! a launcher that stops heartbeating has its jobs recovered so "critical
//! faults causing ungraceful launcher termination do not cause jobs to be
//! locked in perpetuity" (paper §3.1).



use super::api::*;
use super::auth::TokenAuthority;
use super::models::*;
use super::state;
use super::store::Store;

/// Default lease: a launcher missing heartbeats for this long is presumed
/// dead and its jobs are reset (paper: "the stale heartbeat is detected by
/// the service and affected jobs are reset").
pub const DEFAULT_LEASE_TIMEOUT_S: f64 = 60.0;

/// The central Balsam service.
pub struct ServiceCore {
    pub store: Store,
    auth: TokenAuthority,
    admin: UserId,
    pub lease_timeout_s: f64,
    /// Monotonic API-call counter (perf observability).
    pub calls: u64,
}

impl ServiceCore {
    pub fn new(secret: &[u8]) -> ServiceCore {
        let mut store = Store::new();
        let admin = UserId(store.fresh_id());
        store.users.insert(admin, User { id: admin, name: "admin".into() });
        ServiceCore {
            store,
            auth: TokenAuthority::new(secret),
            admin,
            lease_timeout_s: DEFAULT_LEASE_TIMEOUT_S,
            calls: 0,
        }
    }

    /// Issue a bearer token for an existing user.
    pub fn token_for(&self, user: UserId) -> String {
        self.auth.issue(user)
    }

    pub fn admin_token(&self) -> String {
        self.auth.issue(self.admin)
    }

    /// Entry point for every API interaction.
    pub fn handle(
        &mut self,
        now: f64,
        token: &str,
        req: ApiRequest,
    ) -> Result<ApiResponse, ApiError> {
        self.calls += 1;
        let user = self.auth.validate(token).ok_or(ApiError::Unauthorized)?;
        if !self.store.users.contains_key(&user) {
            return Err(ApiError::Unauthorized);
        }
        self.expire_stale_sessions(now);
        self.dispatch(now, user, req)
    }

    fn dispatch(
        &mut self,
        now: f64,
        user: UserId,
        req: ApiRequest,
    ) -> Result<ApiResponse, ApiError> {
        match req {
            ApiRequest::CreateUser { name } => {
                if user != self.admin {
                    return Err(ApiError::Unauthorized);
                }
                let id = UserId(self.store.fresh_id());
                self.store.users.insert(id, User { id, name });
                Ok(ApiResponse::UserId(id))
            }
            ApiRequest::CreateSite { name, hostname, path } => {
                let id = SiteId(self.store.fresh_id());
                self.store.sites.insert(id, Site { id, owner: user, name, hostname, path });
                Ok(ApiResponse::SiteId(id))
            }
            ApiRequest::RegisterApp { site, name, command_template, parameters } => {
                self.check_site(user, site)?;
                let id = AppId(self.store.fresh_id());
                self.store.apps.insert(id, App { id, site_id: site, name, command_template, parameters });
                Ok(ApiResponse::AppId(id))
            }
            ApiRequest::BulkCreateJobs { jobs } => {
                let mut ids = Vec::with_capacity(jobs.len());
                for jc in jobs {
                    ids.push(self.create_job(now, user, jc)?);
                }
                Ok(ApiResponse::JobIds(ids))
            }
            ApiRequest::ListJobs { filter } => {
                if let Some(site) = filter.site {
                    self.check_site(user, site)?;
                }
                Ok(ApiResponse::Jobs(self.query_jobs(&filter)))
            }
            ApiRequest::CountByState { site } => {
                self.check_site(user, site)?;
                let counts = JobState::ALL
                    .iter()
                    .map(|&s| (s, self.store.count_in_state(site, s)))
                    .filter(|&(_, n)| n > 0)
                    .collect();
                Ok(ApiResponse::Counts(counts))
            }
            ApiRequest::UpdateJobState { job, to, data } => {
                self.transition_job(now, user, job, to, &data)?;
                Ok(ApiResponse::Unit)
            }
            ApiRequest::BulkUpdateJobState { jobs, to, data } => {
                for j in jobs {
                    self.transition_job(now, user, j, to, &data)?;
                }
                Ok(ApiResponse::Unit)
            }
            ApiRequest::CreateSession { site, batch_job } => {
                self.check_site(user, site)?;
                let id = SessionId(self.store.fresh_id());
                self.store.sessions.insert(
                    id,
                    Session {
                        id,
                        site_id: site,
                        batch_job_id: batch_job,
                        heartbeat_at: now,
                        acquired: Default::default(),
                        ended: false,
                    },
                );
                Ok(ApiResponse::SessionId(id))
            }
            ApiRequest::SessionAcquire { session, max_nodes, max_jobs } => {
                let jobs = self.session_acquire(now, user, session, max_nodes, max_jobs)?;
                Ok(ApiResponse::Jobs(jobs))
            }
            ApiRequest::SessionHeartbeat { session } => {
                let sess = self
                    .store
                    .sessions
                    .get_mut(&session)
                    .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
                if sess.ended {
                    return Err(ApiError::BadRequest(format!("session {session} ended")));
                }
                sess.heartbeat_at = now;
                Ok(ApiResponse::Unit)
            }
            ApiRequest::SessionEnd { session } => {
                // Graceful end: release any still-acquired jobs back to the pool.
                let acquired: Vec<JobId> = match self.store.sessions.get_mut(&session) {
                    Some(s) => {
                        s.ended = true;
                        s.acquired.iter().copied().collect()
                    }
                    None => return Err(ApiError::NotFound(format!("session {session}"))),
                };
                for id in acquired {
                    self.release_from_session(id);
                    // A gracefully ended launcher never leaves jobs RUNNING;
                    // if it somehow did, recover them like a lease expiry.
                    if self.store.job(id).map(|j| j.state) == Some(JobState::Running) {
                        self.recover_job(now, id, "graceful session end with running job");
                    }
                }
                Ok(ApiResponse::Unit)
            }
            ApiRequest::CreateBatchJob { site, num_nodes, wall_time_s, mode, queue, project } => {
                self.check_site(user, site)?;
                let id = BatchJobId(self.store.fresh_id());
                self.store.batch_jobs.insert(
                    id,
                    BatchJob {
                        id,
                        site_id: site,
                        num_nodes,
                        wall_time_s,
                        mode,
                        queue,
                        project,
                        state: BatchJobState::Pending,
                        local_id: None,
                        created_at: now,
                        started_at: None,
                        ended_at: None,
                    },
                );
                Ok(ApiResponse::BatchJobId(id))
            }
            ApiRequest::ListBatchJobs { site, active_only } => {
                self.check_site(user, site)?;
                let out = self
                    .store
                    .batch_jobs
                    .values()
                    .filter(|b| b.site_id == site)
                    .filter(|b| {
                        !active_only
                            || matches!(
                                b.state,
                                BatchJobState::Pending | BatchJobState::Queued | BatchJobState::Running
                            )
                    })
                    .cloned()
                    .collect();
                Ok(ApiResponse::BatchJobs(out))
            }
            ApiRequest::UpdateBatchJob { id, state, local_id } => {
                let bj = self
                    .store
                    .batch_jobs
                    .get_mut(&id)
                    .ok_or_else(|| ApiError::NotFound(format!("batchjob {id}")))?;
                bj.state = state;
                if let Some(l) = local_id {
                    bj.local_id = Some(l);
                }
                match state {
                    BatchJobState::Running if bj.started_at.is_none() => bj.started_at = Some(now),
                    BatchJobState::Finished | BatchJobState::Deleted if bj.ended_at.is_none() => {
                        bj.ended_at = Some(now)
                    }
                    _ => {}
                }
                Ok(ApiResponse::Unit)
            }
            ApiRequest::PendingTransferItems { site, direction, limit } => {
                self.check_site(user, site)?;
                // An item is *actionable* only while its job is in the
                // matching stage: stage-in while READY, stage-out once
                // POSTPROCESSED (results exist).
                let gate = match direction {
                    Direction::In => JobState::Ready,
                    Direction::Out => JobState::Postprocessed,
                };
                let limit = if limit == 0 { usize::MAX } else { limit };
                let ids = self.store.titems_in_state(site, direction, TransferState::Pending, usize::MAX);
                let items = ids
                    .iter()
                    .map(|&i| self.store.titem(i).unwrap())
                    .filter(|t| self.store.job(t.job_id).map(|j| j.state == gate).unwrap_or(false))
                    .take(limit)
                    .cloned()
                    .collect();
                Ok(ApiResponse::TransferItems(items))
            }
            ApiRequest::UpdateTransferItems { ids, state, task_id } => {
                for id in &ids {
                    if self.store.titem(*id).is_none() {
                        return Err(ApiError::NotFound(format!("transfer item {id}")));
                    }
                }
                for id in ids {
                    self.store.set_titem_state(id, state, task_id);
                    if state == TransferState::Done {
                        self.on_titem_done(now, id);
                    }
                }
                Ok(ApiResponse::Unit)
            }
            ApiRequest::SiteBacklog { site } => {
                self.check_site(user, site)?;
                Ok(ApiResponse::Backlog(self.backlog(site)))
            }
            ApiRequest::ListEvents { since } => {
                let evs = self.store.events.get(since..).unwrap_or(&[]).to_vec();
                Ok(ApiResponse::Events(evs))
            }
        }
    }

    // ----- helpers --------------------------------------------------------

    fn check_site(&self, user: UserId, site: SiteId) -> Result<(), ApiError> {
        let s = self
            .store
            .sites
            .get(&site)
            .ok_or_else(|| ApiError::NotFound(format!("site {site}")))?;
        if s.owner != user && user != self.admin {
            return Err(ApiError::Unauthorized);
        }
        Ok(())
    }

    fn create_job(&mut self, now: f64, user: UserId, jc: JobCreate) -> Result<JobId, ApiError> {
        self.check_site(user, jc.site_id)?;
        let app = self
            .store
            .apps
            .values()
            .find(|a| a.site_id == jc.site_id && a.name == jc.app)
            .ok_or_else(|| {
                ApiError::BadRequest(format!("app '{}' not registered at site {}", jc.app, jc.site_id))
            })?
            .id;
        for p in &jc.parents {
            if self.store.job(*p).is_none() {
                return Err(ApiError::BadRequest(format!("parent {p} does not exist")));
            }
        }
        let id = JobId(self.store.fresh_id());
        self.store.insert_job(Job {
            id,
            site_id: jc.site_id,
            app_id: app,
            state: JobState::Created,
            params: jc.params,
            tags: jc.tags,
            num_nodes: jc.num_nodes.max(1),
            workload: jc.workload,
            parents: jc.parents.clone(),
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: now,
        });
        for (remote, size) in &jc.transfers_in {
            let tid = TransferItemId(self.store.fresh_id());
            self.store.insert_titem(TransferItem {
                id: tid,
                job_id: id,
                site_id: jc.site_id,
                direction: Direction::In,
                remote: remote.clone(),
                size_bytes: *size,
                state: TransferState::Pending,
                task_id: None,
            });
        }
        for (remote, size) in &jc.transfers_out {
            let tid = TransferItemId(self.store.fresh_id());
            self.store.insert_titem(TransferItem {
                id: tid,
                job_id: id,
                site_id: jc.site_id,
                direction: Direction::Out,
                remote: remote.clone(),
                size_bytes: *size,
                // Stage-out becomes Pending only after the run completes;
                // mark it Error-proof by starting Pending — the transfer
                // module only considers items whose job is POSTPROCESSED.
                state: TransferState::Pending,
                task_id: None,
            });
        }
        // Initial routing.
        let parents_pending = jc
            .parents
            .iter()
            .any(|p| self.store.job(*p).map(|j| j.state != JobState::JobFinished).unwrap_or(true));
        if parents_pending {
            self.store.set_job_state(id, JobState::AwaitingParents, now, "");
        } else {
            self.advance_past_parents(now, id);
        }
        Ok(id)
    }

    /// Created/AwaitingParents -> Ready (stage-in pending) or straight to
    /// Preprocessed when the job carries no input data.
    fn advance_past_parents(&mut self, now: f64, id: JobId) {
        let has_stage_in = self
            .store
            .titems_for_job(id)
            .iter()
            .any(|t| t.direction == Direction::In);
        if has_stage_in {
            self.store.set_job_state(id, JobState::Ready, now, "");
        } else {
            self.store.set_job_state(id, JobState::StagedIn, now, "no stage-in data");
            self.store.set_job_state(id, JobState::Preprocessed, now, "");
        }
    }

    fn query_jobs(&self, filter: &JobFilter) -> Vec<Job> {
        let limit = if filter.limit == 0 { usize::MAX } else { filter.limit };
        let match_tags = |j: &Job| {
            filter.tags.iter().all(|(k, v)| j.tags.iter().any(|(jk, jv)| jk == k && jv == v))
        };
        match (filter.site, filter.states.is_empty()) {
            (Some(site), false) => {
                // Indexed path.
                let mut out = Vec::new();
                for &s in &filter.states {
                    for id in self.store.jobs_in_state(site, s) {
                        let j = self.store.job(id).unwrap();
                        if match_tags(j) {
                            out.push(j.clone());
                            if out.len() >= limit {
                                return out;
                            }
                        }
                    }
                }
                out
            }
            _ => self
                .store
                .jobs_iter()
                .filter(|j| filter.site.map(|s| j.site_id == s).unwrap_or(true))
                .filter(|j| filter.states.is_empty() || filter.states.contains(&j.state))
                .filter(|j| match_tags(j))
                .take(limit)
                .cloned()
                .collect(),
        }
    }

    fn transition_job(
        &mut self,
        now: f64,
        user: UserId,
        id: JobId,
        to: JobState,
        data: &str,
    ) -> Result<(), ApiError> {
        let job = self.store.job(id).ok_or_else(|| ApiError::NotFound(format!("job {id}")))?;
        self.check_site(user, job.site_id)?;
        let from = job.state;
        if !state::legal(from, to) {
            return Err(ApiError::IllegalTransition { job: id, from, to });
        }
        self.store.set_job_state(id, to, now, data);
        self.post_transition(now, id, to);
        Ok(())
    }

    /// Service-side consequences of a transition.
    fn post_transition(&mut self, now: f64, id: JobId, to: JobState) {
        match to {
            JobState::Running => {
                if let Some(j) = self.store.job_mut(id) {
                    j.attempts += 1;
                }
            }
            JobState::RunDone => {
                self.release_from_session(id);
            }
            JobState::RunError | JobState::RunTimeout => {
                self.release_from_session(id);
                let (attempts, max) =
                    self.store.job(id).map(|j| (j.attempts, j.max_attempts)).unwrap_or((0, 0));
                if attempts < max {
                    self.store.set_job_state(id, JobState::RestartReady, now, "retry");
                } else {
                    self.store.set_job_state(id, JobState::Failed, now, "retry budget exhausted");
                    self.propagate_parent_outcome(now, id);
                }
            }
            JobState::Postprocessed => {
                // Jobs without stage-out data complete immediately.
                if self.store.transfers_complete(id, Direction::Out) {
                    self.store.set_job_state(id, JobState::JobFinished, now, "no stage-out data");
                    self.propagate_parent_outcome(now, id);
                }
            }
            JobState::JobFinished | JobState::Failed => {
                self.propagate_parent_outcome(now, id);
            }
            _ => {}
        }
    }

    /// A stage-in/out item completed: advance the owning job if all items
    /// in that direction are now done.
    fn on_titem_done(&mut self, now: f64, id: TransferItemId) {
        let (job_id, dir) = {
            let t = self.store.titem(id).unwrap();
            (t.job_id, t.direction)
        };
        let job_state = self.store.job(job_id).map(|j| j.state);
        match (dir, job_state) {
            (Direction::In, Some(JobState::Ready)) => {
                if self.store.transfers_complete(job_id, Direction::In) {
                    self.store.set_job_state(job_id, JobState::StagedIn, now, "stage-in complete");
                    self.store.set_job_state(job_id, JobState::Preprocessed, now, "");
                }
            }
            (Direction::Out, Some(JobState::Postprocessed)) => {
                if self.store.transfers_complete(job_id, Direction::Out) {
                    self.store.set_job_state(job_id, JobState::JobFinished, now, "stage-out complete");
                    self.propagate_parent_outcome(now, job_id);
                }
            }
            _ => {}
        }
    }

    /// DAG propagation: when a parent reaches a terminal state, advance or
    /// fail its children.
    fn propagate_parent_outcome(&mut self, now: f64, parent: JobId) {
        let parent_failed = self.store.job(parent).map(|j| j.state == JobState::Failed).unwrap_or(false);
        let children: Vec<JobId> = self.store.children_of(parent).to_vec();
        for c in children {
            let cstate = self.store.job(c).map(|j| j.state);
            if cstate != Some(JobState::AwaitingParents) {
                continue;
            }
            if parent_failed {
                self.store.set_job_state(c, JobState::Failed, now, "parent failed");
                self.propagate_parent_outcome(now, c);
                continue;
            }
            let all_done = self
                .store
                .job(c)
                .unwrap()
                .parents
                .iter()
                .all(|p| self.store.job(*p).map(|j| j.state == JobState::JobFinished).unwrap_or(false));
            if all_done {
                self.advance_past_parents(now, c);
            }
        }
    }

    fn release_from_session(&mut self, id: JobId) {
        let sid = self.store.job(id).and_then(|j| j.session);
        if let Some(sid) = sid {
            if let Some(s) = self.store.sessions.get_mut(&sid) {
                s.acquired.remove(&id);
            }
            if let Some(j) = self.store.job_mut(id) {
                j.session = None;
            }
        }
    }

    fn session_acquire(
        &mut self,
        now: f64,
        user: UserId,
        session: SessionId,
        max_nodes: u32,
        max_jobs: usize,
    ) -> Result<Vec<Job>, ApiError> {
        let (site, ended) = {
            let s = self
                .store
                .sessions
                .get(&session)
                .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
            (s.site_id, s.ended)
        };
        if ended {
            return Err(ApiError::BadRequest(format!("session {session} ended")));
        }
        self.check_site(user, site)?;
        // Heartbeat implicitly.
        self.store.sessions.get_mut(&session).unwrap().heartbeat_at = now;

        let mut picked: Vec<JobId> = Vec::new();
        let mut nodes_left = max_nodes;
        // FIFO over runnable states; RestartReady first (recovering work is
        // older than fresh work).
        for st in [JobState::RestartReady, JobState::Preprocessed] {
            for id in self.store.jobs_in_state(site, st) {
                if picked.len() >= max_jobs {
                    break;
                }
                let j = self.store.job(id).unwrap();
                if j.session.is_some() || j.num_nodes > nodes_left {
                    continue;
                }
                nodes_left -= j.num_nodes;
                picked.push(id);
            }
        }
        let mut out = Vec::with_capacity(picked.len());
        for id in picked {
            if let Some(j) = self.store.job_mut(id) {
                j.session = Some(session);
            }
            self.store.sessions.get_mut(&session).unwrap().acquired.insert(id);
            out.push(self.store.job(id).unwrap().clone());
        }
        Ok(out)
    }

    fn backlog(&self, site: SiteId) -> Backlog {
        let sum_nodes = |st: JobState| -> u32 {
            self.store
                .jobs_in_state(site, st)
                .iter()
                .map(|&id| self.store.job(id).unwrap().num_nodes)
                .sum()
        };
        let backlog_states = [
            JobState::Created,
            JobState::AwaitingParents,
            JobState::Ready,
            JobState::StagedIn,
            JobState::Preprocessed,
            JobState::RestartReady,
        ];
        Backlog {
            backlog_jobs: backlog_states.iter().map(|&s| self.store.count_in_state(site, s)).sum(),
            runnable_nodes: sum_nodes(JobState::Preprocessed) + sum_nodes(JobState::RestartReady),
            inflight_nodes: sum_nodes(JobState::Ready) + sum_nodes(JobState::StagedIn),
            batch_nodes: self
                .store
                .batch_jobs
                .values()
                .filter(|b| {
                    b.site_id == site
                        && matches!(
                            b.state,
                            BatchJobState::Pending | BatchJobState::Queued | BatchJobState::Running
                        )
                })
                .map(|b| b.num_nodes)
                .sum(),
        }
    }

    /// Reset a job after launcher death (lease expiry).
    fn recover_job(&mut self, now: f64, id: JobId, reason: &str) {
        let st = self.store.job(id).map(|j| j.state);
        if st == Some(JobState::Running) {
            self.store.set_job_state(id, JobState::RunTimeout, now, reason);
            self.post_transition(now, id, JobState::RunTimeout);
        }
    }

    /// Detect and expire stale sessions (the fault-tolerance core, §4.4).
    pub fn expire_stale_sessions(&mut self, now: f64) {
        let stale: Vec<SessionId> = self
            .store
            .sessions
            .values()
            .filter(|s| !s.ended && now - s.heartbeat_at > self.lease_timeout_s)
            .map(|s| s.id)
            .collect();
        for sid in stale {
            let acquired: Vec<JobId> = {
                let s = self.store.sessions.get_mut(&sid).unwrap();
                s.ended = true;
                s.acquired.iter().copied().collect()
            };
            for id in acquired {
                self.release_from_session(id);
                self.recover_job(now, id, "session lease expired");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ServiceCore, String, SiteId) {
        let mut svc = ServiceCore::new(b"test-secret");
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "theta".into(),
                hostname: "thetalogin1".into(),
                path: "/projects/x".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site,
            name: "EigenCorr".into(),
            command_template: "corr {h5} -imm {imm}".into(),
            parameters: vec!["h5".into(), "imm".into()],
        })
        .unwrap();
        (svc, tok, site)
    }

    fn create_one(svc: &mut ServiceCore, tok: &str, site: SiteId, xfers: bool) -> JobId {
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        if xfers {
            jc.transfers_in = vec![("APS".into(), 878_000_000)];
            jc.transfers_out = vec![("APS".into(), 55_000_000)];
        }
        svc.handle(1.0, tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0]
    }

    #[test]
    fn bad_token_rejected() {
        let (mut svc, _tok, site) = setup();
        let err = svc
            .handle(0.0, "balsam.1.deadbeef", ApiRequest::SiteBacklog { site })
            .unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
    }

    #[test]
    fn unknown_app_rejected() {
        let (mut svc, tok, site) = setup();
        let jc = JobCreate::simple(site, "NotRegistered", "x");
        let err = svc.handle(0.0, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap_err();
        assert!(matches!(err, ApiError::BadRequest(_)));
    }

    #[test]
    fn job_without_transfers_is_immediately_runnable() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, false);
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn job_with_stage_in_waits_in_ready() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, true);
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Ready);
    }

    #[test]
    fn stage_in_completion_advances_job() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, true);
        let items = svc
            .handle(2.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(items.len(), 1);
        svc.handle(3.0, &tok, ApiRequest::UpdateTransferItems {
            ids: items.iter().map(|t| t.id).collect(),
            state: TransferState::Done,
            task_id: None,
        })
        .unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn full_lifecycle_with_stage_out() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, true);
        // stage in
        let items = svc
            .handle(2.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        svc.handle(3.0, &tok, ApiRequest::UpdateTransferItems {
            ids: items.iter().map(|t| t.id).collect(),
            state: TransferState::Done,
            task_id: None,
        })
        .unwrap();
        // run
        let sid = svc
            .handle(4.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let acquired = svc
            .handle(4.5, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 10 })
            .unwrap()
            .jobs();
        assert_eq!(acquired.len(), 1);
        for (t, st) in [(5.0, JobState::Running), (25.0, JobState::RunDone), (25.1, JobState::Postprocessed)] {
            svc.handle(t, &tok, ApiRequest::UpdateJobState { job: id, to: st, data: String::new() })
                .unwrap();
        }
        // still awaiting stage-out
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Postprocessed);
        let out_items = svc
            .handle(26.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::Out, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(out_items.len(), 1);
        svc.handle(30.0, &tok, ApiRequest::UpdateTransferItems {
            ids: out_items.iter().map(|t| t.id).collect(),
            state: TransferState::Done,
            task_id: None,
        })
        .unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::JobFinished);
        // events recorded for every hop
        let evs = svc.handle(31.0, &tok, ApiRequest::ListEvents { since: 0 }).unwrap().events();
        let path: Vec<JobState> = evs.iter().filter(|e| e.job_id == id).map(|e| e.to).collect();
        assert_eq!(
            path,
            vec![
                JobState::Ready,
                JobState::StagedIn,
                JobState::Preprocessed,
                JobState::Running,
                JobState::RunDone,
                JobState::Postprocessed,
                JobState::JobFinished
            ]
        );
    }

    #[test]
    fn illegal_transition_rejected() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, false);
        let err = svc
            .handle(2.0, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::JobFinished, data: String::new() })
            .unwrap_err();
        assert!(matches!(err, ApiError::IllegalTransition { .. }));
    }

    #[test]
    fn acquire_respects_node_budget_and_exclusivity() {
        let (mut svc, tok, site) = setup();
        for _ in 0..5 {
            create_one(&mut svc, &tok, site, false);
        }
        let s1 = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let s2 = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let a1 = svc
            .handle(2.0, &tok, ApiRequest::SessionAcquire { session: s1, max_nodes: 3, max_jobs: 100 })
            .unwrap()
            .jobs();
        assert_eq!(a1.len(), 3); // node budget
        let a2 = svc
            .handle(2.0, &tok, ApiRequest::SessionAcquire { session: s2, max_nodes: 100, max_jobs: 100 })
            .unwrap()
            .jobs();
        assert_eq!(a2.len(), 2); // no overlap with s1
        let ids1: Vec<JobId> = a1.iter().map(|j| j.id).collect();
        assert!(a2.iter().all(|j| !ids1.contains(&j.id)));
    }

    #[test]
    fn stale_session_recovers_running_jobs() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.0, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::Running, data: String::new() })
            .unwrap();
        // No heartbeats for > lease timeout; any API call triggers expiry.
        svc.handle(3.0 + DEFAULT_LEASE_TIMEOUT_S + 1.0, &tok, ApiRequest::SiteBacklog { site })
            .unwrap();
        let j = svc.store.job(id).unwrap();
        assert_eq!(j.state, JobState::RestartReady);
        assert_eq!(j.session, None);
        // And the job can be re-acquired by a new session.
        let sid2 = svc
            .handle(70.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let again = svc
            .handle(71.0, &tok, ApiRequest::SessionAcquire { session: sid2, max_nodes: 8, max_jobs: 8 })
            .unwrap()
            .jobs();
        assert_eq!(again.len(), 1);
        assert_eq!(again[0].id, id);
    }

    #[test]
    fn heartbeat_keeps_session_alive() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.0, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::Running, data: String::new() })
            .unwrap();
        for i in 0..5 {
            svc.handle(3.0 + 30.0 * i as f64, &tok, ApiRequest::SessionHeartbeat { session: sid })
                .unwrap();
        }
        svc.handle(125.0, &tok, ApiRequest::SiteBacklog { site }).unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn retry_budget_exhaustion_fails_job() {
        let (mut svc, tok, site) = setup();
        let id = create_one(&mut svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        for attempt in 0..3 {
            let t = 10.0 * attempt as f64 + 2.0;
            let got = svc
                .handle(t, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
                .unwrap()
                .jobs();
            assert_eq!(got.len(), 1, "attempt {attempt}");
            svc.handle(t + 0.1, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::Running, data: String::new() })
                .unwrap();
            svc.handle(t + 0.2, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::RunError, data: "boom".into() })
                .unwrap();
            svc.handle(t + 0.3, &tok, ApiRequest::SessionHeartbeat { session: sid }).unwrap();
        }
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Failed);
    }

    #[test]
    fn dag_children_advance_after_parent_finishes() {
        let (mut svc, tok, site) = setup();
        let parent = create_one(&mut svc, &tok, site, false);
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.parents = vec![parent];
        let child =
            svc.handle(1.5, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0];
        assert_eq!(svc.store.job(child).unwrap().state, JobState::AwaitingParents);
        // Drive parent to completion.
        let sid = svc
            .handle(2.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.1, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        for st in [JobState::Running, JobState::RunDone, JobState::Postprocessed] {
            svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: parent, to: st, data: String::new() })
                .unwrap();
        }
        assert_eq!(svc.store.job(parent).unwrap().state, JobState::JobFinished);
        assert_eq!(svc.store.job(child).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn dag_children_fail_when_parent_fails() {
        let (mut svc, tok, site) = setup();
        let parent = create_one(&mut svc, &tok, site, false);
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.parents = vec![parent];
        let child =
            svc.handle(1.5, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0];
        let sid = svc
            .handle(2.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        for _ in 0..3 {
            svc.handle(2.1, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
                .unwrap();
            svc.handle(2.2, &tok, ApiRequest::UpdateJobState { job: parent, to: JobState::Running, data: String::new() })
                .unwrap();
            svc.handle(2.3, &tok, ApiRequest::UpdateJobState { job: parent, to: JobState::RunError, data: String::new() })
                .unwrap();
        }
        assert_eq!(svc.store.job(parent).unwrap().state, JobState::Failed);
        assert_eq!(svc.store.job(child).unwrap().state, JobState::Failed);
    }

    #[test]
    fn multi_tenancy_enforced() {
        let (mut svc, admin_tok, site) = setup();
        let mallory = svc
            .handle(0.0, &admin_tok, ApiRequest::CreateUser { name: "mallory".into() })
            .unwrap()
            .user_id();
        let mtok = svc.token_for(mallory);
        let err = svc.handle(1.0, &mtok, ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        let jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        let err = svc.handle(1.0, &mtok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
    }

    #[test]
    fn backlog_snapshot() {
        let (mut svc, tok, site) = setup();
        create_one(&mut svc, &tok, site, false); // -> Preprocessed
        create_one(&mut svc, &tok, site, true); // -> Ready
        let b = svc.handle(2.0, &tok, ApiRequest::SiteBacklog { site }).unwrap().backlog();
        assert_eq!(b.backlog_jobs, 2);
        assert_eq!(b.runnable_nodes, 1);
        assert_eq!(b.inflight_nodes, 1);
        assert_eq!(b.batch_nodes, 0);
    }

    #[test]
    fn tag_filtering() {
        let (mut svc, tok, site) = setup();
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.tags = vec![("experiment".into(), "XPCS".into())];
        svc.handle(1.0, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap();
        create_one(&mut svc, &tok, site, false);
        let jobs = svc
            .handle(2.0, &tok, ApiRequest::ListJobs {
                filter: JobFilter {
                    site: Some(site),
                    tags: vec![("experiment".into(), "XPCS".into())],
                    ..Default::default()
                },
            })
            .unwrap()
            .jobs();
        assert_eq!(jobs.len(), 1);
    }
}
