//! Service handler logic: multi-tenant bookkeeping over the [`Store`].
//!
//! [`ServiceCore::handle`] takes `&self`: the store is sharded by site
//! with interior mutability (see [`super::store`]), so the HTTP gateway's
//! worker threads dispatch concurrently and launcher traffic for
//! different sites never serializes behind one lock — the property behind
//! the paper's flat response times under hundreds of sessions (§4.5).
//!
//! The service is passive (client-driven) except for session-lease expiry:
//! a launcher that stops heartbeating has its jobs recovered so "critical
//! faults causing ungraceful launcher termination do not cause jobs to be
//! locked in perpetuity" (paper §3.1).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::api::*;
use super::auth::TokenAuthority;
use super::models::*;
use super::persist::PersistMode;
use super::store::Store;
use crate::util::metrics;

/// Default lease: a launcher missing heartbeats for this long is presumed
/// dead and its jobs are reset (paper: "the stale heartbeat is detected by
/// the service and affected jobs are reset").
pub const DEFAULT_LEASE_TIMEOUT_S: f64 = 60.0;

/// Default server-side cap on a `WatchEvents` hang: derived from the
/// transport's read timeout with a 5 s margin, so an armed watch always
/// answers (an empty page) before the subscriber's transport gives up on
/// the connection — a long poll must renew, never desynchronize.
pub const DEFAULT_SUBSCRIBE_MAX_MS: u64 =
    crate::util::httpd::CLIENT_READ_TIMEOUT.as_millis() as u64 - 5_000;

/// Default server-side cap on one `WatchEvents` page (events per
/// response). Bounds what a single slow subscriber can make the server
/// buffer and serialize in one response; subscribers with a deep cursor
/// simply page more often (credit-based flow control). Clients may lower
/// it per request via `WatchEvents { max_events }`, never raise it.
pub const DEFAULT_WATCH_PAGE_MAX: usize = 1024;

/// The central Balsam service.
pub struct ServiceCore {
    pub store: Store,
    auth: TokenAuthority,
    admin: UserId,
    pub lease_timeout_s: f64,
    /// Server-side clamp on `WatchEvents { timeout_ms }` (CLI:
    /// `balsam service --subscribe-max-ms`).
    pub subscribe_max_ms: u64,
    /// Server-side clamp on one `WatchEvents` page, events (CLI:
    /// `balsam service --watch-page-max`; 0 = unlimited). A per-request
    /// `max_events` credit can only lower it.
    pub watch_page_max: usize,
    /// Free subscription-parking slots. Every armed `WatchEvents` hang
    /// pins the gateway worker thread that carries it, so parked watches
    /// are capped — `http_gw::serve_with` sizes this to `workers - 1`,
    /// guaranteeing at least one worker always remains for the writes
    /// that wake the watchers. With no slot free a watch degrades to a
    /// non-blocking probe (the subscriber re-arms), never to starvation.
    subscribe_free: AtomicU64,
    /// Monotonic API-call counter (perf observability).
    calls: AtomicU64,
}

/// RAII permit for one parked `WatchEvents` hang; dropping it returns
/// the slot.
struct WatchSlot<'a>(&'a AtomicU64);

impl Drop for WatchSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_add(1, Ordering::Relaxed);
        metrics::WATCH_SLOTS_FREE.inc();
    }
}

impl ServiceCore {
    /// Ephemeral service (state dies with the process).
    pub fn new(secret: &[u8]) -> ServiceCore {
        ServiceCore::with_persist(secret, PersistMode::Ephemeral)
            .expect("ephemeral store cannot fail to open")
    }

    /// Service with an explicit durability mode. In [`PersistMode::Wal`]
    /// the store is recovered from `dir` before serving: jobs, sessions,
    /// transfer items, batch jobs, the event log and the id / sequence
    /// counters all survive process death (the paper's PostgreSQL role),
    /// and the recovered admin identity keeps previously issued tokens
    /// valid as long as the signing secret is unchanged.
    pub fn with_persist(secret: &[u8], mode: PersistMode) -> crate::Result<ServiceCore> {
        let store = Store::open(&mode)?;
        let admin = match store.user_named("admin") {
            Some(id) => id,
            None => {
                let id = UserId(store.fresh_id());
                store.insert_user(User { id, name: "admin".into() });
                id
            }
        };
        Ok(ServiceCore {
            store,
            auth: TokenAuthority::new(secret),
            admin,
            lease_timeout_s: DEFAULT_LEASE_TIMEOUT_S,
            subscribe_max_ms: DEFAULT_SUBSCRIBE_MAX_MS,
            watch_page_max: DEFAULT_WATCH_PAGE_MAX,
            // Unbounded until a gateway sizes it: in-process callers
            // (simulations, tests) have no worker pool to starve.
            subscribe_free: AtomicU64::new(u64::MAX),
            calls: AtomicU64::new(0),
        })
    }

    /// Cap the number of concurrently *parked* `WatchEvents` hangs (see
    /// `subscribe_free`). Called by the gateway at serve time with
    /// `workers - 1`; may be lowered to 0 to disable parking entirely
    /// (every watch degrades to a non-blocking probe).
    pub fn set_subscribe_slots(&self, slots: u64) {
        self.subscribe_free.store(slots, Ordering::Relaxed);
        // Gauge mirror for the sizing guidance in docs/OPERATIONS.md.
        // Process-global, so it tracks the most recently sized gateway
        // (in practice: the one serving) — clamped because the in-process
        // default is the u64::MAX sentinel.
        metrics::WATCH_SLOTS_FREE.set(slots.min(i64::MAX as u64) as i64);
    }

    /// Take a parking permit, or `None` when every slot is armed.
    fn try_arm_watch(&self) -> Option<WatchSlot<'_>> {
        let mut cur = self.subscribe_free.load(Ordering::Relaxed);
        loop {
            if cur == 0 {
                return None;
            }
            match self.subscribe_free.compare_exchange(
                cur,
                cur - 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    metrics::WATCH_SLOTS_FREE.dec();
                    return Some(WatchSlot(&self.subscribe_free));
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Issue a bearer token for an existing user.
    pub fn token_for(&self, user: UserId) -> String {
        self.auth.issue(user)
    }

    pub fn admin_token(&self) -> String {
        self.auth.issue(self.admin)
    }

    /// Authenticate a bearer token without dispatching a request — the
    /// gateway's rate limiter keys its per-principal buckets on this
    /// *before* spending a worker on [`ServiceCore::handle`]. Same
    /// validation as `handle` (signature + user existence), so a
    /// throttled identity is always one that could have been served.
    pub fn authenticate(&self, token: &str) -> Option<UserId> {
        self.auth.validate(token).filter(|&u| self.store.user_exists(u))
    }

    /// The bootstrap admin principal (the rate limiter's exempt identity
    /// when `--rate-limit-admin-exempt` is on).
    pub fn admin_user(&self) -> UserId {
        self.admin
    }

    /// API calls served so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Entry point for every API interaction. `&self`: safe to call from
    /// any number of gateway worker threads concurrently.
    ///
    /// In a durability mode, a poisoned persist layer (any WAL / event
    /// segment I/O failure) fails the request that hit it AND every
    /// subsequent request with [`ApiError::Internal`] (a framed 500 over
    /// HTTP): in-memory state may be ahead of the log, so continuing to
    /// acknowledge mutations would silently diverge from what recovery
    /// can replay.
    pub fn handle(&self, now: f64, token: &str, req: ApiRequest) -> Result<ApiResponse, ApiError> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        let user = self.auth.validate(token).ok_or(ApiError::Unauthorized)?;
        if !self.store.user_exists(user) {
            return Err(ApiError::Unauthorized);
        }
        if let Some(e) = self.store.persist_error() {
            return Err(ApiError::Internal(e));
        }
        self.expire_stale_sessions(now);
        let out = self.dispatch(now, user, req);
        if let Some(e) = self.store.persist_error() {
            return Err(ApiError::Internal(e));
        }
        out
    }

    fn dispatch(&self, now: f64, user: UserId, req: ApiRequest) -> Result<ApiResponse, ApiError> {
        match req {
            ApiRequest::CreateUser { name } => {
                if user != self.admin {
                    return Err(ApiError::Unauthorized);
                }
                let id = UserId(self.store.fresh_id());
                self.store.insert_user(User { id, name });
                Ok(ApiResponse::UserId(id))
            }
            ApiRequest::CreateSite { name, hostname, path } => {
                let id = SiteId(self.store.fresh_id());
                self.store.insert_site(Site { id, owner: user, name, hostname, path });
                Ok(ApiResponse::SiteId(id))
            }
            ApiRequest::RegisterApp { site, name, command_template, parameters } => {
                self.check_site(user, site)?;
                let id = AppId(self.store.fresh_id());
                self.store.insert_app(App { id, site_id: site, name, command_template, parameters });
                Ok(ApiResponse::AppId(id))
            }
            ApiRequest::BulkCreateJobs { jobs } => {
                let mut ids = Vec::with_capacity(jobs.len());
                for jc in jobs {
                    ids.push(self.create_job(now, user, jc)?);
                }
                Ok(ApiResponse::JobIds(ids))
            }
            ApiRequest::ListJobs { filter } => {
                if let Some(site) = filter.site {
                    self.check_site(user, site)?;
                }
                Ok(ApiResponse::Jobs(self.query_jobs(&filter)))
            }
            ApiRequest::CountByState { site } => {
                self.check_site(user, site)?;
                let counts =
                    self.store.counts_by_state(site).into_iter().filter(|&(_, n)| n > 0).collect();
                Ok(ApiResponse::Counts(counts))
            }
            ApiRequest::UpdateJobState { job, to, data } => {
                self.transition_job(now, user, job, to, &data)?;
                Ok(ApiResponse::Unit)
            }
            ApiRequest::BulkUpdateJobState { jobs, to, data } => {
                for j in jobs {
                    self.transition_job(now, user, j, to, &data)?;
                }
                Ok(ApiResponse::Unit)
            }
            ApiRequest::CreateSession { site, batch_job } => {
                self.check_site(user, site)?;
                let id = SessionId(self.store.fresh_id());
                self.store.insert_session(Session {
                    id,
                    site_id: site,
                    batch_job_id: batch_job,
                    heartbeat_at: now,
                    acquired: Default::default(),
                    ended: false,
                });
                Ok(ApiResponse::SessionId(id))
            }
            ApiRequest::SessionAcquire { session, max_nodes, max_jobs } => {
                let site = self
                    .store
                    .session_site(session)
                    .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
                self.check_site(user, site)?;
                let jobs = self.store.acquire(session, now, max_nodes, max_jobs)?;
                Ok(ApiResponse::Jobs(jobs))
            }
            ApiRequest::SessionHeartbeat { session } => {
                self.store.heartbeat(session, now)?;
                Ok(ApiResponse::Unit)
            }
            ApiRequest::SessionSync { session, updates } => {
                let site = self
                    .store
                    .session_site(session)
                    .ok_or_else(|| ApiError::NotFound(format!("session {session}")))?;
                self.check_site(user, site)?;
                self.store.heartbeat(session, now)?;
                // Best-effort batch: an individual rejection (e.g. a job
                // already recovered by lease expiry) must not abort the
                // launcher's whole heartbeat cycle. The authorized
                // updates go through Store::transition_batch so that
                // consecutive same-shard updates — the whole batch, for
                // a launcher syncing its own site — share one WAL commit
                // (one group fsync) instead of paying one per update.
                let mut failed = Vec::new();
                let mut authorized = Vec::new();
                for (job, to, data) in updates {
                    let ok = self
                        .store
                        .job_site(job)
                        .is_some_and(|s| self.check_site(user, s).is_ok());
                    if ok {
                        authorized.push((job, to, data));
                    } else {
                        failed.push(job);
                    }
                }
                let (mut rejected, terminals) = self.store.transition_batch(&authorized, now);
                failed.append(&mut rejected);
                self.propagate_terminals(now, terminals);
                Ok(ApiResponse::JobIds(failed))
            }
            ApiRequest::SessionEnd { session } => {
                // Graceful end: release any still-acquired jobs back to the
                // pool; a gracefully ended launcher never leaves jobs
                // RUNNING, but if it somehow did, recover them like a lease
                // expiry.
                let terminals =
                    self.store.end_session(session, now, "graceful session end with running job")?;
                self.propagate_terminals(now, terminals);
                Ok(ApiResponse::Unit)
            }
            ApiRequest::CreateBatchJob { site, num_nodes, wall_time_s, mode, queue, project } => {
                self.check_site(user, site)?;
                let id = BatchJobId(self.store.fresh_id());
                self.store.insert_batch_job(BatchJob {
                    id,
                    site_id: site,
                    num_nodes,
                    wall_time_s,
                    mode,
                    queue,
                    project,
                    state: BatchJobState::Pending,
                    local_id: None,
                    created_at: now,
                    started_at: None,
                    ended_at: None,
                });
                Ok(ApiResponse::BatchJobId(id))
            }
            ApiRequest::ListBatchJobs { site, active_only } => {
                self.check_site(user, site)?;
                let out = self
                    .store
                    .batch_jobs_for_site(site)
                    .into_iter()
                    .filter(|b| {
                        !active_only
                            || matches!(
                                b.state,
                                BatchJobState::Pending | BatchJobState::Queued | BatchJobState::Running
                            )
                    })
                    .collect();
                Ok(ApiResponse::BatchJobs(out))
            }
            ApiRequest::UpdateBatchJob { id, state, local_id } => {
                self.store.update_batch_job(id, state, local_id, now)?;
                Ok(ApiResponse::Unit)
            }
            ApiRequest::PendingTransferItems { site, direction, limit } => {
                self.check_site(user, site)?;
                // An item is *actionable* only while its job is in the
                // matching stage: stage-in while READY, stage-out once
                // POSTPROCESSED (results exist).
                let gate = match direction {
                    Direction::In => JobState::Ready,
                    Direction::Out => JobState::Postprocessed,
                };
                let items = self.store.pending_actionable_titems(site, direction, gate, limit);
                Ok(ApiResponse::TransferItems(items))
            }
            ApiRequest::UpdateTransferItems { ids, state, task_id } => {
                let updates: Vec<_> = ids.into_iter().map(|id| (id, state, task_id)).collect();
                self.check_titem_sites(user, &updates)?;
                let terminals = self.store.update_titems(&updates, now)?;
                self.propagate_terminals(now, terminals);
                Ok(ApiResponse::Unit)
            }
            ApiRequest::SyncTransferItems { updates } => {
                self.check_titem_sites(user, &updates)?;
                let terminals = self.store.update_titems(&updates, now)?;
                self.propagate_terminals(now, terminals);
                Ok(ApiResponse::Unit)
            }
            ApiRequest::SiteBacklog { site } => {
                self.check_site(user, site)?;
                let (backlog_jobs, runnable_nodes, inflight_nodes, batch_nodes) =
                    self.store.backlog_parts(site);
                Ok(ApiResponse::Backlog(Backlog {
                    backlog_jobs,
                    runnable_nodes,
                    inflight_nodes,
                    batch_nodes,
                }))
            }
            ApiRequest::ListEvents { since } => {
                Ok(ApiResponse::Events(self.store.events_page(since as u64)?))
            }
            ApiRequest::WatchEvents { site, since, timeout_ms, max_events } => {
                // Long poll: answer immediately when the cursor already has
                // something to read (events, or a retention marker for a
                // cursor that fell behind), else park on the store's event
                // watch until a commit moves the horizon or the clamped
                // timeout fires. The wait runs outside every store lock —
                // a hanging subscription never blocks writers.
                //
                // Authorization: a site filter requires owning that site;
                // the unfiltered stream (every tenant's events) is
                // admin-only — otherwise the per-site check would be
                // bypassable by simply omitting the filter. (ListEvents
                // keeps its legacy any-authenticated-user behavior for
                // back-compat; WatchEvents is tenant-scoped from day one.)
                match site {
                    Some(s) => self.check_site(user, s)?,
                    None if user != self.admin => return Err(ApiError::Unauthorized),
                    None => {}
                }
                let since = since as u64;
                // Page credit: the subscriber's max_events can only lower
                // the server's own page cap (0 on either side = "no
                // opinion"). The capped page keeps the OLDEST events, so
                // the `last.seq + 1` cursor never skips history.
                let cap = match (max_events, self.watch_page_max) {
                    (0, server) => server,
                    (client, 0) => client,
                    (client, server) => client.min(server),
                };
                let timeout = Duration::from_millis(timeout_ms.min(self.subscribe_max_ms));
                // Bounded parking: arming requires a subscription slot;
                // with none free (every other worker already pinned by a
                // hang) the watch degrades to a non-blocking probe so
                // writers can always reach a worker.
                let slot = if timeout.is_zero() { None } else { self.try_arm_watch() };
                let deadline = if slot.is_some() { Instant::now() + timeout } else { Instant::now() };
                loop {
                    // Horizon first: an event committed between the page
                    // read and the wait re-triggers the wait immediately
                    // instead of being missed until the next commit.
                    let horizon = self.store.event_horizon();
                    let page = self.store.events_page_limited(site, since, cap)?;
                    if !page.events.is_empty() || page.truncated_before.is_some() {
                        return Ok(ApiResponse::Events(page));
                    }
                    let left = deadline.saturating_duration_since(Instant::now());
                    if left.is_zero() || !self.store.wait_events(horizon, left) {
                        // Timed out (or the store is shutting down): an
                        // empty page — the cursor stays valid, the
                        // subscriber re-arms.
                        return Ok(ApiResponse::Events(page));
                    }
                    // Woken: with a site filter the fresh event may belong
                    // to another shard — loop and re-check.
                }
            }
        }
    }

    // ----- helpers --------------------------------------------------------

    /// Authorize a batch of transfer-item updates: the caller must own
    /// every touched item's site (or be admin). Also surfaces NotFound for
    /// unknown ids before any update is applied.
    fn check_titem_sites(
        &self,
        user: UserId,
        updates: &[(TransferItemId, TransferState, Option<XferTaskId>)],
    ) -> Result<(), ApiError> {
        let mut checked: Vec<SiteId> = Vec::new();
        for (id, _, _) in updates {
            let site = self
                .store
                .titem(*id)
                .map(|t| t.site_id)
                .ok_or_else(|| ApiError::NotFound(format!("transfer item {id}")))?;
            if !checked.contains(&site) {
                self.check_site(user, site)?;
                checked.push(site);
            }
        }
        Ok(())
    }

    fn check_site(&self, user: UserId, site: SiteId) -> Result<(), ApiError> {
        let s = self
            .store
            .site(site)
            .ok_or_else(|| ApiError::NotFound(format!("site {site}")))?;
        if s.owner != user && user != self.admin {
            return Err(ApiError::Unauthorized);
        }
        Ok(())
    }

    fn create_job(&self, now: f64, user: UserId, jc: JobCreate) -> Result<JobId, ApiError> {
        self.check_site(user, jc.site_id)?;
        let app = self.store.app_for(jc.site_id, &jc.app).ok_or_else(|| {
            ApiError::BadRequest(format!("app '{}' not registered at site {}", jc.app, jc.site_id))
        })?;
        for p in &jc.parents {
            if self.store.job(*p).is_none() {
                return Err(ApiError::BadRequest(format!("parent {p} does not exist")));
            }
        }
        let id = JobId(self.store.fresh_id());
        self.store.insert_job(Job {
            id,
            site_id: jc.site_id,
            app_id: app,
            state: JobState::Created,
            params: jc.params,
            tags: jc.tags,
            num_nodes: jc.num_nodes.max(1),
            workload: jc.workload,
            parents: jc.parents.clone(),
            attempts: 0,
            max_attempts: 3,
            session: None,
            created_at: now,
        });
        for (remote, size) in &jc.transfers_in {
            let tid = TransferItemId(self.store.fresh_id());
            self.store.insert_titem(TransferItem {
                id: tid,
                job_id: id,
                site_id: jc.site_id,
                direction: Direction::In,
                remote: remote.clone(),
                size_bytes: *size,
                state: TransferState::Pending,
                task_id: None,
            });
        }
        for (remote, size) in &jc.transfers_out {
            let tid = TransferItemId(self.store.fresh_id());
            self.store.insert_titem(TransferItem {
                id: tid,
                job_id: id,
                site_id: jc.site_id,
                direction: Direction::Out,
                remote: remote.clone(),
                // Stage-out becomes actionable only once the job is
                // POSTPROCESSED; the transfer module gates on job state.
                state: TransferState::Pending,
                task_id: None,
            });
        }
        // Initial routing.
        let parents_pending = jc
            .parents
            .iter()
            .any(|p| self.store.job(*p).map(|j| j.state != JobState::JobFinished).unwrap_or(true));
        self.store.advance_new_job(id, now, parents_pending);
        if parents_pending {
            // Close the race where a parent reached a terminal state
            // between the pre-insert check and the children-index
            // registration (and resolve children submitted after their
            // parent already terminated).
            let any_failed = jc
                .parents
                .iter()
                .any(|p| self.store.job(*p).map(|j| j.state == JobState::Failed).unwrap_or(false));
            let all_done = jc
                .parents
                .iter()
                .all(|p| self.store.job(*p).map(|j| j.state == JobState::JobFinished).unwrap_or(false));
            if any_failed {
                if let Ok(terminals) = self.store.transition(id, JobState::Failed, now, "parent failed")
                {
                    self.propagate_terminals(now, terminals);
                }
            } else if all_done {
                self.store.advance_new_job(id, now, false);
            }
        }
        Ok(id)
    }

    fn query_jobs(&self, filter: &JobFilter) -> Vec<Job> {
        let limit = if filter.limit == 0 { usize::MAX } else { filter.limit };
        let match_tags = |j: &Job| {
            filter.tags.iter().all(|(k, v)| j.tags.iter().any(|(jk, jv)| jk == k && jv == v))
        };
        match (filter.site, filter.states.is_empty()) {
            (Some(site), false) => {
                // Indexed path.
                let mut out = Vec::new();
                for &s in &filter.states {
                    for j in self.store.jobs_in_state_full(site, s) {
                        if match_tags(&j) {
                            out.push(j);
                            if out.len() >= limit {
                                return out;
                            }
                        }
                    }
                }
                out
            }
            _ => self
                .store
                .jobs_snapshot()
                .into_iter()
                .filter(|j| filter.site.map(|s| j.site_id == s).unwrap_or(true))
                .filter(|j| filter.states.is_empty() || filter.states.contains(&j.state))
                .filter(|j| match_tags(j))
                .take(limit)
                .collect(),
        }
    }

    /// Authorization + legality-checked transition + DAG propagation.
    fn transition_job(
        &self,
        now: f64,
        user: UserId,
        id: JobId,
        to: JobState,
        data: &str,
    ) -> Result<(), ApiError> {
        let site = self
            .store
            .job_site(id)
            .ok_or_else(|| ApiError::NotFound(format!("job {id}")))?;
        self.check_site(user, site)?;
        let terminals = self.store.transition(id, to, now, data)?;
        self.propagate_terminals(now, terminals);
        Ok(())
    }

    /// DAG propagation: when parents reach a terminal state, advance or
    /// fail their children. Children may live at other sites, so this runs
    /// outside any shard lock, taking locks one shard at a time.
    fn propagate_terminals(&self, now: f64, terminals: Vec<JobId>) {
        let mut work = terminals;
        while let Some(parent) = work.pop() {
            let parent_failed =
                self.store.job(parent).map(|j| j.state == JobState::Failed).unwrap_or(false);
            for c in self.store.children_of(parent) {
                let cjob = match self.store.job(c) {
                    Some(j) => j,
                    None => continue,
                };
                if cjob.state != JobState::AwaitingParents {
                    continue;
                }
                if parent_failed {
                    if let Ok(mut t) = self.store.transition(c, JobState::Failed, now, "parent failed")
                    {
                        work.append(&mut t);
                    }
                    continue;
                }
                let all_done = cjob
                    .parents
                    .iter()
                    .all(|p| self.store.job(*p).map(|j| j.state == JobState::JobFinished).unwrap_or(false));
                if all_done {
                    self.store.advance_new_job(c, now, false);
                }
            }
        }
    }

    /// Detect and expire stale sessions (the fault-tolerance core, §4.4).
    pub fn expire_stale_sessions(&self, now: f64) {
        let terminals = self.store.expire_stale(now, self.lease_timeout_s);
        self.propagate_terminals(now, terminals);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ServiceCore, String, SiteId) {
        let svc = ServiceCore::new(b"test-secret");
        let tok = svc.admin_token();
        let site = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "theta".into(),
                hostname: "thetalogin1".into(),
                path: "/projects/x".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site,
            name: "EigenCorr".into(),
            command_template: "corr {h5} -imm {imm}".into(),
            parameters: vec!["h5".into(), "imm".into()],
        })
        .unwrap();
        (svc, tok, site)
    }

    fn create_one(svc: &ServiceCore, tok: &str, site: SiteId, xfers: bool) -> JobId {
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        if xfers {
            jc.transfers_in = vec![("APS".into(), 878_000_000)];
            jc.transfers_out = vec![("APS".into(), 55_000_000)];
        }
        svc.handle(1.0, tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0]
    }

    #[test]
    fn bad_token_rejected() {
        let (svc, _tok, site) = setup();
        let err = svc
            .handle(0.0, "balsam.1.deadbeef", ApiRequest::SiteBacklog { site })
            .unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
    }

    #[test]
    fn unknown_app_rejected() {
        let (svc, tok, site) = setup();
        let jc = JobCreate::simple(site, "NotRegistered", "x");
        let err = svc.handle(0.0, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap_err();
        assert!(matches!(err, ApiError::BadRequest(_)));
    }

    #[test]
    fn job_without_transfers_is_immediately_runnable() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, false);
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn job_with_stage_in_waits_in_ready() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, true);
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Ready);
    }

    #[test]
    fn stage_in_completion_advances_job() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, true);
        let items = svc
            .handle(2.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(items.len(), 1);
        svc.handle(3.0, &tok, ApiRequest::UpdateTransferItems {
            ids: items.iter().map(|t| t.id).collect(),
            state: TransferState::Done,
            task_id: None,
        })
        .unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn full_lifecycle_with_stage_out() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, true);
        // stage in
        let items = svc
            .handle(2.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        svc.handle(3.0, &tok, ApiRequest::UpdateTransferItems {
            ids: items.iter().map(|t| t.id).collect(),
            state: TransferState::Done,
            task_id: None,
        })
        .unwrap();
        // run
        let sid = svc
            .handle(4.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let acquired = svc
            .handle(4.5, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 10 })
            .unwrap()
            .jobs();
        assert_eq!(acquired.len(), 1);
        for (t, st) in [(5.0, JobState::Running), (25.0, JobState::RunDone), (25.1, JobState::Postprocessed)] {
            svc.handle(t, &tok, ApiRequest::UpdateJobState { job: id, to: st, data: String::new() })
                .unwrap();
        }
        // still awaiting stage-out
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Postprocessed);
        let out_items = svc
            .handle(26.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::Out, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(out_items.len(), 1);
        svc.handle(30.0, &tok, ApiRequest::UpdateTransferItems {
            ids: out_items.iter().map(|t| t.id).collect(),
            state: TransferState::Done,
            task_id: None,
        })
        .unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::JobFinished);
        // events recorded for every hop
        let evs = svc.handle(31.0, &tok, ApiRequest::ListEvents { since: 0 }).unwrap().events();
        let path: Vec<JobState> = evs.iter().filter(|e| e.job_id == id).map(|e| e.to).collect();
        assert_eq!(
            path,
            vec![
                JobState::Ready,
                JobState::StagedIn,
                JobState::Preprocessed,
                JobState::Running,
                JobState::RunDone,
                JobState::Postprocessed,
                JobState::JobFinished
            ]
        );
    }

    #[test]
    fn illegal_transition_rejected() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, false);
        let err = svc
            .handle(2.0, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::JobFinished, data: String::new() })
            .unwrap_err();
        assert!(matches!(err, ApiError::IllegalTransition { .. }));
    }

    #[test]
    fn acquire_respects_node_budget_and_exclusivity() {
        let (svc, tok, site) = setup();
        for _ in 0..5 {
            create_one(&svc, &tok, site, false);
        }
        let s1 = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let s2 = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let a1 = svc
            .handle(2.0, &tok, ApiRequest::SessionAcquire { session: s1, max_nodes: 3, max_jobs: 100 })
            .unwrap()
            .jobs();
        assert_eq!(a1.len(), 3); // node budget
        let a2 = svc
            .handle(2.0, &tok, ApiRequest::SessionAcquire { session: s2, max_nodes: 100, max_jobs: 100 })
            .unwrap()
            .jobs();
        assert_eq!(a2.len(), 2); // no overlap with s1
        let ids1: Vec<JobId> = a1.iter().map(|j| j.id).collect();
        assert!(a2.iter().all(|j| !ids1.contains(&j.id)));
    }

    #[test]
    fn stale_session_recovers_running_jobs() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.0, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::Running, data: String::new() })
            .unwrap();
        // No heartbeats for > lease timeout; any API call triggers expiry.
        svc.handle(3.0 + DEFAULT_LEASE_TIMEOUT_S + 1.0, &tok, ApiRequest::SiteBacklog { site })
            .unwrap();
        let j = svc.store.job(id).unwrap();
        assert_eq!(j.state, JobState::RestartReady);
        assert_eq!(j.session, None);
        // And the job can be re-acquired by a new session.
        let sid2 = svc
            .handle(70.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        let again = svc
            .handle(71.0, &tok, ApiRequest::SessionAcquire { session: sid2, max_nodes: 8, max_jobs: 8 })
            .unwrap()
            .jobs();
        assert_eq!(again.len(), 1);
        assert_eq!(again[0].id, id);
    }

    #[test]
    fn heartbeat_keeps_session_alive() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.0, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::Running, data: String::new() })
            .unwrap();
        for i in 0..5 {
            svc.handle(3.0 + 30.0 * i as f64, &tok, ApiRequest::SessionHeartbeat { session: sid })
                .unwrap();
        }
        svc.handle(125.0, &tok, ApiRequest::SiteBacklog { site }).unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn retry_budget_exhaustion_fails_job() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        for attempt in 0..3 {
            let t = 10.0 * attempt as f64 + 2.0;
            let got = svc
                .handle(t, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
                .unwrap()
                .jobs();
            assert_eq!(got.len(), 1, "attempt {attempt}");
            svc.handle(t + 0.1, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::Running, data: String::new() })
                .unwrap();
            svc.handle(t + 0.2, &tok, ApiRequest::UpdateJobState { job: id, to: JobState::RunError, data: "boom".into() })
                .unwrap();
            svc.handle(t + 0.3, &tok, ApiRequest::SessionHeartbeat { session: sid }).unwrap();
        }
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Failed);
    }

    #[test]
    fn session_sync_batches_heartbeat_and_updates() {
        let (svc, tok, site) = setup();
        let a = create_one(&svc, &tok, site, false);
        let b = create_one(&svc, &tok, site, false);
        let sid = svc
            .handle(1.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.0, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        svc.handle(2.1, &tok, ApiRequest::BulkUpdateJobState {
            jobs: vec![a, b],
            to: JobState::Running,
            data: String::new(),
        })
        .unwrap();
        // One round trip: heartbeat + both jobs through RunDone then
        // Postprocessed; one bogus update is rejected without aborting.
        let failed = svc
            .handle(3.0, &tok, ApiRequest::SessionSync {
                session: sid,
                updates: vec![
                    (a, JobState::RunDone, String::new()),
                    (a, JobState::Postprocessed, String::new()),
                    (b, JobState::RunDone, String::new()),
                    (b, JobState::JobFinished, String::new()), // illegal edge
                    (b, JobState::Postprocessed, String::new()),
                ],
            })
            .unwrap()
            .job_ids();
        assert_eq!(failed, vec![b]);
        // No stage-out data: both jobs completed the round trip.
        assert_eq!(svc.store.job(a).unwrap().state, JobState::JobFinished);
        assert_eq!(svc.store.job(b).unwrap().state, JobState::JobFinished);
        // The sync heartbeat kept the session alive.
        assert!(svc.store.session(sid).unwrap().heartbeat_at >= 3.0);
    }

    #[test]
    fn sync_transfer_items_mixes_done_and_error() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, true);
        let other = create_one(&svc, &tok, site, true);
        let items = svc
            .handle(2.0, &tok, ApiRequest::PendingTransferItems { site, direction: Direction::In, limit: 0 })
            .unwrap()
            .transfer_items();
        assert_eq!(items.len(), 2);
        svc.handle(3.0, &tok, ApiRequest::SyncTransferItems {
            updates: vec![
                (items[0].id, TransferState::Done, Some(XferTaskId(1))),
                (items[1].id, TransferState::Error, Some(XferTaskId(2))),
            ],
        })
        .unwrap();
        assert_eq!(svc.store.job(id).unwrap().state, JobState::Preprocessed);
        assert_eq!(svc.store.job(other).unwrap().state, JobState::Ready);
        assert_eq!(svc.store.titem(items[1].id).unwrap().state, TransferState::Error);
    }

    #[test]
    fn dag_children_advance_after_parent_finishes() {
        let (svc, tok, site) = setup();
        let parent = create_one(&svc, &tok, site, false);
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.parents = vec![parent];
        let child =
            svc.handle(1.5, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0];
        assert_eq!(svc.store.job(child).unwrap().state, JobState::AwaitingParents);
        // Drive parent to completion.
        let sid = svc
            .handle(2.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.1, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        for st in [JobState::Running, JobState::RunDone, JobState::Postprocessed] {
            svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: parent, to: st, data: String::new() })
                .unwrap();
        }
        assert_eq!(svc.store.job(parent).unwrap().state, JobState::JobFinished);
        assert_eq!(svc.store.job(child).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn dag_children_fail_when_parent_fails() {
        let (svc, tok, site) = setup();
        let parent = create_one(&svc, &tok, site, false);
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.parents = vec![parent];
        let child =
            svc.handle(1.5, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0];
        let sid = svc
            .handle(2.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        for _ in 0..3 {
            svc.handle(2.1, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
                .unwrap();
            svc.handle(2.2, &tok, ApiRequest::UpdateJobState { job: parent, to: JobState::Running, data: String::new() })
                .unwrap();
            svc.handle(2.3, &tok, ApiRequest::UpdateJobState { job: parent, to: JobState::RunError, data: String::new() })
                .unwrap();
        }
        assert_eq!(svc.store.job(parent).unwrap().state, JobState::Failed);
        assert_eq!(svc.store.job(child).unwrap().state, JobState::Failed);
    }

    #[test]
    fn child_of_already_terminal_parent_resolves_at_creation() {
        let (svc, tok, site) = setup();
        let parent = create_one(&svc, &tok, site, false);
        let sid = svc
            .handle(2.0, &tok, ApiRequest::CreateSession { site, batch_job: None })
            .unwrap()
            .session_id();
        svc.handle(2.1, &tok, ApiRequest::SessionAcquire { session: sid, max_nodes: 8, max_jobs: 8 })
            .unwrap();
        for st in [JobState::Running, JobState::RunDone, JobState::Postprocessed] {
            svc.handle(3.0, &tok, ApiRequest::UpdateJobState { job: parent, to: st, data: String::new() })
                .unwrap();
        }
        assert_eq!(svc.store.job(parent).unwrap().state, JobState::JobFinished);
        // Submitted after the parent finished: must not be stuck awaiting.
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.parents = vec![parent];
        let child =
            svc.handle(4.0, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap().job_ids()[0];
        assert_eq!(svc.store.job(child).unwrap().state, JobState::Preprocessed);
    }

    #[test]
    fn multi_tenancy_enforced() {
        let (svc, admin_tok, site) = setup();
        let mallory = svc
            .handle(0.0, &admin_tok, ApiRequest::CreateUser { name: "mallory".into() })
            .unwrap()
            .user_id();
        let mtok = svc.token_for(mallory);
        let err = svc.handle(1.0, &mtok, ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        let jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        let err = svc.handle(1.0, &mtok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        // Transfer-item status sync of a foreign site is also rejected.
        let id = create_one(&svc, &admin_tok, site, true);
        let titem = svc.store.titems_for_job(id)[0].id;
        let err = svc
            .handle(2.0, &mtok, ApiRequest::SyncTransferItems {
                updates: vec![(titem, TransferState::Done, None)],
            })
            .unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        let err = svc
            .handle(2.0, &mtok, ApiRequest::UpdateTransferItems {
                ids: vec![titem],
                state: TransferState::Done,
                task_id: None,
            })
            .unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
    }

    #[test]
    fn backlog_snapshot() {
        let (svc, tok, site) = setup();
        create_one(&svc, &tok, site, false); // -> Preprocessed
        create_one(&svc, &tok, site, true); // -> Ready
        let b = svc.handle(2.0, &tok, ApiRequest::SiteBacklog { site }).unwrap().backlog();
        assert_eq!(b.backlog_jobs, 2);
        assert_eq!(b.runnable_nodes, 1);
        assert_eq!(b.inflight_nodes, 1);
        assert_eq!(b.batch_nodes, 0);
    }

    #[test]
    fn watch_events_returns_immediately_when_events_exist() {
        let (svc, tok, site) = setup();
        create_one(&svc, &tok, site, false); // emits Ready/StagedIn/... events
        let t0 = std::time::Instant::now();
        let page = svc
            .handle(2.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: 0,
                timeout_ms: 30_000,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        assert!(!page.events.is_empty());
        assert!(page.truncated_before.is_none());
        assert!(t0.elapsed() < Duration::from_secs(5), "watch must not hang past events");
    }

    #[test]
    fn watch_events_times_out_with_empty_page() {
        let (svc, tok, site) = setup();
        create_one(&svc, &tok, site, false);
        let cursor = svc.store.event_horizon() as usize;
        let t0 = std::time::Instant::now();
        let page = svc
            .handle(2.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: cursor,
                timeout_ms: 50,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        assert!(page.events.is_empty());
        assert!(t0.elapsed() >= Duration::from_millis(45), "must hang up to the timeout");
        // Non-blocking probe: timeout_ms = 0 returns at once.
        let t0 = std::time::Instant::now();
        svc.handle(2.0, &tok, ApiRequest::WatchEvents { site: None, since: cursor, timeout_ms: 0, max_events: 0 })
            .unwrap();
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn watch_events_wakes_on_commit_from_another_thread() {
        let (svc, tok, site) = setup();
        let id = create_one(&svc, &tok, site, false);
        let svc = std::sync::Arc::new(svc);
        let cursor = svc.store.event_horizon() as usize;
        let svc2 = svc.clone();
        let tok2 = tok.clone();
        let writer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(40));
            svc2.handle(3.0, &tok2, ApiRequest::UpdateJobState {
                job: id,
                to: JobState::Running,
                data: String::new(),
            })
            .unwrap();
        });
        let t0 = std::time::Instant::now();
        let page = svc
            .handle(3.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: cursor,
                timeout_ms: 20_000,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        writer.join().unwrap();
        assert_eq!(page.events.len(), 1);
        assert_eq!(page.events[0].to, JobState::Running);
        assert!(t0.elapsed() < Duration::from_secs(10), "push must beat the timeout");
    }

    #[test]
    fn watch_events_site_filter_ignores_foreign_shards() {
        let (svc, tok, site) = setup();
        // A second site whose traffic must NOT answer site-1 watches.
        let other = svc
            .handle(0.0, &tok, ApiRequest::CreateSite {
                name: "cori".into(),
                hostname: "c".into(),
                path: "/p".into(),
            })
            .unwrap()
            .site_id();
        svc.handle(0.0, &tok, ApiRequest::RegisterApp {
            site: other,
            name: "EigenCorr".into(),
            command_template: "corr".into(),
            parameters: vec![],
        })
        .unwrap();
        let cursor = svc.store.event_horizon() as usize;
        create_one(&svc, &tok, other, false); // events on the OTHER shard
        let page = svc
            .handle(2.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: cursor,
                timeout_ms: 50,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        assert!(page.events.is_empty(), "foreign-site events leaked into the filter");
        // Unfiltered watch sees them immediately.
        let page = svc
            .handle(2.0, &tok, ApiRequest::WatchEvents { site: None, since: cursor, timeout_ms: 0, max_events: 0 })
            .unwrap()
            .events_page();
        assert!(!page.events.is_empty());
    }

    #[test]
    fn watch_parking_degrades_to_probe_when_slots_exhausted() {
        let (svc, tok, site) = setup();
        svc.set_subscribe_slots(0);
        let cursor = svc.store.event_horizon() as usize;
        let t0 = std::time::Instant::now();
        let page = svc
            .handle(1.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: cursor,
                timeout_ms: 10_000,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        assert!(page.events.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(500), "no slot: must not park");
        // Slots restored: the same watch parks again.
        svc.set_subscribe_slots(1);
        let t0 = std::time::Instant::now();
        svc.handle(1.0, &tok, ApiRequest::WatchEvents {
            site: Some(site),
            since: cursor,
            timeout_ms: 50,
            max_events: 0,
        })
        .unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(45));
    }

    /// Tentpole scenario: a stalled, tiny-credit subscriber never wedges
    /// commits. Writers keep committing at full speed while a watcher
    /// stalled at the old horizon pulls nothing; it then drains the whole
    /// backlog in bounded `max_events` pages, gap-free, with oversized
    /// credit asks clamped by the server-side cap.
    #[test]
    fn stalled_watcher_never_wedges_commits() {
        let (mut svc, tok, site) = setup();
        svc.watch_page_max = 3;
        let base = svc.store.event_horizon();
        // Burst of commits while the subscriber is stalled: every commit
        // must succeed immediately — a slow or absent watcher has no
        // handle on the write path (the wait runs outside store locks and
        // the page credit bounds what any later pull serializes).
        let t0 = std::time::Instant::now();
        for i in 0..40u32 {
            svc.handle(1.0 + f64::from(i), &tok, ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate::simple(site, "EigenCorr", "xpcs")],
            })
            .unwrap();
        }
        assert!(t0.elapsed() < Duration::from_secs(5), "commits wedged behind a stalled watcher");
        let horizon = svc.store.event_horizon();
        assert!(horizon - base >= 40, "burst must have committed events");
        // The stalled subscriber wakes up and drains with 2-event credit:
        // each page honors min(client 2, server 3) and starts exactly at
        // the cursor — bounded pages, no gaps, full coverage.
        let mut since = base as usize;
        let mut seen = 0u64;
        loop {
            let page = svc
                .handle(50.0, &tok, ApiRequest::WatchEvents {
                    site: Some(site),
                    since,
                    timeout_ms: 0,
                    max_events: 2,
                })
                .unwrap()
                .events_page();
            if page.events.is_empty() {
                break;
            }
            assert!(page.events.len() <= 2, "credit violated: {} events", page.events.len());
            assert_eq!(page.events[0].seq, since as u64, "page must start at the cursor");
            for w in page.events.windows(2) {
                assert!(w[0].seq < w[1].seq);
            }
            seen += page.events.len() as u64;
            since = (page.events.last().unwrap().seq + 1) as usize;
        }
        assert_eq!(seen, horizon - base, "bounded pages must cover the whole backlog");
        // Credit clamps: an oversized ask is capped by the server; a zero
        // ask takes the server default.
        let page = svc
            .handle(51.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: base as usize,
                timeout_ms: 0,
                max_events: 1000,
            })
            .unwrap()
            .events_page();
        assert_eq!(page.events.len(), 3, "server cap must clamp oversized credit");
        let page = svc
            .handle(51.0, &tok, ApiRequest::WatchEvents {
                site: Some(site),
                since: base as usize,
                timeout_ms: 0,
                max_events: 0,
            })
            .unwrap()
            .events_page();
        assert_eq!(page.events.len(), 3, "zero credit takes the server default cap");
    }

    #[test]
    fn watch_events_foreign_site_unauthorized() {
        let (svc, admin_tok, site) = setup();
        let mallory = svc
            .handle(0.0, &admin_tok, ApiRequest::CreateUser { name: "mallory".into() })
            .unwrap()
            .user_id();
        let mtok = svc.token_for(mallory);
        let req = ApiRequest::WatchEvents { site: Some(site), since: 0, timeout_ms: 0, max_events: 0 };
        let err = svc.handle(1.0, &mtok, req).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        // Omitting the filter must not bypass the per-site check: the
        // unfiltered stream is admin-only.
        let req = ApiRequest::WatchEvents { site: None, since: 0, timeout_ms: 0, max_events: 0 };
        let err = svc.handle(1.0, &mtok, req).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
    }

    #[test]
    fn tag_filtering() {
        let (svc, tok, site) = setup();
        let mut jc = JobCreate::simple(site, "EigenCorr", "xpcs");
        jc.tags = vec![("experiment".into(), "XPCS".into())];
        svc.handle(1.0, &tok, ApiRequest::BulkCreateJobs { jobs: vec![jc] }).unwrap();
        create_one(&svc, &tok, site, false);
        let jobs = svc
            .handle(2.0, &tok, ApiRequest::ListJobs {
                filter: JobFilter {
                    site: Some(site),
                    tags: vec![("experiment".into(), "XPCS".into())],
                    ..Default::default()
                },
            })
            .unwrap()
            .jobs();
        assert_eq!(jobs.len(), 1);
    }
}
