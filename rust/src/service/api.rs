//! Typed REST-ish API: the unifying data model and service interactions
//! "upon which all Balsam components and user workflows are authored"
//! (paper §2). Every site module and client speaks this API — in-process
//! in simulated mode, JSON-over-HTTP through [`super::http_gw`] in
//! real-time mode.
//!
//! Wire encoding: a request is a JSON object `{"type": "<VariantName>",
//! ...fields}` POSTed to `/api` with a bearer token; a response is
//! `{"ok": true, "type": "<VariantName>", "body": ...}` (or `{"ok":
//! false, "error": "..."}` with a 4xx/5xx status). The per-variant wire
//! shapes are documented on [`ApiRequest`] / [`ApiResponse`]; the codecs
//! live in [`super::codec`] (JSON plus a negotiated binary frame
//! encoding) and the JSON row payloads reuse the `to_json`/`from_json`
//! codecs on [`super::models`] types.

use super::models::*;

/// Job creation payload (one fine-grained task).
#[derive(Debug, Clone)]
pub struct JobCreate {
    /// Site the job executes at (its shard owns the job row).
    pub site_id: SiteId,
    /// Registered App name at the site (must exist — the service rejects
    /// arbitrary command injection, paper §3.1 security model).
    pub app: String,
    /// Workload class consumed by the execution backend
    /// (e.g. "md_small", "md_large", "xpcs").
    pub workload: String,
    /// Node footprint of one run.
    pub num_nodes: u32,
    /// App parameter bindings, `(name, value)`.
    pub params: Vec<(String, String)>,
    /// Free-form labels for filtering, `(key, value)`.
    pub tags: Vec<(String, String)>,
    /// Stage-in requirements: (remote endpoint, bytes).
    pub transfers_in: Vec<(String, u64)>,
    /// Stage-out requirements: (remote endpoint, bytes).
    pub transfers_out: Vec<(String, u64)>,
    /// DAG dependencies: the job stays `AWAITING_PARENTS` until every
    /// parent reaches `JOB_FINISHED` (and fails if any parent fails).
    pub parents: Vec<JobId>,
}

impl JobCreate {
    /// Convenience constructor for the common single-node case.
    pub fn simple(site_id: SiteId, app: &str, workload: &str) -> JobCreate {
        JobCreate {
            site_id,
            app: app.to_string(),
            workload: workload.to_string(),
            num_nodes: 1,
            params: vec![],
            tags: vec![],
            transfers_in: vec![],
            transfers_out: vec![],
            parents: vec![],
        }
    }
}

/// Filter for job list/count queries (the SDK's `Job.objects.filter(...)`).
#[derive(Debug, Clone, Default)]
pub struct JobFilter {
    /// Restrict to one site's shard (`None` = all sites).
    pub site: Option<SiteId>,
    /// Empty = any state.
    pub states: Vec<JobState>,
    /// All listed tags must match.
    pub tags: Vec<(String, String)>,
    /// 0 = unlimited.
    pub limit: usize,
}

/// One service interaction. Each variant documents its JSON wire shape —
/// the object POSTed to `/api` (the `"type"` discriminator is the variant
/// name) — and the [`ApiResponse`] variant it returns.
#[derive(Debug, Clone)]
pub enum ApiRequest {
    // --- identity / topology ---
    /// Create a user (admin only). Wire: `{"type":"CreateUser",
    /// "name":s}` → [`ApiResponse::UserId`].
    CreateUser {
        /// Display name; not unique (ids are identity).
        name: String,
    },
    /// Register an execution site owned by the caller. Wire:
    /// `{"type":"CreateSite","name":s,"hostname":s,"path":s}` →
    /// [`ApiResponse::SiteId`].
    CreateSite {
        /// Facility name (e.g. "theta"); matches a simulator facility.
        name: String,
        /// Login hostname of the site.
        hostname: String,
        /// Site directory path at the facility.
        path: String,
    },
    /// Index an ApplicationDefinition at a site. Wire:
    /// `{"type":"RegisterApp","site":n,"name":s,"command_template":s,
    /// "parameters":[s,...]}` → [`ApiResponse::AppId`].
    RegisterApp {
        /// Owning site.
        site: SiteId,
        /// App name, unique per site.
        name: String,
        /// Shell template expanded at the site (metadata only server-side).
        command_template: String,
        /// Names of the template's parameters.
        parameters: Vec<String>,
    },
    // --- jobs ---
    /// Create many jobs in one call. Wire: `{"type":"BulkCreateJobs",
    /// "jobs":[{...see [`JobCreate`] fields...},...]}` →
    /// [`ApiResponse::JobIds`] in input order.
    BulkCreateJobs {
        /// Creation payloads, applied in order.
        jobs: Vec<JobCreate>,
    },
    /// List jobs matching a filter. Wire: `{"type":"ListJobs","filter":
    /// {"site":n|null,"states":[s,...],"tags":[[k,v],...],"limit":n}}` →
    /// [`ApiResponse::Jobs`].
    ListJobs {
        /// Which jobs to return.
        filter: JobFilter,
    },
    /// Per-state job counts at a site (zero-count states omitted). Wire:
    /// `{"type":"CountByState","site":n}` → [`ApiResponse::Counts`].
    CountByState {
        /// Site to count at.
        site: SiteId,
    },
    /// One legality-checked job transition. Wire:
    /// `{"type":"UpdateJobState","job":n,"to":s,"data":s}` →
    /// [`ApiResponse::Unit`]; an illegal edge is a 400.
    UpdateJobState {
        /// Job to move.
        job: JobId,
        /// Target state (`JobState::name` string on the wire).
        to: JobState,
        /// Free-form annotation recorded on the event.
        data: String,
    },
    /// The same transition applied to many jobs; fails on the first
    /// rejection. Wire: `{"type":"BulkUpdateJobState","jobs":[n,...],
    /// "to":s,"data":s}` → [`ApiResponse::Unit`].
    BulkUpdateJobState {
        /// Jobs to move, in order.
        jobs: Vec<JobId>,
        /// Target state for every job.
        to: JobState,
        /// Annotation recorded on each event.
        data: String,
    },
    // --- sessions (launcher leases) ---
    /// Open a launcher lease at a site. Wire: `{"type":"CreateSession",
    /// "site":n,"batch_job":n|null}` → [`ApiResponse::SessionId`].
    CreateSession {
        /// Site the launcher runs at.
        site: SiteId,
        /// Pilot allocation backing this launcher, if any.
        batch_job: Option<BatchJobId>,
    },
    /// Atomically acquire runnable jobs for a session (implicit
    /// heartbeat). Wire: `{"type":"SessionAcquire","session":n,
    /// "max_nodes":n,"max_jobs":n}` → [`ApiResponse::Jobs`].
    SessionAcquire {
        /// The acquiring lease.
        session: SessionId,
        /// Node budget across the acquired jobs.
        max_nodes: u32,
        /// Cap on acquired jobs.
        max_jobs: usize,
    },
    /// Standalone lease refresh. Wire: `{"type":"SessionHeartbeat",
    /// "session":n}` → [`ApiResponse::Unit`]; 400 once the session ended.
    SessionHeartbeat {
        /// Lease to refresh.
        session: SessionId,
    },
    /// One-round-trip launcher sync: heartbeat the session, then apply the
    /// batched per-job transitions in order (a job may appear twice, e.g.
    /// RUN_DONE then POSTPROCESSED). Best-effort per update; the response
    /// is `JobIds` listing the jobs whose transition was rejected, so the
    /// launcher can re-fetch their state. Wire: `{"type":"SessionSync",
    /// "session":n,"updates":[[job,state,data],...]}` →
    /// [`ApiResponse::JobIds`] (the rejected jobs).
    SessionSync {
        /// Lease being refreshed.
        session: SessionId,
        /// Ordered `(job, to, data)` transitions.
        updates: Vec<(JobId, JobState, String)>,
    },
    /// Graceful lease end: releases acquired jobs, recovers running ones.
    /// Wire: `{"type":"SessionEnd","session":n}` → [`ApiResponse::Unit`].
    SessionEnd {
        /// Lease to end.
        session: SessionId,
    },
    // --- batch jobs (pilot allocations) ---
    /// Request a pilot allocation. Wire: `{"type":"CreateBatchJob",
    /// "site":n,"num_nodes":n,"wall_time_s":x,"mode":s,"queue":s,
    /// "project":s}` → [`ApiResponse::BatchJobId`].
    CreateBatchJob {
        /// Site the allocation is requested at.
        site: SiteId,
        /// Allocation width in nodes.
        num_nodes: u32,
        /// Requested wall time, seconds.
        wall_time_s: f64,
        /// Launcher packing mode inside the allocation.
        mode: JobMode,
        /// Local scheduler queue.
        queue: String,
        /// Local scheduler project/account.
        project: String,
    },
    /// List a site's batch jobs. Wire: `{"type":"ListBatchJobs","site":n,
    /// "active_only":b}` → [`ApiResponse::BatchJobs`].
    ListBatchJobs {
        /// Site whose allocations to list.
        site: SiteId,
        /// Restrict to Pending/Queued/Running allocations.
        active_only: bool,
    },
    /// Scheduler-module status sync for one allocation. Wire:
    /// `{"type":"UpdateBatchJob","id":n,"state":s,"local_id":n|null}` →
    /// [`ApiResponse::Unit`].
    UpdateBatchJob {
        /// Allocation to update.
        id: BatchJobId,
        /// Observed scheduler state.
        state: BatchJobState,
        /// Local scheduler id, once known.
        local_id: Option<u64>,
    },
    // --- transfer items ---
    /// Pending transfer items whose owning job is in the actionable stage
    /// (stage-in while READY, stage-out once POSTPROCESSED). Wire:
    /// `{"type":"PendingTransferItems","site":n,"direction":s,
    /// "limit":n}` → [`ApiResponse::TransferItems`].
    PendingTransferItems {
        /// Site whose shard is queried.
        site: SiteId,
        /// `"in"` (stage-in) or `"out"` (stage-out) on the wire.
        direction: Direction,
        /// Cap on returned items; 0 = unlimited.
        limit: usize,
    },
    /// Move a batch of items to one state (legacy single-state bulk
    /// update; the mixed-status path is [`ApiRequest::SyncTransferItems`]).
    /// Wire: `{"type":"UpdateTransferItems","ids":[n,...],"state":s,
    /// "task_id":n|null}` → [`ApiResponse::Unit`].
    UpdateTransferItems {
        /// Items to update.
        ids: Vec<TransferItemId>,
        /// Target state for all of them.
        state: TransferState,
        /// Transfer-task handle to record, if any.
        task_id: Option<XferTaskId>,
    },
    /// One-round-trip transfer-module sync: mixed per-item status updates
    /// (Done and Error batches from several transfer tasks in one call).
    /// Wire: `{"type":"SyncTransferItems","updates":[[id,state,
    /// task|null],...]}` → [`ApiResponse::Unit`].
    SyncTransferItems {
        /// Ordered `(item, state, task)` updates.
        updates: Vec<(TransferItemId, TransferState, Option<XferTaskId>)>,
    },
    // --- monitoring ---
    /// Aggregate backlog snapshot for one site. Wire:
    /// `{"type":"SiteBacklog","site":n}` → [`ApiResponse::Backlog`].
    SiteBacklog {
        /// Site to aggregate.
        site: SiteId,
    },
    /// One page of the merged event log from global sequence `since` on.
    /// Wire: `{"type":"ListEvents","since":n}` → [`ApiResponse::Events`]
    /// (see the [`EventsPage`] dual wire shape).
    ListEvents {
        /// First global sequence number wanted (cursor).
        since: usize,
    },
    /// Long-poll subscription over the event log: returns immediately when
    /// events with `seq >= since` exist (or the cursor predates event-log
    /// retention — then `truncated_before` is set), otherwise hangs in the
    /// gateway until a matching event is committed or `timeout_ms`
    /// elapses (an empty page; the cursor stays valid and the client
    /// re-arms). The server clamps `timeout_ms` to its subscribe cap so a
    /// watch always answers within the transport's read timeout. Wire:
    /// `{"type":"WatchEvents","site":n|null,"since":n,"timeout_ms":n}` →
    /// [`ApiResponse::Events`]. Back-compat: an old server answers
    /// `"unknown request type"` (a 400) — subscribers fall back to
    /// [`ApiRequest::ListEvents`] polling.
    WatchEvents {
        /// Restrict to one site's shard — the caller must own that site.
        /// `None` subscribes to every site's events and is admin-only
        /// (otherwise omitting the filter would bypass the per-site
        /// check). A site filter still pages on the *global* sequence
        /// number.
        site: Option<SiteId>,
        /// First global sequence number wanted (cursor).
        since: usize,
        /// Max server-side hang, milliseconds (0 = non-blocking check).
        timeout_ms: u64,
        /// Credit: max events the subscriber is ready to buffer in one
        /// page (0 = server default). The server truncates the page to
        /// `min(max_events, server cap)` oldest events; the cursor
        /// (`last.seq + 1`) stays valid, so a slow subscriber simply
        /// pages more often instead of forcing unbounded buffering.
        /// Wire: optional `"max_events"` field — absent means 0, so old
        /// clients keep working against new servers and vice versa.
        max_events: usize,
    },
}

impl ApiRequest {
    /// The wire `"type"` discriminator for this variant — the stable
    /// endpoint name. Doubles as the `endpoint` label value for the
    /// gateway's per-endpoint metrics
    /// ([`crate::util::metrics::api_observe`]); the metric registry's
    /// [`crate::util::metrics::ENDPOINTS`] list must contain every name
    /// returned here (pinned by a gateway test).
    pub fn name(&self) -> &'static str {
        match self {
            ApiRequest::CreateUser { .. } => "CreateUser",
            ApiRequest::CreateSite { .. } => "CreateSite",
            ApiRequest::RegisterApp { .. } => "RegisterApp",
            ApiRequest::BulkCreateJobs { .. } => "BulkCreateJobs",
            ApiRequest::ListJobs { .. } => "ListJobs",
            ApiRequest::CountByState { .. } => "CountByState",
            ApiRequest::UpdateJobState { .. } => "UpdateJobState",
            ApiRequest::BulkUpdateJobState { .. } => "BulkUpdateJobState",
            ApiRequest::CreateSession { .. } => "CreateSession",
            ApiRequest::SessionAcquire { .. } => "SessionAcquire",
            ApiRequest::SessionHeartbeat { .. } => "SessionHeartbeat",
            ApiRequest::SessionSync { .. } => "SessionSync",
            ApiRequest::SessionEnd { .. } => "SessionEnd",
            ApiRequest::CreateBatchJob { .. } => "CreateBatchJob",
            ApiRequest::ListBatchJobs { .. } => "ListBatchJobs",
            ApiRequest::UpdateBatchJob { .. } => "UpdateBatchJob",
            ApiRequest::PendingTransferItems { .. } => "PendingTransferItems",
            ApiRequest::UpdateTransferItems { .. } => "UpdateTransferItems",
            ApiRequest::SyncTransferItems { .. } => "SyncTransferItems",
            ApiRequest::SiteBacklog { .. } => "SiteBacklog",
            ApiRequest::ListEvents { .. } => "ListEvents",
            ApiRequest::WatchEvents { .. } => "WatchEvents",
        }
    }
}

/// Aggregate backlog snapshot used by the Elastic Queue module and the
/// shortest-backlog client strategy (paper §3.2, §4.6).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Backlog {
    /// Jobs not yet finished/failed and not yet running.
    pub backlog_jobs: usize,
    /// Node footprint of immediately runnable jobs (PREPROCESSED / RESTART_READY).
    pub runnable_nodes: u32,
    /// Node footprint of jobs whose data is still in flight (READY / STAGED_IN).
    pub inflight_nodes: u32,
    /// Nodes in queued-or-running BatchJobs at the site.
    pub batch_nodes: u32,
}

/// One page of the merged event log ([`ApiRequest::ListEvents`] /
/// [`ApiRequest::WatchEvents`]).
///
/// `truncated_before = Some(n)` means event-log retention has dropped
/// events below global seq `n` that the request asked for — the page is
/// complete from `n` on. Pagers treat it as an explicit "history starts
/// at N" signal instead of silently missing events.
///
/// Wire shape is dual for back-compat: a bare JSON array of events (the
/// pre-retention shape, emitted whenever there is no truncation to
/// report, so old clients keep working) or `{"truncated_before":n,
/// "events":[...]}` once retention actually dropped requested history.
/// Decoders accept both.
#[derive(Debug, Clone, Default)]
pub struct EventsPage {
    /// Retention marker: history below this global seq is gone.
    pub truncated_before: Option<u64>,
    /// Events with `seq >= since`, ordered by global sequence.
    pub events: Vec<Event>,
}

/// A successful service reply. On the wire each variant is
/// `{"ok":true,"type":"<VariantName>","body":...}`; the per-variant
/// `body` shapes are noted below (row payloads use the
/// [`super::models`] `to_json` codecs).
#[derive(Debug, Clone)]
pub enum ApiResponse {
    /// No payload (`body` is `null`).
    Unit,
    /// A created user id (`body` is a number).
    UserId(UserId),
    /// A created site id (`body` is a number).
    SiteId(SiteId),
    /// A registered app id (`body` is a number).
    AppId(AppId),
    /// Job ids (`body` is an array of numbers). For
    /// [`ApiRequest::BulkCreateJobs`] these are the created jobs in input
    /// order; for [`ApiRequest::SessionSync`] the rejected updates.
    JobIds(Vec<JobId>),
    /// Full job rows (`body` is an array of job objects).
    Jobs(Vec<Job>),
    /// Per-state counts (`body` is an array of `[state, count]` pairs).
    Counts(Vec<(JobState, usize)>),
    /// A created session id (`body` is a number).
    SessionId(SessionId),
    /// A created batch-job id (`body` is a number).
    BatchJobId(BatchJobId),
    /// Batch-job rows (`body` is an array of batch-job objects).
    BatchJobs(Vec<BatchJob>),
    /// Transfer-item rows (`body` is an array of item objects).
    TransferItems(Vec<TransferItem>),
    /// Backlog aggregates (`body` is an object with the four counters).
    Backlog(Backlog),
    /// An event page — see the [`EventsPage`] dual wire shape.
    Events(EventsPage),
}

macro_rules! expect_variant {
    ($fn_name:ident, $variant:ident, $ty:ty) => {
        /// Unwrap helper; panics on wrong variant (programming error).
        pub fn $fn_name(self) -> $ty {
            match self {
                ApiResponse::$variant(x) => x,
                other => panic!(concat!("expected ", stringify!($variant), ", got {:?}"), other),
            }
        }
    };
}

impl ApiResponse {
    expect_variant!(site_id, SiteId, SiteId);
    expect_variant!(app_id, AppId, AppId);
    expect_variant!(user_id, UserId, UserId);
    expect_variant!(job_ids, JobIds, Vec<JobId>);
    expect_variant!(jobs, Jobs, Vec<Job>);
    expect_variant!(counts, Counts, Vec<(JobState, usize)>);
    expect_variant!(session_id, SessionId, SessionId);
    expect_variant!(batch_job_id, BatchJobId, BatchJobId);
    expect_variant!(batch_jobs, BatchJobs, Vec<BatchJob>);
    expect_variant!(transfer_items, TransferItems, Vec<TransferItem>);
    expect_variant!(backlog, Backlog, Backlog);
    expect_variant!(events_page, Events, EventsPage);

    /// The event page's events alone (most callers ignore the retention
    /// marker; use [`ApiResponse::events_page`] to see it).
    pub fn events(self) -> Vec<Event> {
        self.events_page().events
    }
}

/// A failed service interaction — over HTTP these map to statuses
/// (401 / 404 / 400 / 500) and back.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// Missing/invalid token, or the caller does not own the touched
    /// site (HTTP 401).
    Unauthorized,
    /// The named entity does not exist (HTTP 404).
    NotFound(String),
    /// A job transition not permitted by the state machine (HTTP 400).
    IllegalTransition {
        /// Job whose transition was rejected.
        job: JobId,
        /// Its current state.
        from: JobState,
        /// The rejected target state.
        to: JobState,
    },
    /// Malformed or semantically invalid request (HTTP 400).
    BadRequest(String),
    /// Client-side transport failure (connect/send/frame); the request
    /// may or may not have reached the service.
    Transport(String),
    /// Server-side failure (e.g. a poisoned durable store): the request
    /// may not have been made durable. Served as a framed 500.
    Internal(String),
    /// The service refused the request under load (HTTP 429 from the
    /// per-principal rate limiter, or a framed 503 from transport load
    /// shedding). The request was NOT processed; retry after
    /// `retry_after_s` seconds (plus jitter). Never a lease-loss or
    /// state-machine signal — callers back off and repeat the same
    /// request.
    Backpressure {
        /// Server's `Retry-After` hint, seconds (≥ 1).
        retry_after_s: u64,
    },
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::Unauthorized => write!(f, "unauthorized"),
            ApiError::NotFound(s) => write!(f, "not found: {s}"),
            ApiError::IllegalTransition { job, from, to } => {
                write!(f, "illegal transition {from} -> {to} for job {job}")
            }
            ApiError::BadRequest(s) => write!(f, "bad request: {s}"),
            ApiError::Transport(s) => write!(f, "transport: {s}"),
            ApiError::Internal(s) => write!(f, "internal: {s}"),
            ApiError::Backpressure { retry_after_s } => {
                write!(f, "backpressure: retry after {retry_after_s}s")
            }
        }
    }
}

impl std::error::Error for ApiError {}

/// A connection to the Balsam service. Implemented by the in-process
/// simulator transport and by the HTTP client transport; all site modules
/// and clients are written against this trait.
pub trait ApiConn {
    /// Issue one authenticated request and wait for its response. A
    /// blocking variant ([`ApiRequest::WatchEvents`]) may hang up to its
    /// `timeout_ms` before answering.
    fn api(&mut self, token: &str, req: ApiRequest) -> Result<ApiResponse, ApiError>;
}
