//! Typed REST-ish API: the unifying data model and service interactions
//! "upon which all Balsam components and user workflows are authored"
//! (paper §2). Every site module and client speaks this API — in-process
//! in simulated mode, JSON-over-HTTP through [`super::http_gw`] in
//! real-time mode.

use super::models::*;

/// Job creation payload (one fine-grained task).
#[derive(Debug, Clone)]
pub struct JobCreate {
    pub site_id: SiteId,
    /// Registered App name at the site (must exist — the service rejects
    /// arbitrary command injection, paper §3.1 security model).
    pub app: String,
    /// Workload class consumed by the execution backend
    /// (e.g. "md_small", "md_large", "xpcs").
    pub workload: String,
    pub num_nodes: u32,
    pub params: Vec<(String, String)>,
    pub tags: Vec<(String, String)>,
    /// Stage-in requirements: (remote endpoint, bytes).
    pub transfers_in: Vec<(String, u64)>,
    /// Stage-out requirements: (remote endpoint, bytes).
    pub transfers_out: Vec<(String, u64)>,
    pub parents: Vec<JobId>,
}

impl JobCreate {
    /// Convenience constructor for the common single-node case.
    pub fn simple(site_id: SiteId, app: &str, workload: &str) -> JobCreate {
        JobCreate {
            site_id,
            app: app.to_string(),
            workload: workload.to_string(),
            num_nodes: 1,
            params: vec![],
            tags: vec![],
            transfers_in: vec![],
            transfers_out: vec![],
            parents: vec![],
        }
    }
}

/// Filter for job list/count queries (the SDK's `Job.objects.filter(...)`).
#[derive(Debug, Clone, Default)]
pub struct JobFilter {
    pub site: Option<SiteId>,
    /// Empty = any state.
    pub states: Vec<JobState>,
    /// All listed tags must match.
    pub tags: Vec<(String, String)>,
    /// 0 = unlimited.
    pub limit: usize,
}

#[derive(Debug, Clone)]
pub enum ApiRequest {
    // --- identity / topology ---
    CreateUser { name: String },
    CreateSite { name: String, hostname: String, path: String },
    RegisterApp { site: SiteId, name: String, command_template: String, parameters: Vec<String> },
    // --- jobs ---
    BulkCreateJobs { jobs: Vec<JobCreate> },
    ListJobs { filter: JobFilter },
    CountByState { site: SiteId },
    UpdateJobState { job: JobId, to: JobState, data: String },
    BulkUpdateJobState { jobs: Vec<JobId>, to: JobState, data: String },
    // --- sessions (launcher leases) ---
    CreateSession { site: SiteId, batch_job: Option<BatchJobId> },
    SessionAcquire { session: SessionId, max_nodes: u32, max_jobs: usize },
    SessionHeartbeat { session: SessionId },
    /// One-round-trip launcher sync: heartbeat the session, then apply the
    /// batched per-job transitions in order (a job may appear twice, e.g.
    /// RUN_DONE then POSTPROCESSED). Best-effort per update; the response
    /// is `JobIds` listing the jobs whose transition was rejected, so the
    /// launcher can re-fetch their state.
    SessionSync { session: SessionId, updates: Vec<(JobId, JobState, String)> },
    SessionEnd { session: SessionId },
    // --- batch jobs (pilot allocations) ---
    CreateBatchJob {
        site: SiteId,
        num_nodes: u32,
        wall_time_s: f64,
        mode: JobMode,
        queue: String,
        project: String,
    },
    ListBatchJobs { site: SiteId, active_only: bool },
    UpdateBatchJob { id: BatchJobId, state: BatchJobState, local_id: Option<u64> },
    // --- transfer items ---
    PendingTransferItems { site: SiteId, direction: Direction, limit: usize },
    UpdateTransferItems { ids: Vec<TransferItemId>, state: TransferState, task_id: Option<XferTaskId> },
    /// One-round-trip transfer-module sync: mixed per-item status updates
    /// (Done and Error batches from several transfer tasks in one call).
    SyncTransferItems { updates: Vec<(TransferItemId, TransferState, Option<XferTaskId>)> },
    // --- monitoring ---
    SiteBacklog { site: SiteId },
    ListEvents { since: usize },
}

/// Aggregate backlog snapshot used by the Elastic Queue module and the
/// shortest-backlog client strategy (paper §3.2, §4.6).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Backlog {
    /// Jobs not yet finished/failed and not yet running.
    pub backlog_jobs: usize,
    /// Node footprint of immediately runnable jobs (PREPROCESSED / RESTART_READY).
    pub runnable_nodes: u32,
    /// Node footprint of jobs whose data is still in flight (READY / STAGED_IN).
    pub inflight_nodes: u32,
    /// Nodes in queued-or-running BatchJobs at the site.
    pub batch_nodes: u32,
}

/// One page of the merged event log (`ListEvents { since }`).
///
/// `truncated_before = Some(n)` means event-log retention has dropped
/// events below global seq `n` that the request asked for — the page is
/// complete from `n` on. Pagers treat it as an explicit "history starts
/// at N" signal instead of silently missing events.
#[derive(Debug, Clone, Default)]
pub struct EventsPage {
    pub truncated_before: Option<u64>,
    pub events: Vec<Event>,
}

#[derive(Debug, Clone)]
pub enum ApiResponse {
    Unit,
    UserId(UserId),
    SiteId(SiteId),
    AppId(AppId),
    JobIds(Vec<JobId>),
    Jobs(Vec<Job>),
    Counts(Vec<(JobState, usize)>),
    SessionId(SessionId),
    BatchJobId(BatchJobId),
    BatchJobs(Vec<BatchJob>),
    TransferItems(Vec<TransferItem>),
    Backlog(Backlog),
    Events(EventsPage),
}

macro_rules! expect_variant {
    ($fn_name:ident, $variant:ident, $ty:ty) => {
        /// Unwrap helper; panics on wrong variant (programming error).
        pub fn $fn_name(self) -> $ty {
            match self {
                ApiResponse::$variant(x) => x,
                other => panic!(concat!("expected ", stringify!($variant), ", got {:?}"), other),
            }
        }
    };
}

impl ApiResponse {
    expect_variant!(site_id, SiteId, SiteId);
    expect_variant!(app_id, AppId, AppId);
    expect_variant!(user_id, UserId, UserId);
    expect_variant!(job_ids, JobIds, Vec<JobId>);
    expect_variant!(jobs, Jobs, Vec<Job>);
    expect_variant!(counts, Counts, Vec<(JobState, usize)>);
    expect_variant!(session_id, SessionId, SessionId);
    expect_variant!(batch_job_id, BatchJobId, BatchJobId);
    expect_variant!(batch_jobs, BatchJobs, Vec<BatchJob>);
    expect_variant!(transfer_items, TransferItems, Vec<TransferItem>);
    expect_variant!(backlog, Backlog, Backlog);
    expect_variant!(events_page, Events, EventsPage);

    /// The event page's events alone (most callers ignore the retention
    /// marker; use [`ApiResponse::events_page`] to see it).
    pub fn events(self) -> Vec<Event> {
        self.events_page().events
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    Unauthorized,
    NotFound(String),
    IllegalTransition { job: JobId, from: JobState, to: JobState },
    BadRequest(String),
    Transport(String),
    /// Server-side failure (e.g. a poisoned durable store): the request
    /// may not have been made durable. Served as a framed 500.
    Internal(String),
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::Unauthorized => write!(f, "unauthorized"),
            ApiError::NotFound(s) => write!(f, "not found: {s}"),
            ApiError::IllegalTransition { job, from, to } => {
                write!(f, "illegal transition {from} -> {to} for job {job}")
            }
            ApiError::BadRequest(s) => write!(f, "bad request: {s}"),
            ApiError::Transport(s) => write!(f, "transport: {s}"),
            ApiError::Internal(s) => write!(f, "internal: {s}"),
        }
    }
}

impl std::error::Error for ApiError {}

/// A connection to the Balsam service. Implemented by the in-process
/// simulator transport and by the HTTP client transport; all site modules
/// and clients are written against this trait.
pub trait ApiConn {
    fn api(&mut self, token: &str, req: ApiRequest) -> Result<ApiResponse, ApiError>;
}
