//! HTTP gateway: the Balsam REST API over real sockets.
//!
//! Serializes [`ApiRequest`]/[`ApiResponse`] as JSON and carries them over
//! the hand-rolled HTTP/1.1 transport ([`crate::util::httpd`]). This is
//! the real-time-mode transport: the end-to-end examples run the service
//! behind this gateway and every site module / client connects as an HTTP
//! client with a bearer token — exactly the paper's deployment shape.

use std::sync::Arc;
use std::time::Instant;

use crate::util::httpd::{
    self, HttpClient, HttpConfig, Request, Response, Server, SHED_RETRY_AFTER_S,
};
use crate::util::json::{kv_from_json, kv_to_json, u64s_from_json, Json};
use crate::util::metrics;

use super::api::*;
use super::auth::{Admission, RateLimiter};
use super::core::ServiceCore;
use super::models::*;

// ---------------------------------------------------------------------------
// JSON codecs — row and enum encodings live on the model types
// (`super::models`), shared with the WAL persistence layer; this module
// adds only the request/response envelope codecs plus lenient enum
// decoders for wire tolerance.
// ---------------------------------------------------------------------------

fn xfers_to_json(xs: &[(String, u64)]) -> Json {
    Json::Arr(xs.iter().map(|(r, s)| Json::arr([Json::str(r.clone()), Json::num(*s as f64)])).collect())
}

fn xfers_from_json(j: &Json) -> Vec<(String, u64)> {
    j.as_arr()
        .map(|a| {
            a.iter()
                .filter_map(|p| Some((p.idx(0)?.as_str()?.to_string(), p.idx(1)?.as_u64()?)))
                .collect()
        })
        .unwrap_or_default()
}

fn ids_to_json<T: Copy>(ids: &[T], f: impl Fn(T) -> u64) -> Json {
    Json::Arr(ids.iter().map(|&i| Json::num(f(i) as f64)).collect())
}

// Lenient wire decoders: unknown names fall back to a safe default
// rather than erroring (strict paths use `T::from_name` directly).
fn dir_from(s: &str) -> Direction {
    Direction::from_name(s).unwrap_or(Direction::In)
}

fn tstate_from(s: &str) -> TransferState {
    TransferState::from_name(s).unwrap_or(TransferState::Pending)
}

fn bstate_from(s: &str) -> BatchJobState {
    BatchJobState::from_name(s).unwrap_or(BatchJobState::Pending)
}

fn mode_from(s: &str) -> JobMode {
    JobMode::from_name(s).unwrap_or(JobMode::Mpi)
}

pub fn request_to_json(req: &ApiRequest) -> Json {
    use ApiRequest::*;
    match req {
        CreateUser { name } => Json::obj(vec![("type", Json::str("CreateUser")), ("name", Json::str(name.clone()))]),
        CreateSite { name, hostname, path } => Json::obj(vec![
            ("type", Json::str("CreateSite")),
            ("name", Json::str(name.clone())),
            ("hostname", Json::str(hostname.clone())),
            ("path", Json::str(path.clone())),
        ]),
        RegisterApp { site, name, command_template, parameters } => Json::obj(vec![
            ("type", Json::str("RegisterApp")),
            ("site", Json::num(site.0 as f64)),
            ("name", Json::str(name.clone())),
            ("command_template", Json::str(command_template.clone())),
            ("parameters", Json::Arr(parameters.iter().map(|p| Json::str(p.clone())).collect())),
        ]),
        BulkCreateJobs { jobs } => Json::obj(vec![
            ("type", Json::str("BulkCreateJobs")),
            (
                "jobs",
                Json::Arr(
                    jobs.iter()
                        .map(|jc| {
                            Json::obj(vec![
                                ("site_id", Json::num(jc.site_id.0 as f64)),
                                ("app", Json::str(jc.app.clone())),
                                ("workload", Json::str(jc.workload.clone())),
                                ("num_nodes", Json::num(jc.num_nodes as f64)),
                                ("params", kv_to_json(&jc.params)),
                                ("tags", kv_to_json(&jc.tags)),
                                ("transfers_in", xfers_to_json(&jc.transfers_in)),
                                ("transfers_out", xfers_to_json(&jc.transfers_out)),
                                ("parents", ids_to_json(&jc.parents, |p| p.0)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        ListJobs { filter } => Json::obj(vec![("type", Json::str("ListJobs")), ("filter", filter_to_json(filter))]),
        CountByState { site } => {
            Json::obj(vec![("type", Json::str("CountByState")), ("site", Json::num(site.0 as f64))])
        }
        UpdateJobState { job, to, data } => Json::obj(vec![
            ("type", Json::str("UpdateJobState")),
            ("job", Json::num(job.0 as f64)),
            ("to", Json::str(to.name())),
            ("data", Json::str(data.clone())),
        ]),
        BulkUpdateJobState { jobs, to, data } => Json::obj(vec![
            ("type", Json::str("BulkUpdateJobState")),
            ("jobs", ids_to_json(jobs, |j| j.0)),
            ("to", Json::str(to.name())),
            ("data", Json::str(data.clone())),
        ]),
        CreateSession { site, batch_job } => Json::obj(vec![
            ("type", Json::str("CreateSession")),
            ("site", Json::num(site.0 as f64)),
            ("batch_job", batch_job.map(|b| Json::num(b.0 as f64)).unwrap_or(Json::Null)),
        ]),
        SessionAcquire { session, max_nodes, max_jobs } => Json::obj(vec![
            ("type", Json::str("SessionAcquire")),
            ("session", Json::num(session.0 as f64)),
            ("max_nodes", Json::num(*max_nodes as f64)),
            ("max_jobs", Json::num(*max_jobs as f64)),
        ]),
        SessionHeartbeat { session } => Json::obj(vec![
            ("type", Json::str("SessionHeartbeat")),
            ("session", Json::num(session.0 as f64)),
        ]),
        SessionSync { session, updates } => Json::obj(vec![
            ("type", Json::str("SessionSync")),
            ("session", Json::num(session.0 as f64)),
            (
                "updates",
                Json::Arr(
                    updates
                        .iter()
                        .map(|(job, to, data)| {
                            Json::arr([
                                Json::num(job.0 as f64),
                                Json::str(to.name()),
                                Json::str(data.clone()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        SessionEnd { session } => {
            Json::obj(vec![("type", Json::str("SessionEnd")), ("session", Json::num(session.0 as f64))])
        }
        CreateBatchJob { site, num_nodes, wall_time_s, mode, queue, project } => Json::obj(vec![
            ("type", Json::str("CreateBatchJob")),
            ("site", Json::num(site.0 as f64)),
            ("num_nodes", Json::num(*num_nodes as f64)),
            ("wall_time_s", Json::num(*wall_time_s)),
            ("mode", Json::str(mode.name())),
            ("queue", Json::str(queue.clone())),
            ("project", Json::str(project.clone())),
        ]),
        ListBatchJobs { site, active_only } => Json::obj(vec![
            ("type", Json::str("ListBatchJobs")),
            ("site", Json::num(site.0 as f64)),
            ("active_only", Json::Bool(*active_only)),
        ]),
        UpdateBatchJob { id, state, local_id } => Json::obj(vec![
            ("type", Json::str("UpdateBatchJob")),
            ("id", Json::num(id.0 as f64)),
            ("state", Json::str(state.name())),
            ("local_id", local_id.map(|l| Json::num(l as f64)).unwrap_or(Json::Null)),
        ]),
        PendingTransferItems { site, direction, limit } => Json::obj(vec![
            ("type", Json::str("PendingTransferItems")),
            ("site", Json::num(site.0 as f64)),
            ("direction", Json::str(direction.name())),
            ("limit", Json::num(*limit as f64)),
        ]),
        UpdateTransferItems { ids, state, task_id } => Json::obj(vec![
            ("type", Json::str("UpdateTransferItems")),
            ("ids", ids_to_json(ids, |i| i.0)),
            ("state", Json::str(state.name())),
            ("task_id", task_id.map(|t| Json::num(t.0 as f64)).unwrap_or(Json::Null)),
        ]),
        SyncTransferItems { updates } => Json::obj(vec![
            ("type", Json::str("SyncTransferItems")),
            (
                "updates",
                Json::Arr(
                    updates
                        .iter()
                        .map(|(id, st, task)| {
                            Json::arr([
                                Json::num(id.0 as f64),
                                Json::str(st.name()),
                                task.map(|t| Json::num(t.0 as f64)).unwrap_or(Json::Null),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
        SiteBacklog { site } => {
            Json::obj(vec![("type", Json::str("SiteBacklog")), ("site", Json::num(site.0 as f64))])
        }
        ListEvents { since } => {
            Json::obj(vec![("type", Json::str("ListEvents")), ("since", Json::num(*since as f64))])
        }
        WatchEvents { site, since, timeout_ms, max_events } => Json::obj(vec![
            ("type", Json::str("WatchEvents")),
            ("site", site.map(|s| Json::num(s.0 as f64)).unwrap_or(Json::Null)),
            ("since", Json::num(*since as f64)),
            ("timeout_ms", Json::num(*timeout_ms as f64)),
            ("max_events", Json::num(*max_events as f64)),
        ]),
    }
}

fn filter_to_json(f: &JobFilter) -> Json {
    Json::obj(vec![
        ("site", f.site.map(|s| Json::num(s.0 as f64)).unwrap_or(Json::Null)),
        ("states", Json::Arr(f.states.iter().map(|s| Json::str(s.name())).collect())),
        ("tags", kv_to_json(&f.tags)),
        ("limit", Json::num(f.limit as f64)),
    ])
}

fn filter_from_json(j: &Json) -> JobFilter {
    JobFilter {
        site: j.get("site").and_then(Json::as_u64).map(SiteId),
        states: j
            .get("states")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|s| s.as_str().and_then(JobState::from_name)).collect())
            .unwrap_or_default(),
        tags: j.get("tags").map(kv_from_json).unwrap_or_default(),
        limit: j.get("limit").and_then(Json::as_u64).unwrap_or(0) as usize,
    }
}

pub fn request_from_json(j: &Json) -> Result<ApiRequest, String> {
    let ty = j.get("type").and_then(Json::as_str).ok_or("missing type")?;
    let site = || j.get("site").and_then(Json::as_u64).map(SiteId).ok_or("missing site");
    let get_str = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    Ok(match ty {
        "CreateUser" => ApiRequest::CreateUser { name: get_str("name") },
        "CreateSite" => ApiRequest::CreateSite {
            name: get_str("name"),
            hostname: get_str("hostname"),
            path: get_str("path"),
        },
        "RegisterApp" => ApiRequest::RegisterApp {
            site: site()?,
            name: get_str("name"),
            command_template: get_str("command_template"),
            parameters: j
                .get("parameters")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        },
        "BulkCreateJobs" => ApiRequest::BulkCreateJobs {
            jobs: j
                .get("jobs")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .map(|jc| JobCreate {
                            site_id: SiteId(jc.get("site_id").and_then(Json::as_u64).unwrap_or(0)),
                            app: jc.get("app").and_then(Json::as_str).unwrap_or("").into(),
                            workload: jc.get("workload").and_then(Json::as_str).unwrap_or("").into(),
                            num_nodes: jc.get("num_nodes").and_then(Json::as_u64).unwrap_or(1) as u32,
                            params: jc.get("params").map(kv_from_json).unwrap_or_default(),
                            tags: jc.get("tags").map(kv_from_json).unwrap_or_default(),
                            transfers_in: jc.get("transfers_in").map(xfers_from_json).unwrap_or_default(),
                            transfers_out: jc.get("transfers_out").map(xfers_from_json).unwrap_or_default(),
                            parents: jc
                                .get("parents")
                                .map(u64s_from_json)
                                .unwrap_or_default()
                                .into_iter()
                                .map(JobId)
                                .collect(),
                        })
                        .collect()
                })
                .unwrap_or_default(),
        },
        "ListJobs" => ApiRequest::ListJobs {
            filter: j.get("filter").map(filter_from_json).unwrap_or_default(),
        },
        "CountByState" => ApiRequest::CountByState { site: site()? },
        "UpdateJobState" => ApiRequest::UpdateJobState {
            job: JobId(j.get("job").and_then(Json::as_u64).ok_or("missing job")?),
            to: JobState::from_name(&get_str("to")).ok_or("bad state")?,
            data: get_str("data"),
        },
        "BulkUpdateJobState" => ApiRequest::BulkUpdateJobState {
            jobs: j.get("jobs").map(u64s_from_json).unwrap_or_default().into_iter().map(JobId).collect(),
            to: JobState::from_name(&get_str("to")).ok_or("bad state")?,
            data: get_str("data"),
        },
        "CreateSession" => ApiRequest::CreateSession {
            site: site()?,
            batch_job: j.get("batch_job").and_then(Json::as_u64).map(BatchJobId),
        },
        "SessionAcquire" => ApiRequest::SessionAcquire {
            session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
            max_nodes: j.get("max_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            max_jobs: j.get("max_jobs").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        "SessionHeartbeat" => ApiRequest::SessionHeartbeat {
            session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
        },
        "SessionSync" => {
            // Strict decode: a malformed tuple is a request error, not a
            // silent drop — the endpoint's contract is that every update
            // is either applied or reported back in the failed list.
            let mut updates = Vec::new();
            if let Some(a) = j.get("updates").and_then(Json::as_arr) {
                for u in a {
                    let job = u
                        .idx(0)
                        .and_then(Json::as_u64)
                        .ok_or("SessionSync update: bad job id")?;
                    let to = u
                        .idx(1)
                        .and_then(Json::as_str)
                        .and_then(JobState::from_name)
                        .ok_or("SessionSync update: bad state")?;
                    let data = u.idx(2).and_then(Json::as_str).unwrap_or("").to_string();
                    updates.push((JobId(job), to, data));
                }
            }
            ApiRequest::SessionSync {
                session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
                updates,
            }
        }
        "SessionEnd" => ApiRequest::SessionEnd {
            session: SessionId(j.get("session").and_then(Json::as_u64).ok_or("missing session")?),
        },
        "CreateBatchJob" => ApiRequest::CreateBatchJob {
            site: site()?,
            num_nodes: j.get("num_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            wall_time_s: j.get("wall_time_s").and_then(Json::as_f64).unwrap_or(0.0),
            mode: mode_from(&get_str("mode")),
            queue: get_str("queue"),
            project: get_str("project"),
        },
        "ListBatchJobs" => ApiRequest::ListBatchJobs {
            site: site()?,
            active_only: j.get("active_only").and_then(Json::as_bool).unwrap_or(false),
        },
        "UpdateBatchJob" => ApiRequest::UpdateBatchJob {
            id: BatchJobId(j.get("id").and_then(Json::as_u64).ok_or("missing id")?),
            state: bstate_from(&get_str("state")),
            local_id: j.get("local_id").and_then(Json::as_u64),
        },
        "PendingTransferItems" => ApiRequest::PendingTransferItems {
            site: site()?,
            direction: dir_from(&get_str("direction")),
            limit: j.get("limit").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        "UpdateTransferItems" => ApiRequest::UpdateTransferItems {
            ids: j.get("ids").map(u64s_from_json).unwrap_or_default().into_iter().map(TransferItemId).collect(),
            state: tstate_from(&get_str("state")),
            task_id: j.get("task_id").and_then(Json::as_u64).map(XferTaskId),
        },
        "SyncTransferItems" => {
            // Strict decode: an unknown state string must not default to
            // Pending (that would silently reset a live item).
            let mut updates = Vec::new();
            if let Some(a) = j.get("updates").and_then(Json::as_arr) {
                for u in a {
                    let id = u
                        .idx(0)
                        .and_then(Json::as_u64)
                        .ok_or("SyncTransferItems update: bad item id")?;
                    let state = u
                        .idx(1)
                        .and_then(Json::as_str)
                        .and_then(TransferState::from_name)
                        .ok_or("SyncTransferItems update: bad state")?;
                    let task = u.idx(2).and_then(Json::as_u64).map(XferTaskId);
                    updates.push((TransferItemId(id), state, task));
                }
            }
            ApiRequest::SyncTransferItems { updates }
        }
        "SiteBacklog" => ApiRequest::SiteBacklog { site: site()? },
        "ListEvents" => ApiRequest::ListEvents {
            since: j.get("since").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        // A missing/garbled timeout degrades to a non-blocking probe (0),
        // never to an accidental server-side hang. A missing `max_events`
        // (old client) is 0 = server default — wire back-compat for the
        // page-credit field.
        "WatchEvents" => ApiRequest::WatchEvents {
            site: j.get("site").and_then(Json::as_u64).map(SiteId),
            since: j.get("since").and_then(Json::as_u64).unwrap_or(0) as usize,
            timeout_ms: j.get("timeout_ms").and_then(Json::as_u64).unwrap_or(0),
            max_events: j.get("max_events").and_then(Json::as_u64).unwrap_or(0) as usize,
        },
        other => return Err(format!("unknown request type {other}")),
    })
}

pub fn response_to_json(resp: &ApiResponse) -> Json {
    use ApiResponse::*;
    let (ty, body) = match resp {
        Unit => ("Unit", Json::Null),
        UserId(x) => ("UserId", Json::num(x.0 as f64)),
        SiteId(x) => ("SiteId", Json::num(x.0 as f64)),
        AppId(x) => ("AppId", Json::num(x.0 as f64)),
        JobIds(x) => ("JobIds", ids_to_json(x, |i| i.0)),
        Jobs(x) => ("Jobs", Json::Arr(x.iter().map(Job::to_json).collect())),
        Counts(x) => (
            "Counts",
            Json::Arr(
                x.iter()
                    .map(|(s, n)| Json::arr([Json::str(s.name()), Json::num(*n as f64)]))
                    .collect(),
            ),
        ),
        SessionId(x) => ("SessionId", Json::num(x.0 as f64)),
        BatchJobId(x) => ("BatchJobId", Json::num(x.0 as f64)),
        BatchJobs(x) => ("BatchJobs", Json::Arr(x.iter().map(BatchJob::to_json).collect())),
        TransferItems(x) => ("TransferItems", Json::Arr(x.iter().map(TransferItem::to_json).collect())),
        Backlog(b) => (
            "Backlog",
            Json::obj(vec![
                ("backlog_jobs", Json::num(b.backlog_jobs as f64)),
                ("runnable_nodes", Json::num(b.runnable_nodes as f64)),
                ("inflight_nodes", Json::num(b.inflight_nodes as f64)),
                ("batch_nodes", Json::num(b.batch_nodes as f64)),
            ]),
        ),
        // The legacy wire shape (a bare array) is kept whenever there is
        // no truncation to report — the overwhelmingly common case — so
        // pre-retention clients keep working against a new service; the
        // object shape only appears once retention (a new-server opt-in)
        // actually dropped history.
        Events(p) => (
            "Events",
            match p.truncated_before {
                None => Json::Arr(p.events.iter().map(Event::to_json).collect()),
                Some(n) => Json::obj(vec![
                    ("truncated_before", Json::num(n as f64)),
                    ("events", Json::Arr(p.events.iter().map(Event::to_json).collect())),
                ]),
            },
        ),
    };
    Json::obj(vec![("ok", Json::Bool(true)), ("type", Json::str(ty)), ("body", body)])
}

pub fn response_from_json(j: &Json) -> Result<ApiResponse, ApiError> {
    if j.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = j.get("error").and_then(Json::as_str).unwrap_or("unknown").to_string();
        return Err(ApiError::Transport(msg));
    }
    let ty = j.get("type").and_then(Json::as_str).unwrap_or("");
    let b = j.get("body").unwrap_or(&Json::Null);
    let u = |b: &Json| b.as_u64().unwrap_or(0);
    Ok(match ty {
        "Unit" => ApiResponse::Unit,
        "UserId" => ApiResponse::UserId(UserId(u(b))),
        "SiteId" => ApiResponse::SiteId(SiteId(u(b))),
        "AppId" => ApiResponse::AppId(AppId(u(b))),
        "SessionId" => ApiResponse::SessionId(SessionId(u(b))),
        "BatchJobId" => ApiResponse::BatchJobId(BatchJobId(u(b))),
        "JobIds" => ApiResponse::JobIds(u64s_from_json(b).into_iter().map(JobId).collect()),
        "Jobs" => ApiResponse::Jobs(b.as_arr().unwrap_or(&[]).iter().map(Job::from_json).collect()),
        "Counts" => ApiResponse::Counts(
            b.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|p| {
                    Some((
                        JobState::from_name(p.idx(0)?.as_str()?)?,
                        p.idx(1)?.as_u64()? as usize,
                    ))
                })
                .collect(),
        ),
        "BatchJobs" => {
            ApiResponse::BatchJobs(b.as_arr().unwrap_or(&[]).iter().map(BatchJob::from_json).collect())
        }
        "TransferItems" => {
            ApiResponse::TransferItems(b.as_arr().unwrap_or(&[]).iter().map(TransferItem::from_json).collect())
        }
        "Backlog" => ApiResponse::Backlog(Backlog {
            backlog_jobs: b.get("backlog_jobs").and_then(Json::as_u64).unwrap_or(0) as usize,
            runnable_nodes: b.get("runnable_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            inflight_nodes: b.get("inflight_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
            batch_nodes: b.get("batch_nodes").and_then(Json::as_u64).unwrap_or(0) as u32,
        }),
        // Current shape: {"truncated_before": n|null, "events": [...]}.
        // A bare array is the pre-retention wire shape (an older peer):
        // accept it so version skew degrades to "no truncation info"
        // instead of a silently empty page.
        "Events" => ApiResponse::Events(EventsPage {
            truncated_before: b.get("truncated_before").and_then(Json::as_u64),
            events: b
                .get("events")
                .and_then(Json::as_arr)
                .or_else(|| b.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(Event::from_json)
                .collect(),
        }),
        other => return Err(ApiError::Transport(format!("unknown response type {other}"))),
    })
}

// ---------------------------------------------------------------------------
// Server + client
// ---------------------------------------------------------------------------

/// Run a [`ServiceCore`] behind the HTTP gateway with the default worker
/// pool and env-default transport config. Timestamps are wall-clock
/// seconds since server start, so event-log analysis works identically to
/// simulated mode.
///
/// The service is shared as a plain `Arc` — [`ServiceCore::handle`] takes
/// `&self`, so gateway workers dispatch concurrently and requests for
/// different sites never contend (per-site store shards).
pub fn serve(service: Arc<ServiceCore>, addr: &str) -> crate::Result<Server> {
    serve_with(service, addr, httpd::default_workers(), HttpConfig::default())
}

/// Gateway-level admission knobs, beyond the transport's [`HttpConfig`].
#[derive(Debug, Clone, Default)]
pub struct GatewayConfig {
    /// Per-principal token bucket: `Some((rps, burst))` installs the
    /// limiter (CLI: `--rate-limit=RPS,BURST`); `None` = unlimited.
    /// Throttled requests get 429 + `Retry-After` and count in
    /// `balsam_api_throttled_total`.
    pub rate_limit: Option<(u64, u64)>,
    /// Exempt the bootstrap admin principal from the rate limit (CLI:
    /// `--rate-limit-admin-exempt`) — operator tooling keeps working
    /// while tenants are throttled.
    pub admin_exempt: bool,
}

/// Which API requests the gateway sheds *first* under pressure: cheap
/// reads whose callers poll and can harmlessly retry. Writes (job state,
/// session sync, transfers) and `WatchEvents` (the push fabric; parked
/// watches are already slot-bounded) keep flowing until the transport's
/// hard limit sheds everything.
fn sheddable_read(req: &ApiRequest) -> bool {
    matches!(
        req,
        ApiRequest::ListEvents { .. }
            | ApiRequest::ListJobs { .. }
            | ApiRequest::CountByState { .. }
            | ApiRequest::SiteBacklog { .. }
            | ApiRequest::ListBatchJobs { .. }
            | ApiRequest::PendingTransferItems { .. }
    )
}

/// [`serve`] with an explicit worker-pool size and transport knobs:
/// keep-alive on/off, idle timeout, max requests per connection (see
/// [`HttpConfig`]). The `service_throughput` bench drives this with both
/// transports; `balsam service` threads its CLI flags through here.
pub fn serve_with(
    service: Arc<ServiceCore>,
    addr: &str,
    workers: usize,
    http: HttpConfig,
) -> crate::Result<Server> {
    serve_with_limits(service, addr, workers, http, GatewayConfig::default())
}

/// [`serve_with`] plus gateway admission control ([`GatewayConfig`]).
/// Overload is a handled condition here, not a failure mode:
///
/// 1. the transport sheds whole requests with framed 503s once its
///    accept queue passes [`HttpConfig::accept_queue_limit`];
/// 2. this gateway sheds *cheap reads* with 503s already at half that
///    depth (writes keep flowing — see [`sheddable_read`]);
/// 3. the per-principal token bucket turns one tenant's burst into that
///    tenant's 429s instead of everyone's latency.
///
/// `/healthz` and `/metrics` bypass all three (and the transport's
/// pre-body shed path), so a saturated gateway stays observable.
pub fn serve_with_limits(
    service: Arc<ServiceCore>,
    addr: &str,
    workers: usize,
    http: HttpConfig,
    gw: GatewayConfig,
) -> crate::Result<Server> {
    let t0 = Instant::now();
    let limiter = gw.rate_limit.map(|(rps, burst)| {
        let rl = RateLimiter::new(rps, burst);
        if gw.admin_exempt {
            rl.exempt(service.admin_user())
        } else {
            rl
        }
    });
    // Soft-shed threshold for cheap reads: half the transport's hard
    // limit (0 = soft shedding off, matching a disabled hard limit).
    let soft_shed_at = http.accept_queue_limit / 2;
    // On Server::stop, wake every armed WatchEvents long poll so its
    // worker finishes the in-flight response and can be joined — a socket
    // shutdown alone cannot unblock a handler parked on the store condvar.
    // Arming first returns this gateway's generation: a core that already
    // served (and stopped) once long-polls normally behind the fresh
    // gateway, and a *stale* gateway's stop hook (overlapping restart)
    // cannot close the channel out from under this one.
    let watch_generation = service.store.open_watchers();
    // Parked watches may pin at most workers - 1 threads: at least one
    // worker always remains for the mutations that wake the watchers
    // (with a single worker, watches degrade to non-blocking probes).
    service.set_subscribe_slots(workers.max(1) as u64 - 1);
    let stop_svc = service.clone();
    let mut server = Server::serve_cfg(addr, workers, http, move |req: Request| {
        let now = t0.elapsed().as_secs_f64();
        // Unauthenticated operational endpoints, routed before anything
        // else. Neither touches the watch-parking permits (`/metrics`
        // under keep-alive must never starve a WatchEvents subscriber —
        // pinned by the `metrics_health` suite) and neither parses a
        // body, so a scrape stays cheap even while the store is wedged.
        if req.method == "GET" && req.path == "/healthz" {
            return match service.store.persist_error() {
                // Poisoned durable store: in-memory state may be ahead of
                // the log and every mutation 500s — tell the orchestrator
                // to stop routing here.
                Some(e) => Response::error(503, &format!("persist poisoned: {e}")),
                None if service.store.watchers_closed() => Response::error(503, "stopping"),
                None => Response {
                    status: 200,
                    body: b"ok\n".to_vec(),
                    content_type: "text/plain",
                    retry_after: None,
                },
            };
        }
        if req.method == "GET" && req.path == "/metrics" {
            let mut body = metrics::render();
            service.store.render_metrics(&mut body);
            return Response {
                status: 200,
                body: body.into_bytes(),
                content_type: "text/plain; version=0.0.4",
                retry_after: None,
            };
        }
        let token = req
            .header("authorization")
            .and_then(|h| h.strip_prefix("Bearer "))
            .unwrap_or("")
            .to_string();
        if req.method != "POST" || req.path != "/api" {
            return Response::error(404, "POST /api only");
        }
        // Per-principal admission, before spending any parse work on the
        // body. An unknown/invalid token falls through — `handle` turns
        // it into the usual 401, and anonymous junk can't fill a bucket.
        if let Some(rl) = &limiter {
            if let Some(user) = service.authenticate(&token) {
                if let Admission::Throttle(retry_s) = rl.check(user) {
                    metrics::API_THROTTLED_TOTAL.inc();
                    return Response::too_many_requests(
                        &format!("rate limit exceeded for user {}", user.0),
                        retry_s,
                    );
                }
            }
        }
        let parsed = match Json::parse(&req.body_str()) {
            Ok(j) => j,
            Err(e) => return Response::error(400, &format!("bad json: {e}")),
        };
        let api_req = match request_from_json(&parsed) {
            Ok(r) => r,
            Err(e) => return Response::error(400, &e),
        };
        // Soft shed: past half the accept-queue limit, refuse cheap reads
        // with 503 + Retry-After so the remaining workers drain writes
        // (the transport's pre-body shed takes over at the full limit).
        if soft_shed_at > 0 && req.backlog >= soft_shed_at && sheddable_read(&api_req) {
            metrics::HTTP_SHED_TOTAL.inc();
            return Response::unavailable("overloaded: shedding reads", SHED_RETRY_AFTER_S);
        }
        // Per-endpoint observability: the label is the wire discriminator
        // (captured before `api_req` moves into the handler), the latency
        // is handler wall time — for WatchEvents that includes the
        // server-side park, so its histogram reads as hang duration.
        let endpoint = api_req.name();
        let t_req = metrics::clock();
        let result = service.handle(now, &token, api_req);
        metrics::api_observe(endpoint, result.is_err(), t_req);
        match result {
            Ok(resp) => Response::ok_json(response_to_json(&resp).to_string()),
            Err(e) => {
                let body = Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::str(e.to_string())),
                ]);
                let (status, retry_after) = match &e {
                    ApiError::Unauthorized => (401, None),
                    ApiError::NotFound(_) => (404, None),
                    // Poisoned durable store (or any server-side fault):
                    // a framed 500, so keep-alive clients stay usable.
                    ApiError::Internal(_) => (500, None),
                    // Totality: backpressure normally originates in this
                    // gateway (above), but any core-raised variant still
                    // reaches the wire as a well-formed 429.
                    ApiError::Backpressure { retry_after_s } => (429, Some(*retry_after_s)),
                    _ => (400, None),
                };
                Response {
                    status,
                    body: body.to_string().into_bytes(),
                    content_type: "application/json",
                    retry_after,
                }
            }
        }
    })?;
    server.add_stop_hook(move || stop_svc.store.close_watchers(watch_generation));
    Ok(server)
}

/// Client-side [`ApiConn`] over HTTP — what every remote Balsam component
/// uses in real-time mode. Holds one pooled persistent connection (see
/// [`HttpClient`]): a launcher session's whole lifetime of API calls rides
/// a single authenticated TCP stream, reconnecting transparently when the
/// server closes it (idle reap, max-requests budget, restart).
pub struct HttpConn {
    client: HttpClient,
}

impl HttpConn {
    pub fn new(addr: impl Into<String>) -> HttpConn {
        HttpConn { client: HttpClient::new(addr) }
    }

    /// Explicit transport config (tests force keep-alive on/off regardless
    /// of the `BALSAM_HTTP_KEEPALIVE` env default).
    pub fn with_config(addr: impl Into<String>, cfg: HttpConfig) -> HttpConn {
        HttpConn { client: HttpClient::with_config(addr, cfg) }
    }

    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    /// TCP connections dialed so far — reuse tests assert `1` after many
    /// API calls.
    pub fn connects(&self) -> u64 {
        self.client.connects()
    }
}

impl ApiConn for HttpConn {
    fn api(&mut self, token: &str, req: ApiRequest) -> Result<ApiResponse, ApiError> {
        let body = request_to_json(&req).to_string();
        let auth = format!("Bearer {token}");
        let (status, bytes, retry_after) = self
            .client
            .request_with_retry_after(
                "POST",
                "/api",
                &[("authorization", &auth), ("content-type", "application/json")],
                body.as_bytes(),
            )
            .map_err(|e| ApiError::Transport(e.to_string()))?;
        // Backpressure first: a framed 429 (rate limit) or 503 (load
        // shed) means "not processed, retry later" — it carries the
        // server's Retry-After and must never be mistaken for a lease
        // loss or bad request. The shed path may answer with a plain-text
        // body, so decode before any JSON parse.
        if status == 429 || status == 503 {
            return Err(ApiError::Backpressure { retry_after_s: retry_after.unwrap_or(1).max(1) });
        }
        let text = String::from_utf8_lossy(&bytes);
        let parsed = Json::parse(&text).map_err(|e| ApiError::Transport(e.to_string()))?;
        if status == 200 {
            response_from_json(&parsed)
        } else {
            let msg = parsed.get("error").and_then(Json::as_str).unwrap_or("unknown").to_string();
            Err(match status {
                401 => ApiError::Unauthorized,
                404 => ApiError::NotFound(msg),
                500 => ApiError::Internal(msg),
                _ => ApiError::BadRequest(msg),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let reqs = vec![
            ApiRequest::CreateSite { name: "theta".into(), hostname: "h".into(), path: "/p".into() },
            ApiRequest::SessionAcquire { session: SessionId(9), max_nodes: 32, max_jobs: 64 },
            ApiRequest::UpdateJobState { job: JobId(3), to: JobState::Running, data: "x".into() },
            ApiRequest::PendingTransferItems {
                site: SiteId(1),
                direction: Direction::Out,
                limit: 16,
            },
            ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate {
                    site_id: SiteId(2),
                    app: "EigenCorr".into(),
                    workload: "xpcs".into(),
                    num_nodes: 1,
                    params: vec![("h5".into(), "inp.h5".into())],
                    tags: vec![("experiment".into(), "XPCS".into())],
                    transfers_in: vec![("APS".into(), 878_000_000)],
                    transfers_out: vec![("APS".into(), 55_000_000)],
                    parents: vec![JobId(1)],
                }],
            },
            ApiRequest::SessionSync {
                session: SessionId(4),
                updates: vec![
                    (JobId(7), JobState::RunDone, String::new()),
                    (JobId(7), JobState::Postprocessed, "ok".into()),
                ],
            },
            ApiRequest::SyncTransferItems {
                updates: vec![
                    (TransferItemId(11), TransferState::Done, Some(XferTaskId(3))),
                    (TransferItemId(12), TransferState::Error, None),
                ],
            },
            ApiRequest::WatchEvents {
                site: Some(SiteId(3)),
                since: 17,
                timeout_ms: 1500,
                max_events: 64,
            },
            ApiRequest::WatchEvents { site: None, since: 0, timeout_ms: 0, max_events: 0 },
        ];
        for req in reqs {
            let j = request_to_json(&req);
            let back = request_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            // Compare via re-serialization (no PartialEq on ApiRequest).
            assert_eq!(j.to_string(), request_to_json(&back).to_string());
        }
    }

    #[test]
    fn response_json_roundtrip() {
        let resps = vec![
            ApiResponse::Unit,
            ApiResponse::JobIds(vec![JobId(1), JobId(2)]),
            ApiResponse::Backlog(Backlog {
                backlog_jobs: 5,
                runnable_nodes: 3,
                inflight_nodes: 2,
                batch_nodes: 16,
            }),
            ApiResponse::Counts(vec![(JobState::Ready, 4)]),
        ];
        for resp in resps {
            let j = response_to_json(&resp);
            let back = response_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(j.to_string(), response_to_json(&back).to_string());
        }
    }

    #[test]
    fn end_to_end_over_sockets() {
        let svc = Arc::new(ServiceCore::new(b"k"));
        let tok = svc.admin_token();
        let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
        let mut conn = HttpConn::new(server.addr.clone());

        let site = conn
            .api(&tok, ApiRequest::CreateSite { name: "cori".into(), hostname: "c".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        conn.api(&tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md {n}".into(),
            parameters: vec!["n".into()],
        })
        .unwrap();
        let ids = conn
            .api(&tok, ApiRequest::BulkCreateJobs { jobs: vec![JobCreate::simple(site, "MD", "md_small")] })
            .unwrap()
            .job_ids();
        assert_eq!(ids.len(), 1);
        let jobs = conn
            .api(&tok, ApiRequest::ListJobs { filter: JobFilter { site: Some(site), ..Default::default() } })
            .unwrap()
            .jobs();
        assert_eq!(jobs[0].state, JobState::Preprocessed);

        // Bad token comes back as Unauthorized over the wire.
        let err = conn.api("balsam.1.bad", ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        server.stop();
    }

    /// Every `ApiRequest` variant's wire name must have a slot in the
    /// metric registry's endpoint label list — an unlisted name would
    /// silently land in the terminal `"other"` slot and vanish from
    /// per-endpoint dashboards. Also pins that `name()` IS the wire
    /// `"type"` discriminator.
    #[test]
    fn every_endpoint_has_a_metric_slot() {
        let reqs = vec![
            ApiRequest::CreateUser { name: "u".into() },
            ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() },
            ApiRequest::RegisterApp {
                site: SiteId(1),
                name: "a".into(),
                command_template: "c".into(),
                parameters: vec![],
            },
            ApiRequest::BulkCreateJobs { jobs: vec![] },
            ApiRequest::ListJobs { filter: JobFilter::default() },
            ApiRequest::CountByState { site: SiteId(1) },
            ApiRequest::UpdateJobState { job: JobId(1), to: JobState::Running, data: "".into() },
            ApiRequest::BulkUpdateJobState { jobs: vec![], to: JobState::Running, data: "".into() },
            ApiRequest::CreateSession { site: SiteId(1), batch_job: None },
            ApiRequest::SessionAcquire { session: SessionId(1), max_nodes: 1, max_jobs: 1 },
            ApiRequest::SessionHeartbeat { session: SessionId(1) },
            ApiRequest::SessionSync { session: SessionId(1), updates: vec![] },
            ApiRequest::SessionEnd { session: SessionId(1) },
            ApiRequest::CreateBatchJob {
                site: SiteId(1),
                num_nodes: 1,
                wall_time_s: 1.0,
                mode: JobMode::Mpi,
                queue: "q".into(),
                project: "p".into(),
            },
            ApiRequest::ListBatchJobs { site: SiteId(1), active_only: false },
            ApiRequest::UpdateBatchJob {
                id: BatchJobId(1),
                state: BatchJobState::Pending,
                local_id: None,
            },
            ApiRequest::PendingTransferItems {
                site: SiteId(1),
                direction: Direction::In,
                limit: 0,
            },
            ApiRequest::UpdateTransferItems {
                ids: vec![],
                state: TransferState::Done,
                task_id: None,
            },
            ApiRequest::SyncTransferItems { updates: vec![] },
            ApiRequest::SiteBacklog { site: SiteId(1) },
            ApiRequest::ListEvents { since: 0 },
            ApiRequest::WatchEvents { site: None, since: 0, timeout_ms: 0, max_events: 0 },
        ];
        for req in &reqs {
            assert!(
                metrics::ENDPOINTS.contains(&req.name()),
                "no metric endpoint slot for {}",
                req.name()
            );
            let j = request_to_json(req);
            assert_eq!(j.get("type").and_then(Json::as_str), Some(req.name()));
        }
        // One slot per variant plus the terminal catch-all.
        assert_eq!(metrics::ENDPOINTS.len(), reqs.len() + 1);
        assert_eq!(metrics::ENDPOINTS.last(), Some(&"other"));
    }

    /// Per-principal rate limiting end to end: a tenant that exhausts its
    /// burst gets a framed 429 decoded as [`ApiError::Backpressure`] with
    /// the server's Retry-After, while the exempt admin and an
    /// independent polite tenant keep being served on the same gateway.
    #[test]
    fn rate_limiter_throttles_per_principal_with_retry_after() {
        let svc = Arc::new(ServiceCore::new(b"rl"));
        let admin_tok = svc.admin_token();
        let gw = GatewayConfig { rate_limit: Some((1, 3)), admin_exempt: true };
        let server =
            serve_with_limits(svc.clone(), "127.0.0.1:0", 2, HttpConfig::default(), gw).unwrap();
        let mut conn = HttpConn::new(server.addr.clone());

        let greedy = conn
            .api(&admin_tok, ApiRequest::CreateUser { name: "greedy".into() })
            .unwrap()
            .user_id();
        let polite = conn
            .api(&admin_tok, ApiRequest::CreateUser { name: "polite".into() })
            .unwrap()
            .user_id();
        let gtok = svc.token_for(greedy);
        let ptok = svc.token_for(polite);
        let site = conn
            .api(&gtok, ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();

        // Burn through the greedy tenant's bucket (one token already went
        // to CreateSite); the bucket refills at 1 rps so a tight loop must
        // hit Throttle within the remaining burst + 1 calls.
        let mut throttled = None;
        for _ in 0..10 {
            match conn.api(&gtok, ApiRequest::SiteBacklog { site }) {
                Ok(_) => {}
                Err(e) => {
                    throttled = Some(e);
                    break;
                }
            }
        }
        match throttled {
            Some(ApiError::Backpressure { retry_after_s }) => assert!(retry_after_s >= 1),
            other => panic!("expected Backpressure, got {other:?}"),
        }
        // Backpressure is per-principal: the polite tenant and the exempt
        // admin are still admitted on the very next calls.
        conn.api(&ptok, ApiRequest::ListEvents { since: 0 }).unwrap();
        conn.api(&admin_tok, ApiRequest::ListEvents { since: 0 }).unwrap();
        server.stop();
    }

    /// `/healthz` and `/metrics` must stay scrapeable while tenants are
    /// throttled — they carry no token and never consult the limiter.
    #[test]
    fn health_and_metrics_bypass_the_rate_limiter() {
        let svc = Arc::new(ServiceCore::new(b"byp"));
        let tok = svc.admin_token();
        // Admin NOT exempt and a bucket of one: the second API call is
        // throttled, proving the scrapes below didn't ride on quota.
        let gw = GatewayConfig { rate_limit: Some((1, 1)), admin_exempt: false };
        let server =
            serve_with_limits(svc.clone(), "127.0.0.1:0", 2, HttpConfig::default(), gw).unwrap();
        let mut conn = HttpConn::new(server.addr.clone());

        conn.api(&tok, ApiRequest::ListEvents { since: 0 }).unwrap();
        let err = conn.api(&tok, ApiRequest::ListEvents { since: 0 }).unwrap_err();
        assert!(matches!(err, ApiError::Backpressure { .. }), "{err:?}");

        let mut scrape = HttpClient::new(server.addr.clone());
        for path in ["/healthz", "/metrics"] {
            let (status, body) = scrape.request("GET", path, &[], b"").unwrap();
            assert_eq!(status, 200, "{path} must bypass the limiter");
            assert!(!body.is_empty());
        }
        server.stop();
    }

    /// Tentpole contract: a whole API session (including error responses)
    /// rides one persistent connection when keep-alive is on.
    #[test]
    fn api_session_reuses_one_connection_across_errors() {
        let svc = Arc::new(ServiceCore::new(b"ka"));
        let tok = svc.admin_token();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), ka);

        let site = conn
            .api(&tok, ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        // App-level errors (404 not-found, 401 bad token) must be framed
        // so the connection stays usable — the error-response framing fix.
        let err = conn.api(&tok, ApiRequest::SiteBacklog { site: SiteId(site.0 + 999) }).unwrap_err();
        assert!(matches!(err, ApiError::NotFound(_)), "{err:?}");
        let err = conn.api("balsam.1.bad", ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        // And the same connection keeps serving successful calls.
        for _ in 0..10 {
            conn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
        }
        assert_eq!(conn.connects(), 1, "session must hold one persistent connection");
        server.stop();
    }
}
