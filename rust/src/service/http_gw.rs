//! HTTP gateway: the Balsam REST API over real sockets.
//!
//! Carries [`ApiRequest`]/[`ApiResponse`] envelopes over the hand-rolled
//! HTTP/1.1 transport ([`crate::util::httpd`]), in whichever encoding the
//! peer negotiated ([`super::codec`]): JSON by default, binary frames for
//! clients that opt in via `Content-Type`/`Accept`. This is the
//! real-time-mode transport: the end-to-end examples run the service
//! behind this gateway and every site module / client connects as an HTTP
//! client with a bearer token — exactly the paper's deployment shape.

use std::sync::Arc;
use std::time::Instant;

use crate::util::httpd::{
    self, HttpClient, HttpConfig, Request, Response, Server, SHED_RETRY_AFTER_S,
};
use crate::util::metrics;

use super::api::*;
use super::auth::{Admission, RateLimiter};
use super::codec::{Wire, WireCodec, CT_FRAME};
use super::core::ServiceCore;
use super::models::*;

// ---------------------------------------------------------------------------
// Envelope codecs — extracted to `super::codec` (the JSON envelope plus
// the negotiated binary frame protocol). Re-exported here for the
// existing callers (benches, loadgen, examples) that reach the JSON
// codec functions through the gateway module.
// ---------------------------------------------------------------------------

pub use super::codec::json::{
    request_from_json, request_to_json, response_from_json, response_to_json,
};

// ---------------------------------------------------------------------------
// Server + client
// ---------------------------------------------------------------------------

/// Run a [`ServiceCore`] behind the HTTP gateway with the default worker
/// pool and env-default transport config. Timestamps are wall-clock
/// seconds since server start, so event-log analysis works identically to
/// simulated mode.
///
/// The service is shared as a plain `Arc` — [`ServiceCore::handle`] takes
/// `&self`, so gateway workers dispatch concurrently and requests for
/// different sites never contend (per-site store shards).
pub fn serve(service: Arc<ServiceCore>, addr: &str) -> crate::Result<Server> {
    serve_with(service, addr, httpd::default_workers(), HttpConfig::default())
}

/// Gateway-level admission knobs, beyond the transport's [`HttpConfig`].
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Per-principal token bucket: `Some((rps, burst))` installs the
    /// limiter (CLI: `--rate-limit=RPS,BURST`); `None` = unlimited.
    /// Throttled requests get 429 + `Retry-After` and count in
    /// `balsam_api_throttled_total`.
    pub rate_limit: Option<(u64, u64)>,
    /// Exempt the bootstrap admin principal from the rate limit (CLI:
    /// `--rate-limit-admin-exempt`) — operator tooling keeps working
    /// while tenants are throttled.
    pub admin_exempt: bool,
    /// Accept binary-frame requests (`application/x-balsam-frame`). On by
    /// default; `balsam service --wire json` turns it off, answering
    /// frame requests with 415 so binary clients fall back to JSON.
    pub binary: bool,
}

impl Default for GatewayConfig {
    fn default() -> GatewayConfig {
        GatewayConfig { rate_limit: None, admin_exempt: false, binary: true }
    }
}

/// An error response in the negotiated response encoding: the codec's
/// error envelope as the body, the codec's content type on the wire.
fn err_response(wire: Wire, status: u16, msg: &str, retry_after: Option<u64>) -> Response {
    let mut body = Vec::with_capacity(msg.len() + 32);
    wire.codec().encode_err(msg, &mut body);
    Response { status, body, content_type: wire.content_type(), retry_after }
}

/// Which API requests the gateway sheds *first* under pressure: cheap
/// reads whose callers poll and can harmlessly retry. Writes (job state,
/// session sync, transfers) and `WatchEvents` (the push fabric; parked
/// watches are already slot-bounded) keep flowing until the transport's
/// hard limit sheds everything.
fn sheddable_read(req: &ApiRequest) -> bool {
    matches!(
        req,
        ApiRequest::ListEvents { .. }
            | ApiRequest::ListJobs { .. }
            | ApiRequest::CountByState { .. }
            | ApiRequest::SiteBacklog { .. }
            | ApiRequest::ListBatchJobs { .. }
            | ApiRequest::PendingTransferItems { .. }
    )
}

/// [`serve`] with an explicit worker-pool size and transport knobs:
/// keep-alive on/off, idle timeout, max requests per connection (see
/// [`HttpConfig`]). The `service_throughput` bench drives this with both
/// transports; `balsam service` threads its CLI flags through here.
pub fn serve_with(
    service: Arc<ServiceCore>,
    addr: &str,
    workers: usize,
    http: HttpConfig,
) -> crate::Result<Server> {
    serve_with_limits(service, addr, workers, http, GatewayConfig::default())
}

/// [`serve_with`] plus gateway admission control ([`GatewayConfig`]).
/// Overload is a handled condition here, not a failure mode:
///
/// 1. the transport sheds whole requests with framed 503s once its
///    accept queue passes [`HttpConfig::accept_queue_limit`];
/// 2. this gateway sheds *cheap reads* with 503s already at half that
///    depth (writes keep flowing — see [`sheddable_read`]);
/// 3. the per-principal token bucket turns one tenant's burst into that
///    tenant's 429s instead of everyone's latency.
///
/// `/healthz` and `/metrics` bypass all three (and the transport's
/// pre-body shed path), so a saturated gateway stays observable.
pub fn serve_with_limits(
    service: Arc<ServiceCore>,
    addr: &str,
    workers: usize,
    http: HttpConfig,
    gw: GatewayConfig,
) -> crate::Result<Server> {
    let t0 = Instant::now();
    let limiter = gw.rate_limit.map(|(rps, burst)| {
        let rl = RateLimiter::new(rps, burst);
        if gw.admin_exempt {
            rl.exempt(service.admin_user())
        } else {
            rl
        }
    });
    // Soft-shed threshold for cheap reads: half the transport's hard
    // limit (0 = soft shedding off, matching a disabled hard limit).
    let soft_shed_at = http.accept_queue_limit / 2;
    let binary_ok = gw.binary;
    // On Server::stop, wake every armed WatchEvents long poll so its
    // worker finishes the in-flight response and can be joined — a socket
    // shutdown alone cannot unblock a handler parked on the store condvar.
    // Arming first returns this gateway's generation: a core that already
    // served (and stopped) once long-polls normally behind the fresh
    // gateway, and a *stale* gateway's stop hook (overlapping restart)
    // cannot close the channel out from under this one.
    let watch_generation = service.store.open_watchers();
    // Parked watches may pin at most workers - 1 threads: at least one
    // worker always remains for the mutations that wake the watchers
    // (with a single worker, watches degrade to non-blocking probes).
    service.set_subscribe_slots(workers.max(1) as u64 - 1);
    let stop_svc = service.clone();
    let mut server = Server::serve_cfg(addr, workers, http, move |req: Request| {
        let now = t0.elapsed().as_secs_f64();
        // Unauthenticated operational endpoints, routed before anything
        // else. Neither touches the watch-parking permits (`/metrics`
        // under keep-alive must never starve a WatchEvents subscriber —
        // pinned by the `metrics_health` suite) and neither parses a
        // body, so a scrape stays cheap even while the store is wedged.
        if req.method == "GET" && req.path == "/healthz" {
            return match service.store.persist_error() {
                // Poisoned durable store: in-memory state may be ahead of
                // the log and every mutation 500s — tell the orchestrator
                // to stop routing here.
                Some(e) => Response::error(503, &format!("persist poisoned: {e}")),
                None if service.store.watchers_closed() => Response::error(503, "stopping"),
                None => Response {
                    status: 200,
                    body: b"ok\n".to_vec(),
                    content_type: "text/plain",
                    retry_after: None,
                },
            };
        }
        if req.method == "GET" && req.path == "/metrics" {
            let mut body = metrics::render();
            service.store.render_metrics(&mut body);
            return Response {
                status: 200,
                body: body.into_bytes(),
                content_type: "text/plain; version=0.0.4",
                retry_after: None,
            };
        }
        let token = req
            .header("authorization")
            .and_then(|h| h.strip_prefix("Bearer "))
            .unwrap_or("")
            .to_string();
        if req.method != "POST" || req.path != "/api" {
            return Response::error(404, "POST /api only");
        }
        // Per-principal admission, before spending any parse work on the
        // body. An unknown/invalid token falls through — `handle` turns
        // it into the usual 401, and anonymous junk can't fill a bucket.
        if let Some(rl) = &limiter {
            if let Some(user) = service.authenticate(&token) {
                if let Admission::Throttle(retry_s) = rl.check(user) {
                    metrics::API_THROTTLED_TOTAL.inc();
                    return Response::too_many_requests(
                        &format!("rate limit exceeded for user {}", user.0),
                        retry_s,
                    );
                }
            }
        }
        // Wire negotiation (see `super::codec`): the request body's
        // encoding is whatever `Content-Type` declares (absent/unknown =
        // JSON, so pre-codec clients and the raw-socket fault-injection
        // tests are untouched); the response encoding follows `Accept`,
        // or mirrors the request when no `Accept` was sent.
        let req_wire = match req.header("content-type") {
            Some(ct) if ct.starts_with(CT_FRAME) => Wire::Binary,
            _ => Wire::Json,
        };
        let resp_wire = match req.header("accept") {
            Some(a) if a.contains(CT_FRAME) => Wire::Binary,
            Some(_) => Wire::Json,
            None => req_wire,
        };
        if req_wire == Wire::Binary && !binary_ok {
            // Plain-text 415 (no framed body): the binary client treats
            // any 415 as "speak JSON here from now on" without decoding.
            return Response::error(415, "binary frames disabled; send application/json");
        }
        metrics::API_REQUESTS_BY_CODEC_TOTAL[metrics::codec_index(req_wire.content_type())].inc();
        let api_req = match req_wire.codec().decode_request(&req.body) {
            Ok(r) => r,
            // The 400 body is encoded with the *response* codec — a
            // malformed frame still gets a well-formed framed error the
            // client can decode (and the error path stays allocation-
            // bounded: the message is a short static-ish string).
            Err(e) => return err_response(resp_wire, 400, &e, None),
        };
        // Soft shed: past half the accept-queue limit, refuse cheap reads
        // with 503 + Retry-After so the remaining workers drain writes
        // (the transport's pre-body shed takes over at the full limit).
        if soft_shed_at > 0 && req.backlog >= soft_shed_at && sheddable_read(&api_req) {
            metrics::HTTP_SHED_TOTAL.inc();
            return Response::unavailable("overloaded: shedding reads", SHED_RETRY_AFTER_S);
        }
        // Per-endpoint observability: the label is the wire discriminator
        // (captured before `api_req` moves into the handler), the latency
        // is handler wall time — for WatchEvents that includes the
        // server-side park, so its histogram reads as hang duration.
        let endpoint = api_req.name();
        let t_req = metrics::clock();
        let result = service.handle(now, &token, api_req);
        metrics::api_observe(endpoint, result.is_err(), t_req);
        match result {
            Ok(resp) => {
                let mut body = Vec::with_capacity(128);
                resp_wire.codec().encode_ok(&resp, &mut body);
                Response::ok_bytes(body, resp_wire.content_type())
            }
            Err(e) => {
                let (status, retry_after) = match &e {
                    ApiError::Unauthorized => (401, None),
                    ApiError::NotFound(_) => (404, None),
                    // Poisoned durable store (or any server-side fault):
                    // a framed 500, so keep-alive clients stay usable.
                    ApiError::Internal(_) => (500, None),
                    // Totality: backpressure normally originates in this
                    // gateway (above), but any core-raised variant still
                    // reaches the wire as a well-formed 429.
                    ApiError::Backpressure { retry_after_s } => (429, Some(*retry_after_s)),
                    _ => (400, None),
                };
                err_response(resp_wire, status, &e.to_string(), retry_after)
            }
        }
    })?;
    server.add_stop_hook(move || stop_svc.store.close_watchers(watch_generation));
    Ok(server)
}

/// Client-side [`ApiConn`] over HTTP — what every remote Balsam component
/// uses in real-time mode. Holds one pooled persistent connection (see
/// [`HttpClient`]): a launcher session's whole lifetime of API calls rides
/// a single authenticated TCP stream, reconnecting transparently when the
/// server closes it (idle reap, max-requests budget, restart).
pub struct HttpConn {
    client: HttpClient,
    /// The encoding this connection speaks. Starts from the constructor
    /// (or `BALSAM_WIRE`); a server 415 demotes Binary → Json permanently.
    wire: Wire,
    /// Reusable request-encode scratch — one buffer per connection, not
    /// one allocation per call.
    buf: Vec<u8>,
}

impl HttpConn {
    pub fn new(addr: impl Into<String>) -> HttpConn {
        HttpConn::with_config(addr, HttpConfig::default())
    }

    /// Explicit transport config (tests force keep-alive on/off regardless
    /// of the `BALSAM_HTTP_KEEPALIVE` env default). The wire codec follows
    /// the `BALSAM_WIRE` env default; see [`HttpConn::with_wire`].
    pub fn with_config(addr: impl Into<String>, cfg: HttpConfig) -> HttpConn {
        HttpConn::with_wire(addr, cfg, super::codec::wire_from_env())
    }

    /// Explicit transport config *and* wire codec — the site modules and
    /// loadgen thread their `--wire` knob through here.
    pub fn with_wire(addr: impl Into<String>, cfg: HttpConfig, wire: Wire) -> HttpConn {
        HttpConn { client: HttpClient::with_config(addr, cfg), wire, buf: Vec::new() }
    }

    pub fn addr(&self) -> &str {
        self.client.addr()
    }

    /// TCP connections dialed so far — reuse tests assert `1` after many
    /// API calls.
    pub fn connects(&self) -> u64 {
        self.client.connects()
    }

    /// The encoding this connection currently speaks (tests assert the
    /// 415 fallback actually demoted a binary connection to JSON).
    pub fn wire(&self) -> Wire {
        self.wire
    }
}

impl ApiConn for HttpConn {
    fn api(&mut self, token: &str, req: ApiRequest) -> Result<ApiResponse, ApiError> {
        let auth = format!("Bearer {token}");
        loop {
            self.buf.clear();
            self.wire.codec().encode_request(&req, &mut self.buf);
            let ct = self.wire.content_type();
            let (status, bytes, retry_after) = self
                .client
                .request_with_retry_after(
                    "POST",
                    "/api",
                    // `Accept` mirrors the request encoding: responses
                    // come back in the codec this connection speaks.
                    &[("authorization", &auth), ("content-type", ct), ("accept", ct)],
                    &self.buf,
                )
                .map_err(|e| ApiError::Transport(e.to_string()))?;
            // Backpressure first: a framed 429 (rate limit) or 503 (load
            // shed) means "not processed, retry later" — it carries the
            // server's Retry-After and must never be mistaken for a lease
            // loss or bad request. The shed path may answer with a
            // plain-text body, so decode before touching any codec.
            if status == 429 || status == 503 {
                return Err(ApiError::Backpressure {
                    retry_after_s: retry_after.unwrap_or(1).max(1),
                });
            }
            // A server with binary disabled answers frames with 415:
            // fall back to JSON for the rest of this connection's life
            // and re-issue the one in-flight request. `wire` is now Json,
            // so this branch cannot fire twice — the loop terminates.
            if status == 415 && self.wire == Wire::Binary {
                self.wire = Wire::Json;
                continue;
            }
            return if status == 200 {
                self.wire.codec().decode_ok(&bytes)
            } else {
                let msg = self.wire.codec().decode_err(&bytes);
                Err(match status {
                    401 => ApiError::Unauthorized,
                    404 => ApiError::NotFound(msg),
                    500 => ApiError::Internal(msg),
                    _ => ApiError::BadRequest(msg),
                })
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn request_json_roundtrip() {
        let reqs = vec![
            ApiRequest::CreateSite { name: "theta".into(), hostname: "h".into(), path: "/p".into() },
            ApiRequest::SessionAcquire { session: SessionId(9), max_nodes: 32, max_jobs: 64 },
            ApiRequest::UpdateJobState { job: JobId(3), to: JobState::Running, data: "x".into() },
            ApiRequest::PendingTransferItems {
                site: SiteId(1),
                direction: Direction::Out,
                limit: 16,
            },
            ApiRequest::BulkCreateJobs {
                jobs: vec![JobCreate {
                    site_id: SiteId(2),
                    app: "EigenCorr".into(),
                    workload: "xpcs".into(),
                    num_nodes: 1,
                    params: vec![("h5".into(), "inp.h5".into())],
                    tags: vec![("experiment".into(), "XPCS".into())],
                    transfers_in: vec![("APS".into(), 878_000_000)],
                    transfers_out: vec![("APS".into(), 55_000_000)],
                    parents: vec![JobId(1)],
                }],
            },
            ApiRequest::SessionSync {
                session: SessionId(4),
                updates: vec![
                    (JobId(7), JobState::RunDone, String::new()),
                    (JobId(7), JobState::Postprocessed, "ok".into()),
                ],
            },
            ApiRequest::SyncTransferItems {
                updates: vec![
                    (TransferItemId(11), TransferState::Done, Some(XferTaskId(3))),
                    (TransferItemId(12), TransferState::Error, None),
                ],
            },
            ApiRequest::WatchEvents {
                site: Some(SiteId(3)),
                since: 17,
                timeout_ms: 1500,
                max_events: 64,
            },
            ApiRequest::WatchEvents { site: None, since: 0, timeout_ms: 0, max_events: 0 },
        ];
        for req in reqs {
            let j = request_to_json(&req);
            let back = request_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            // Compare via re-serialization (no PartialEq on ApiRequest).
            assert_eq!(j.to_string(), request_to_json(&back).to_string());
        }
    }

    #[test]
    fn response_json_roundtrip() {
        let resps = vec![
            ApiResponse::Unit,
            ApiResponse::JobIds(vec![JobId(1), JobId(2)]),
            ApiResponse::Backlog(Backlog {
                backlog_jobs: 5,
                runnable_nodes: 3,
                inflight_nodes: 2,
                batch_nodes: 16,
            }),
            ApiResponse::Counts(vec![(JobState::Ready, 4)]),
        ];
        for resp in resps {
            let j = response_to_json(&resp);
            let back = response_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
            assert_eq!(j.to_string(), response_to_json(&back).to_string());
        }
    }

    #[test]
    fn end_to_end_over_sockets() {
        let svc = Arc::new(ServiceCore::new(b"k"));
        let tok = svc.admin_token();
        let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
        let mut conn = HttpConn::new(server.addr.clone());

        let site = conn
            .api(&tok, ApiRequest::CreateSite { name: "cori".into(), hostname: "c".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        conn.api(&tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md {n}".into(),
            parameters: vec!["n".into()],
        })
        .unwrap();
        let ids = conn
            .api(&tok, ApiRequest::BulkCreateJobs { jobs: vec![JobCreate::simple(site, "MD", "md_small")] })
            .unwrap()
            .job_ids();
        assert_eq!(ids.len(), 1);
        let jobs = conn
            .api(&tok, ApiRequest::ListJobs { filter: JobFilter { site: Some(site), ..Default::default() } })
            .unwrap()
            .jobs();
        assert_eq!(jobs[0].state, JobState::Preprocessed);

        // Bad token comes back as Unauthorized over the wire.
        let err = conn.api("balsam.1.bad", ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        server.stop();
    }

    /// Every `ApiRequest` variant's wire name must have a slot in the
    /// metric registry's endpoint label list — an unlisted name would
    /// silently land in the terminal `"other"` slot and vanish from
    /// per-endpoint dashboards. Also pins that `name()` IS the wire
    /// `"type"` discriminator.
    #[test]
    fn every_endpoint_has_a_metric_slot() {
        let reqs = vec![
            ApiRequest::CreateUser { name: "u".into() },
            ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() },
            ApiRequest::RegisterApp {
                site: SiteId(1),
                name: "a".into(),
                command_template: "c".into(),
                parameters: vec![],
            },
            ApiRequest::BulkCreateJobs { jobs: vec![] },
            ApiRequest::ListJobs { filter: JobFilter::default() },
            ApiRequest::CountByState { site: SiteId(1) },
            ApiRequest::UpdateJobState { job: JobId(1), to: JobState::Running, data: "".into() },
            ApiRequest::BulkUpdateJobState { jobs: vec![], to: JobState::Running, data: "".into() },
            ApiRequest::CreateSession { site: SiteId(1), batch_job: None },
            ApiRequest::SessionAcquire { session: SessionId(1), max_nodes: 1, max_jobs: 1 },
            ApiRequest::SessionHeartbeat { session: SessionId(1) },
            ApiRequest::SessionSync { session: SessionId(1), updates: vec![] },
            ApiRequest::SessionEnd { session: SessionId(1) },
            ApiRequest::CreateBatchJob {
                site: SiteId(1),
                num_nodes: 1,
                wall_time_s: 1.0,
                mode: JobMode::Mpi,
                queue: "q".into(),
                project: "p".into(),
            },
            ApiRequest::ListBatchJobs { site: SiteId(1), active_only: false },
            ApiRequest::UpdateBatchJob {
                id: BatchJobId(1),
                state: BatchJobState::Pending,
                local_id: None,
            },
            ApiRequest::PendingTransferItems {
                site: SiteId(1),
                direction: Direction::In,
                limit: 0,
            },
            ApiRequest::UpdateTransferItems {
                ids: vec![],
                state: TransferState::Done,
                task_id: None,
            },
            ApiRequest::SyncTransferItems { updates: vec![] },
            ApiRequest::SiteBacklog { site: SiteId(1) },
            ApiRequest::ListEvents { since: 0 },
            ApiRequest::WatchEvents { site: None, since: 0, timeout_ms: 0, max_events: 0 },
        ];
        for req in &reqs {
            assert!(
                metrics::ENDPOINTS.contains(&req.name()),
                "no metric endpoint slot for {}",
                req.name()
            );
            let j = request_to_json(req);
            assert_eq!(j.get("type").and_then(Json::as_str), Some(req.name()));
        }
        // One slot per variant plus the terminal catch-all.
        assert_eq!(metrics::ENDPOINTS.len(), reqs.len() + 1);
        assert_eq!(metrics::ENDPOINTS.last(), Some(&"other"));
    }

    /// Per-principal rate limiting end to end: a tenant that exhausts its
    /// burst gets a framed 429 decoded as [`ApiError::Backpressure`] with
    /// the server's Retry-After, while the exempt admin and an
    /// independent polite tenant keep being served on the same gateway.
    #[test]
    fn rate_limiter_throttles_per_principal_with_retry_after() {
        let svc = Arc::new(ServiceCore::new(b"rl"));
        let admin_tok = svc.admin_token();
        let gw = GatewayConfig { rate_limit: Some((1, 3)), admin_exempt: true, ..Default::default() };
        let server =
            serve_with_limits(svc.clone(), "127.0.0.1:0", 2, HttpConfig::default(), gw).unwrap();
        let mut conn = HttpConn::new(server.addr.clone());

        let greedy = conn
            .api(&admin_tok, ApiRequest::CreateUser { name: "greedy".into() })
            .unwrap()
            .user_id();
        let polite = conn
            .api(&admin_tok, ApiRequest::CreateUser { name: "polite".into() })
            .unwrap()
            .user_id();
        let gtok = svc.token_for(greedy);
        let ptok = svc.token_for(polite);
        let site = conn
            .api(&gtok, ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();

        // Burn through the greedy tenant's bucket (one token already went
        // to CreateSite); the bucket refills at 1 rps so a tight loop must
        // hit Throttle within the remaining burst + 1 calls.
        let mut throttled = None;
        for _ in 0..10 {
            match conn.api(&gtok, ApiRequest::SiteBacklog { site }) {
                Ok(_) => {}
                Err(e) => {
                    throttled = Some(e);
                    break;
                }
            }
        }
        match throttled {
            Some(ApiError::Backpressure { retry_after_s }) => assert!(retry_after_s >= 1),
            other => panic!("expected Backpressure, got {other:?}"),
        }
        // Backpressure is per-principal: the polite tenant and the exempt
        // admin are still admitted on the very next calls.
        conn.api(&ptok, ApiRequest::ListEvents { since: 0 }).unwrap();
        conn.api(&admin_tok, ApiRequest::ListEvents { since: 0 }).unwrap();
        server.stop();
    }

    /// `/healthz` and `/metrics` must stay scrapeable while tenants are
    /// throttled — they carry no token and never consult the limiter.
    #[test]
    fn health_and_metrics_bypass_the_rate_limiter() {
        let svc = Arc::new(ServiceCore::new(b"byp"));
        let tok = svc.admin_token();
        // Admin NOT exempt and a bucket of one: the second API call is
        // throttled, proving the scrapes below didn't ride on quota.
        let gw =
            GatewayConfig { rate_limit: Some((1, 1)), admin_exempt: false, ..Default::default() };
        let server =
            serve_with_limits(svc.clone(), "127.0.0.1:0", 2, HttpConfig::default(), gw).unwrap();
        let mut conn = HttpConn::new(server.addr.clone());

        conn.api(&tok, ApiRequest::ListEvents { since: 0 }).unwrap();
        let err = conn.api(&tok, ApiRequest::ListEvents { since: 0 }).unwrap_err();
        assert!(matches!(err, ApiError::Backpressure { .. }), "{err:?}");

        let mut scrape = HttpClient::new(server.addr.clone());
        for path in ["/healthz", "/metrics"] {
            let (status, body) = scrape.request("GET", path, &[], b"").unwrap();
            assert_eq!(status, 200, "{path} must bypass the limiter");
            assert!(!body.is_empty());
        }
        server.stop();
    }

    /// Tentpole contract: a whole API session (including error responses)
    /// rides one persistent connection when keep-alive is on.
    #[test]
    fn api_session_reuses_one_connection_across_errors() {
        let svc = Arc::new(ServiceCore::new(b"ka"));
        let tok = svc.admin_token();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_config(server.addr.clone(), ka);

        let site = conn
            .api(&tok, ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        // App-level errors (404 not-found, 401 bad token) must be framed
        // so the connection stays usable — the error-response framing fix.
        let err = conn.api(&tok, ApiRequest::SiteBacklog { site: SiteId(site.0 + 999) }).unwrap_err();
        assert!(matches!(err, ApiError::NotFound(_)), "{err:?}");
        let err = conn.api("balsam.1.bad", ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        // And the same connection keeps serving successful calls.
        for _ in 0..10 {
            conn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
        }
        assert_eq!(conn.connects(), 1, "session must hold one persistent connection");
        server.stop();
    }

    /// Binary frames end to end: a `--wire binary` client runs the same
    /// session shape as the JSON e2e test — including decoded app errors
    /// — on one persistent connection, against a default server.
    #[test]
    fn binary_end_to_end_over_sockets() {
        let svc = Arc::new(ServiceCore::new(b"bin"));
        let tok = svc.admin_token();
        let ka = HttpConfig { keep_alive: true, ..HttpConfig::default() };
        let server = serve_with(svc.clone(), "127.0.0.1:0", 2, ka.clone()).unwrap();
        let mut conn = HttpConn::with_wire(server.addr.clone(), ka, Wire::Binary);

        let site = conn
            .api(&tok, ApiRequest::CreateSite { name: "aps".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        conn.api(&tok, ApiRequest::RegisterApp {
            site,
            name: "MD".into(),
            command_template: "md {n}".into(),
            parameters: vec!["n".into()],
        })
        .unwrap();
        let ids = conn
            .api(&tok, ApiRequest::BulkCreateJobs { jobs: vec![JobCreate::simple(site, "MD", "md_small")] })
            .unwrap()
            .job_ids();
        assert_eq!(ids.len(), 1);
        let jobs = conn
            .api(&tok, ApiRequest::ListJobs { filter: JobFilter { site: Some(site), ..Default::default() } })
            .unwrap()
            .jobs();
        assert_eq!(jobs[0].state, JobState::Preprocessed);
        // App errors arrive as framed binary error envelopes.
        let err = conn.api(&tok, ApiRequest::SiteBacklog { site: SiteId(site.0 + 999) }).unwrap_err();
        assert!(matches!(err, ApiError::NotFound(_)), "{err:?}");
        let err = conn.api("balsam.1.bad", ApiRequest::SiteBacklog { site }).unwrap_err();
        assert_eq!(err, ApiError::Unauthorized);
        assert_eq!(conn.wire(), Wire::Binary, "no fallback against a binary-capable server");
        assert_eq!(conn.connects(), 1);
        server.stop();
    }

    /// Compatibility both ways on ONE server: a JSON-only client (no
    /// `Accept`, JSON bodies) and a binary client interleave freely —
    /// neither negotiation leaks into the other's responses.
    #[test]
    fn json_and_binary_clients_interleave_on_one_server() {
        let svc = Arc::new(ServiceCore::new(b"mix"));
        let tok = svc.admin_token();
        let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
        let mut jconn = HttpConn::with_wire(server.addr.clone(), HttpConfig::default(), Wire::Json);
        let mut bconn =
            HttpConn::with_wire(server.addr.clone(), HttpConfig::default(), Wire::Binary);

        let site = jconn
            .api(&tok, ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        for _ in 0..3 {
            jconn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
            bconn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
        }
        // A pre-codec peer (raw JSON POST, no Accept header) still gets
        // plain JSON back — the compatibility guarantee.
        let mut raw = HttpClient::new(server.addr.clone());
        let auth = format!("Bearer {tok}");
        let body = request_to_json(&ApiRequest::SiteBacklog { site }).to_string();
        let (status, bytes) = raw
            .request("POST", "/api", &[("authorization", &auth)], body.as_bytes())
            .unwrap();
        assert_eq!(status, 200);
        let parsed = Json::parse(&String::from_utf8_lossy(&bytes)).unwrap();
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        server.stop();
    }

    /// `--wire json` on the server: the first binary call eats a 415,
    /// demotes the connection to JSON permanently, and transparently
    /// re-issues — the caller just sees its responses.
    #[test]
    fn binary_client_falls_back_to_json_on_415() {
        let svc = Arc::new(ServiceCore::new(b"fb"));
        let tok = svc.admin_token();
        let gw = GatewayConfig { binary: false, ..Default::default() };
        let server =
            serve_with_limits(svc.clone(), "127.0.0.1:0", 2, HttpConfig::default(), gw).unwrap();
        let mut conn =
            HttpConn::with_wire(server.addr.clone(), HttpConfig::default(), Wire::Binary);

        let site = conn
            .api(&tok, ApiRequest::CreateSite { name: "s".into(), hostname: "h".into(), path: "/p".into() })
            .unwrap()
            .site_id();
        assert_eq!(conn.wire(), Wire::Json, "415 must demote the connection to JSON");
        // Demotion is permanent: later calls go straight through.
        conn.api(&tok, ApiRequest::SiteBacklog { site }).unwrap();
        assert_eq!(conn.connects(), 1, "fallback re-issue must ride the same connection");
        server.stop();
    }

    /// Malformed frames answer as framed 400s and never desynchronize the
    /// connection: truncated, bad-tag, and trailing-garbage frames each
    /// get a decodable error envelope, and a well-formed request right
    /// after succeeds on the same socket.
    #[test]
    fn malformed_frames_get_framed_400s() {
        use super::super::codec::frame;

        let svc = Arc::new(ServiceCore::new(b"mal"));
        let tok = svc.admin_token();
        let server = serve(svc.clone(), "127.0.0.1:0").unwrap();
        let auth = format!("Bearer {tok}");
        let mut raw = HttpClient::new(server.addr.clone());

        let mut good = Vec::new();
        frame::encode_request(&ApiRequest::ListEvents { since: 0 }, &mut good);
        let truncated = &good[..good.len() - 1];
        let mut trailing = good.clone();
        trailing.push(0xff);
        for bad in [&[0x01u8, 250][..], truncated, &trailing] {
            let (status, bytes) = raw
                .request(
                    "POST",
                    "/api",
                    &[("authorization", &auth), ("content-type", CT_FRAME), ("accept", CT_FRAME)],
                    bad,
                )
                .unwrap();
            assert_eq!(status, 400, "{bad:?}");
            let msg = frame::FrameCodec.decode_err(&bytes);
            assert_ne!(msg, "unknown", "400 body must be a decodable error frame");
        }
        // The connection survives the 400s and serves a good frame.
        let (status, _) = raw
            .request(
                "POST",
                "/api",
                &[("authorization", &auth), ("content-type", CT_FRAME), ("accept", CT_FRAME)],
                &good,
            )
            .unwrap();
        assert_eq!(status, 200);
        assert_eq!(raw.connects(), 1);
        server.stop();
    }
}
