//! `balsam` CLI — leader entrypoint.
//!
//! Subcommands:
//!   repro `<id|all>` [--fast] [--seed N]   regenerate a paper table/figure
//!   service [--addr A]                   run the central service over HTTP
//!   loadgen [--quick] [--out FILE]       open-loop capacity sweep + SLO verdict
//!   scenario [--quick] [--out FILE]      two-beamline × three-site real-time run
//!   runtime-check [--artifacts DIR]      load + execute the AOT artifacts
//!   state-graph                          print the job state machine
//!
//! The end-to-end drivers live in examples/ (see README).

use std::sync::Arc;

use balsam::service::persist::DEFAULT_SNAPSHOT_EVERY;
use balsam::service::{http_gw, EventLogConfig, FsyncPolicy, PersistMode, ServiceCore};
use balsam::util::cli::Args;
use balsam::util::httpd::{default_workers, HttpConfig};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("repro") => cmd_repro(&args),
        Some("service") => cmd_service(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("scenario") => cmd_scenario(&args),
        Some("runtime-check") => cmd_runtime_check(&args),
        Some("state-graph") => cmd_state_graph(),
        _ => {
            eprintln!(
                "usage: balsam <repro|service|loadgen|scenario|runtime-check|state-graph> [options]\n\
                 \n  repro <id|all> [--fast] [--seed N]   ids: {:?}\
                 \n  service [--addr 127.0.0.1:8008] [--persist-dir DIR] [--snapshot-every N]\
                 \n          [--fsync=never|always|group:K,Tms] [--events-segment-bytes N]\
                 \n          [--events-retain-bytes N] [--events-retain-age SECS]\
                 \n          [--workers N] [--no-keepalive] [--http-idle-timeout SECS]\
                 \n          [--http-max-requests N] [--subscribe-max-ms N] [--no-metrics]\
                 \n          [--accept-queue-limit N] [--watch-page-max N]\
                 \n          [--rate-limit RPS,BURST] [--rate-limit-admin-exempt]\
                 \n          [--wire json|binary]\
                 \n  loadgen [--quick] [--out FILE] [--target ADDR --token T]\
                 \n          [--mix submit,sync,watch] [--sites 1,4] [--sessions 2,8]\
                 \n          [--wire json|binary]\
                 \n          [--rps-start N] [--rps-factor X] [--rps-steps N] [--step-secs S]\
                 \n          [--stop-failure-rate F] [--stop-median-ms MS] [--workers N]\
                 \n          [--wal-dir DIR] [--fsync=never|always|group:K,Tms] [--seed N]\
                 \n  loadgen --fairness [--quick] [--out FILE] [--polite N] [--greedy N]\
                 \n          [--polite-rps R] [--greedy-rps R] [--fairness-secs S]\
                 \n          [--rate-limit RPS,BURST] [--workers N] [--seed N]\
                 \n  scenario [--quick] [--out FILE] [--batches N] [--batch N]\
                 \n          [--trigger-period SECS] [--poll-period SECS] [--run-secs SECS]\
                 \n          [--kill-site IDX] [--restart-mid-run] [--no-staging]\
                 \n          [--deadline SECS] [--workers N]\
                 \n  runtime-check [--artifacts artifacts] [--model NAME]\
                 \n  state-graph",
                balsam::experiments::ALL
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_repro(args: &Args) -> balsam::Result<()> {
    let id = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let fast = args.flag("fast");
    let seed = args.u64_or("seed", 2021);
    balsam::experiments::run(id, fast, seed)
}

fn cmd_service(args: &Args) -> balsam::Result<()> {
    let addr = args.str_or("addr", "127.0.0.1:8008");
    // --persist-dir enables the durable WAL+snapshot backend: restarting
    // with the same dir recovers all jobs/sessions/transfers/events.
    // --fsync picks the commit durability (flush-to-OS, fsync-always, or
    // group commit — acks wait for a shared fsync; e.g. --fsync=group:64,5ms,
    // where K is an advisory group-size bound and T the stall-recovery
    // re-check period); the --events-* knobs size the segmented event log
    // and its retention.
    let fsync_spec = args.str_or("fsync", "never");
    let fsync = FsyncPolicy::parse(fsync_spec);
    balsam::ensure!(
        fsync.is_some(),
        "--fsync must be never|always|group|group:K,Tms — got '{fsync_spec}'"
    );
    let defaults = EventLogConfig::default();
    let mode = match args.get("persist-dir") {
        Some(dir) => PersistMode::Wal {
            dir: dir.into(),
            snapshot_every: args.u64_or("snapshot-every", DEFAULT_SNAPSHOT_EVERY),
            fsync: fsync.unwrap(),
            events: EventLogConfig {
                segment_bytes: args.u64_or("events-segment-bytes", defaults.segment_bytes),
                retain_bytes: args.u64_or("events-retain-bytes", defaults.retain_bytes),
                retain_age_s: args.u64_or("events-retain-age", defaults.retain_age_s),
            },
        },
        None => PersistMode::Ephemeral,
    };
    let durable = matches!(mode, PersistMode::Wal { .. });
    // Transport knobs: keep-alive (default on, also via the
    // BALSAM_HTTP_KEEPALIVE env var), idle reap, per-connection request
    // budget, gateway worker-pool size.
    let mut http = HttpConfig::default();
    if args.flag("no-keepalive") {
        http.keep_alive = false;
    }
    let idle_secs = args.f64_or("http-idle-timeout", http.idle_timeout.as_secs_f64());
    balsam::ensure!(
        idle_secs.is_finite() && idle_secs > 0.0 && idle_secs <= 1e9,
        "--http-idle-timeout must be seconds in (0, 1e9], got {idle_secs}"
    );
    http.idle_timeout = std::time::Duration::from_secs_f64(idle_secs);
    http.max_requests_per_conn = args.u64_or("http-max-requests", 0) as usize;
    // --accept-queue-limit bounds the transport's admission queue: past
    // it the gateway sheds with a framed 503 + Retry-After instead of
    // queueing without bound (0 disables shedding).
    http.accept_queue_limit =
        args.u64_or("accept-queue-limit", http.accept_queue_limit as u64) as usize;
    let workers = args.u64_or("workers", default_workers() as u64) as usize;
    let keep_alive = http.keep_alive;
    let idle = http.idle_timeout.as_secs();
    // --rate-limit RPS,BURST turns on the per-principal token bucket;
    // --rate-limit-admin-exempt keeps the bootstrap admin unthrottled
    // for break-glass operations.
    let mut gw = http_gw::GatewayConfig::default();
    if let Some(spec) = args.get("rate-limit") {
        let rl = parse_rate_limit(spec);
        balsam::ensure!(
            rl.is_some(),
            "--rate-limit must be RPS,BURST (positive integers), got '{spec}'"
        );
        gw.rate_limit = rl;
    }
    gw.admin_exempt = args.flag("rate-limit-admin-exempt");
    // --wire binary (default) negotiates both envelope encodings per
    // request; --wire json answers binary-frame requests with 415 so
    // capable clients fall back to JSON (JSON is always accepted).
    let wire_spec = args.str_or("wire", "binary");
    balsam::ensure!(
        matches!(wire_spec, "json" | "binary"),
        "--wire must be json|binary, got '{wire_spec}'"
    );
    gw.binary = wire_spec == "binary";
    let mut core = ServiceCore::with_persist(b"balsam-demo-secret", mode)?;
    // --watch-page-max clamps one WatchEvents page server-side (the
    // credit ceiling; clients may only lower it per request, 0 = no cap).
    core.watch_page_max = args.u64_or("watch-page-max", core.watch_page_max as u64) as usize;
    // Server-side clamp on WatchEvents long polls: must stay below the
    // pooled client's read timeout (with a 1 s margin) or armed
    // subscribers would time out at the transport instead of renewing
    // cleanly.
    let cap_ms = balsam::util::httpd::CLIENT_READ_TIMEOUT.as_millis() as u64 - 1_000;
    let subscribe_max = args.u64_or("subscribe-max-ms", core.subscribe_max_ms);
    balsam::ensure!(
        subscribe_max <= cap_ms,
        "--subscribe-max-ms must be <= {cap_ms} (the transport read timeout minus margin), \
         got {subscribe_max}"
    );
    core.subscribe_max_ms = subscribe_max;
    // --no-metrics turns hot-path recording into cheap no-ops; /metrics
    // and /healthz stay routable (the exposition just stops advancing).
    let metrics_on = !args.flag("no-metrics");
    balsam::util::metrics::set_enabled(metrics_on);
    let svc = Arc::new(core);
    let token = svc.admin_token();
    let rate_limited = gw.rate_limit;
    let admin_exempt = gw.admin_exempt;
    let binary_frames = gw.binary;
    let queue_limit = http.accept_queue_limit;
    let server = http_gw::serve_with_limits(svc, addr, workers, http, gw)?;
    println!("balsam service on http://{}", server.addr);
    println!("admin token: {token}");
    match rate_limited {
        Some((rps, burst)) => println!(
            "admission: accept queue limit {queue_limit}, per-principal rate limit \
             {rps} rps (burst {burst}){}",
            if admin_exempt { ", admin exempt" } else { "" }
        ),
        None => println!("admission: accept queue limit {queue_limit}, no rate limit"),
    }
    println!(
        "transport: {} ({workers} workers, idle timeout {idle}s)",
        if keep_alive { "HTTP/1.1 keep-alive" } else { "one request per connection" }
    );
    println!(
        "wire: {}",
        if binary_frames {
            "JSON + binary frames (negotiated per request via Content-Type/Accept)"
        } else {
            "JSON only (--wire json; binary frames answered with 415)"
        }
    );
    if durable {
        println!(
            "durable store: {} (WAL + snapshots + event segments; fsync={})",
            args.str_or("persist-dir", ""),
            fsync_spec
        );
    }
    println!(
        "observability: GET /metrics (Prometheus) and /healthz, recording {}",
        if metrics_on { "on" } else { "off (--no-metrics)" }
    );
    println!("POST JSON to /api with 'authorization: Bearer <token>'. Ctrl-C to stop.");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_loadgen(args: &Args) -> balsam::Result<()> {
    // --fairness runs the greedy-vs-polite tenant probe instead of the
    // capacity ladder (see docs/OPERATIONS.md "Backpressure & quotas").
    if args.flag("fairness") {
        return cmd_loadgen_fairness(args);
    }
    // Capacity sweep (see docs/OPERATIONS.md "Capacity testing"): open-loop
    // rps ladder per (mix × sites × sessions) combo with stop-and-declare
    // SLO rules. Self-hosts a fresh service per combo unless --target (+
    // --token) attaches to a running one.
    let mut cfg = if args.flag("quick") {
        balsam::loadgen::LoadgenConfig::quick()
    } else {
        balsam::loadgen::LoadgenConfig::default()
    };
    if let Some(addr) = args.get("target") {
        let token = args.get("token");
        balsam::ensure!(token.is_some(), "--target requires --token <bearer token>");
        cfg.target = Some((addr.to_string(), token.unwrap().to_string()));
    }
    if let Some(spec) = args.get("mix") {
        let mut mixes = Vec::new();
        for part in spec.split(',') {
            let m = balsam::loadgen::mix::Mix::parse(part);
            balsam::ensure!(m.is_some(), "--mix must be submit|sync|watch (comma-separated), got '{part}'");
            mixes.push(m.unwrap());
        }
        cfg.mixes = mixes;
    }
    if let Some(spec) = args.get("sites") {
        cfg.sites_list = parse_usize_list("sites", spec)?;
    }
    if let Some(spec) = args.get("sessions") {
        cfg.sessions_list = parse_usize_list("sessions", spec)?;
    }
    cfg.rps_start = args.f64_or("rps-start", cfg.rps_start);
    cfg.rps_factor = args.f64_or("rps-factor", cfg.rps_factor);
    cfg.rps_steps = args.u64_or("rps-steps", cfg.rps_steps as u64) as usize;
    cfg.step_secs = args.f64_or("step-secs", cfg.step_secs);
    cfg.stop_failure_rate = args.f64_or("stop-failure-rate", cfg.stop_failure_rate);
    cfg.stop_median_ms = args.f64_or("stop-median-ms", cfg.stop_median_ms);
    cfg.workers = args.u64_or("workers", cfg.workers as u64) as usize;
    cfg.seed = args.u64_or("seed", cfg.seed);
    // --wire overrides the BALSAM_WIRE env default the config picked up.
    if let Some(spec) = args.get("wire") {
        let w = balsam::service::Wire::parse(spec);
        balsam::ensure!(w.is_some(), "--wire must be json|binary, got '{spec}'");
        cfg.wire = w.unwrap();
    }
    balsam::ensure!(
        cfg.rps_start > 0.0 && cfg.rps_factor > 1.0 && cfg.step_secs > 0.0,
        "--rps-start must be > 0, --rps-factor > 1, --step-secs > 0"
    );
    if let Some(dir) = args.get("wal-dir") {
        let fsync_spec = args.str_or("fsync", "group");
        let fsync = FsyncPolicy::parse(fsync_spec);
        balsam::ensure!(
            fsync.is_some(),
            "--fsync must be never|always|group|group:K,Tms — got '{fsync_spec}'"
        );
        cfg.wal = Some((dir.into(), fsync.unwrap()));
    }

    let report = balsam::loadgen::run(&cfg)?;
    let json = report.to_json().to_string();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &json)
            .map_err(|e| balsam::util::error::err_msg(format!("write {out}: {e}")))?;
        eprintln!("loadgen report written to {out}");
    } else {
        println!("{json}");
    }
    Ok(())
}

fn cmd_loadgen_fairness(args: &Args) -> balsam::Result<()> {
    let mut cfg = if args.flag("quick") {
        balsam::loadgen::FairnessConfig::quick()
    } else {
        balsam::loadgen::FairnessConfig::default()
    };
    cfg.polite = args.u64_or("polite", cfg.polite as u64) as usize;
    cfg.greedy = args.u64_or("greedy", cfg.greedy as u64) as usize;
    cfg.polite_rps = args.f64_or("polite-rps", cfg.polite_rps);
    cfg.greedy_rps = args.f64_or("greedy-rps", cfg.greedy_rps);
    cfg.duration_s = args.f64_or("fairness-secs", cfg.duration_s);
    if let Some(spec) = args.get("rate-limit") {
        let rl = parse_rate_limit(spec);
        balsam::ensure!(
            rl.is_some(),
            "--rate-limit must be RPS,BURST (positive integers), got '{spec}'"
        );
        cfg.rate_limit = rl.unwrap();
    }
    cfg.workers = args.u64_or("workers", cfg.workers as u64) as usize;
    cfg.seed = args.u64_or("seed", cfg.seed);
    balsam::ensure!(
        cfg.polite >= 1 && cfg.greedy >= 1,
        "--fairness needs at least one polite and one greedy tenant"
    );
    balsam::ensure!(
        cfg.polite_rps > 0.0 && cfg.greedy_rps > 0.0 && cfg.duration_s > 0.0,
        "--polite-rps, --greedy-rps and --fairness-secs must be > 0"
    );
    let report = balsam::loadgen::run_fairness(&cfg)?;
    let json = report.to_json().to_string();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &json)
            .map_err(|e| balsam::util::error::err_msg(format!("write {out}: {e}")))?;
        eprintln!("fairness report written to {out}");
    } else {
        println!("{json}");
    }
    Ok(())
}

fn cmd_scenario(args: &Args) -> balsam::Result<()> {
    // The paper's end-to-end demo (see docs/ARCHITECTURE.md "End-to-end
    // real-time path"): two beamlines trigger batches against three
    // push-mode sites over real sockets; the report carries push vs poll
    // trigger-to-result latency plus the integrity counters the scenario
    // gate checks (lost / duplicates / undelivered all zero).
    let mut cfg = balsam::scenario::ScenarioConfig::quick();
    if !args.flag("quick") {
        cfg.batches = 4;
        cfg.batch = 6;
        cfg.deadline_s = 120.0;
    }
    cfg.batches = args.u64_or("batches", cfg.batches as u64) as usize;
    cfg.batch = args.u64_or("batch", cfg.batch as u64) as usize;
    cfg.trigger_period_s = args.f64_or("trigger-period", cfg.trigger_period_s);
    cfg.poll_period_s = args.f64_or("poll-period", cfg.poll_period_s);
    cfg.run_s = args.f64_or("run-secs", cfg.run_s);
    cfg.deadline_s = args.f64_or("deadline", cfg.deadline_s);
    cfg.workers = args.u64_or("workers", cfg.workers as u64) as usize;
    if let Some(idx) = args.get("kill-site") {
        let idx: usize = idx
            .parse()
            .map_err(|_| balsam::err!("--kill-site expects a site index, got '{idx}'"))?;
        balsam::ensure!(idx < cfg.facilities.len(), "--kill-site index out of range");
        cfg.kill_site_mid_batch = Some(idx);
    }
    if args.flag("restart-mid-run") {
        cfg.restart_service_mid_run = true;
    }
    if args.flag("no-staging") {
        cfg.stage_data = false;
    }
    let report = balsam::scenario::run(&cfg)?;
    let json = report.to_json().to_string();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &json)
            .map_err(|e| balsam::util::error::err_msg(format!("write {out}: {e}")))?;
        eprintln!(
            "scenario report written to {out} (push p95 {:.1} ms, poll p95 {:.1} ms, speedup {:.1}x)",
            report.push.p95_ms,
            report.poll.p95_ms,
            report.push_speedup_p95()
        );
    } else {
        println!("{json}");
    }
    Ok(())
}

/// `RPS,BURST` — two positive integers.
fn parse_rate_limit(spec: &str) -> Option<(u64, u64)> {
    let (rps, burst) = spec.split_once(',')?;
    let rps: u64 = rps.trim().parse().ok()?;
    let burst: u64 = burst.trim().parse().ok()?;
    (rps > 0 && burst > 0).then_some((rps, burst))
}

fn parse_usize_list(flag: &str, spec: &str) -> balsam::Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in spec.split(',') {
        let n: usize = part
            .trim()
            .parse()
            .map_err(|_| balsam::util::error::err_msg(format!("--{flag}: bad count '{part}'")))?;
        balsam::ensure!(n > 0, "--{flag}: counts must be > 0");
        out.push(n);
    }
    balsam::ensure!(!out.is_empty(), "--{flag}: empty list");
    Ok(out)
}

fn cmd_runtime_check(args: &Args) -> balsam::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let only = args.get("model");
    let names: Vec<&str> = only.into_iter().collect();
    let rt = balsam::runtime::Runtime::load(dir, &names)?;
    for (name, model) in &rt.models {
        let inputs: Vec<Vec<f32>> = model
            .spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, _)| (0..model.spec.input_len(i)).map(|k| 1.0 + (k % 7) as f32 * 0.1).collect())
            .collect();
        let t0 = std::time::Instant::now();
        let outs = model.run_f32(&inputs)?;
        println!(
            "{name}: ok in {:.2}s — outputs {:?}",
            t0.elapsed().as_secs_f64(),
            outs.iter().map(Vec::len).collect::<Vec<_>>()
        );
    }
    Ok(())
}

fn cmd_state_graph() -> balsam::Result<()> {
    use balsam::service::models::JobState;
    use balsam::service::state::successors;
    println!("Balsam job state machine:");
    for s in JobState::ALL {
        let succ: Vec<&str> = successors(s).into_iter().map(|x| x.name()).collect();
        println!(
            "  {:>18} -> {}",
            s.name(),
            if succ.is_empty() { "(terminal)".into() } else { succ.join(", ") }
        );
    }
    Ok(())
}
