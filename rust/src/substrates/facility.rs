//! Compute-facility and light-source catalog + calibration constants.
//!
//! Numbers are taken from the paper's own measurements (§4, Table 1,
//! Figs. 4/5/8) so the simulators regenerate the evaluation's *shape*:
//! who wins, by what factor, and where the crossovers fall.

/// Batch scheduler family at a facility.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    /// ALCF-Theta. Job starts are effectively serialized: the paper
    /// measures a *median 273 s* per-job queueing delay on an exclusive
    /// idle reservation — Cobalt's startup rate, not resource contention.
    Cobalt,
    /// NERSC-Cori: parallel job starts with a median 2.7 s delay.
    Slurm,
    /// OLCF-Summit.
    Lsf,
}

/// A compute facility (execution site substrate).
#[derive(Debug, Clone)]
pub struct Facility {
    pub name: &'static str,
    pub scheduler: SchedKind,
    pub total_nodes: u32,
    pub cores_per_node: u32,
    /// Serialized job-start interval (s, lognormal median): Cobalt model.
    pub start_interval_median: f64,
    /// Per-job startup delay (s, lognormal median): Slurm/LSF model.
    pub start_delay_median: f64,
}

pub const THETA: Facility = Facility {
    name: "theta",
    scheduler: SchedKind::Cobalt,
    total_nodes: 4392,
    cores_per_node: 64,
    start_interval_median: 8.6, // 273 s median queueing at ~32-job backlog
    start_delay_median: 12.0,
};

pub const SUMMIT: Facility = Facility {
    name: "summit",
    scheduler: SchedKind::Lsf,
    total_nodes: 4608,
    cores_per_node: 42,
    start_interval_median: 0.0,
    start_delay_median: 8.0,
};

pub const CORI: Facility = Facility {
    name: "cori",
    scheduler: SchedKind::Slurm,
    total_nodes: 2388,
    cores_per_node: 32,
    start_interval_median: 0.0,
    start_delay_median: 2.7, // paper: median Slurm queueing delay 2.7 s
};

pub const FACILITIES: [&Facility; 3] = [&THETA, &SUMMIT, &CORI];

pub fn facility(name: &str) -> &'static Facility {
    FACILITIES.iter().find(|f| f.name == name).unwrap_or_else(|| panic!("unknown facility {name}"))
}

/// Light sources (data-producing client endpoints).
pub const LIGHT_SOURCES: [&str; 2] = ["APS", "ALS"];

/// Application runtime model: (mean, sd) seconds on one node of `fac`.
///
/// Calibration: Table 1 (MD on Theta), Fig. 8 medians (XPCS per system),
/// §4.2 ("task durations on the order of 20 seconds (small input) or 1.5
/// minutes (large input)").
pub fn runtime_model(fac: &str, workload: &str) -> (f64, f64) {
    match (fac, workload) {
        ("theta", "md_small") => (18.6, 9.6),
        ("theta", "md_large") => (89.1, 3.8),
        ("theta", "xpcs") => (110.0, 8.0),
        ("summit", "md_small") => (13.0, 5.0),
        ("summit", "md_large") => (65.0, 5.0),
        ("summit", "xpcs") => (108.0, 8.0),
        ("cori", "md_small") => (9.5, 3.0),
        ("cori", "md_large") => (45.0, 4.0),
        ("cori", "xpcs") => (55.0, 6.0),
        // Local-cluster baseline treats staging as filesystem copy; runtime
        // identical to the Balsam case by construction (§4.1.5).
        (_, w) => default_runtime(w),
    }
}

fn default_runtime(workload: &str) -> (f64, f64) {
    match workload {
        "md_small" => (15.0, 5.0),
        "md_large" => (70.0, 6.0),
        "xpcs" => (90.0, 8.0),
        _ => (10.0, 2.0),
    }
}

/// Dataset payload sizes (bytes) per workload class (paper §4.1.3).
pub fn payload_bytes(workload: &str) -> (u64, u64) {
    match workload {
        // (stage-in, stage-out)
        "md_small" => (200_000_000, 40_000),    // 5000^2 f64 -> 40 kB eigenvalues
        "md_large" => (1_150_000_000, 96_000),  // 12000^2 -> 96 kB
        "xpcs" => (878_000_000, 55_000_000),    // 823 MB IMM + 55 MB HDF; HDF returns
        _ => (1_000_000, 1_000),
    }
}

/// Pilot-job application-launch overhead (s): paper §4.5 — "consistently
/// in the range of 1 to 2 seconds".
pub const APP_STARTUP_OVERHEAD: (f64, f64) = (1.0, 2.0);

/// WAN route calibration: effective per-transfer-task bandwidth
/// (MB/s, lognormal median + sigma) and aggregate route capacity (MB/s).
/// Calibrated against Fig. 5 quartiles and the Fig. 9 arrival rates
/// (Theta 16.0, Summit 19.6, Cori 29.6 datasets/min at 878 MB/dataset).
pub struct RouteCal {
    pub task_bw_median: f64,
    pub sigma: f64,
    pub capacity: f64,
}

/// Base calibration (the MD campaign: Table 1 / Figs. 3-4 sustain
/// 2.0 jobs/s of 200 MB datasets into Theta, i.e. >=400 MB/s effective).
/// The XPCS campaign measured markedly lower effective rates — the paper
/// itself flags APS->ALCF DTN rates as anomalous ("needs further
/// investigation", §4.3) — so the XPCS experiments apply
/// [`XPCS_CAMPAIGN_BW_SCALE`] on top of this base (see `NetSim::bw_scale`).
pub fn route_cal(light_source: &str, fac: &str) -> RouteCal {
    let (m, cap) = match (light_source, fac) {
        ("APS", "theta") => (310.0, 660.0),
        ("APS", "summit") => (380.0, 810.0),
        ("APS", "cori") => (540.0, 1150.0),
        ("ALS", "theta") => (270.0, 580.0),
        ("ALS", "summit") => (340.0, 720.0),
        ("ALS", "cori") => (480.0, 1030.0),
        // Local (intra-facility) staging: parallel filesystem copy, one to
        // three orders of magnitude faster than WAN (Fig. 4).
        _ => (1800.0, 8000.0),
    };
    RouteCal { task_bw_median: m, sigma: 0.35, capacity: cap }
}

/// Bandwidth derate reproducing the effective rates measured during the
/// paper's XPCS campaign (Fig. 5 / Fig. 8 / Fig. 9 arrival rates:
/// Theta 16.0, Summit 19.6, Cori 29.6 datasets/min at 878 MB/dataset).
pub const XPCS_CAMPAIGN_BW_SCALE: f64 = 0.40;

/// GridFTP pipelining efficiency vs files-per-task (Yildirim et al. [40]):
/// one file cannot saturate a transfer task (default concurrency 4).
pub fn gridftp_efficiency(nfiles: usize) -> f64 {
    match nfiles {
        0 | 1 => 0.45,
        2 => 0.62,
        3 => 0.78,
        _ => 0.92,
    }
}

/// Fixed per-transfer-task overhead (s): Globus API + GridFTP setup.
pub const XFER_TASK_OVERHEAD: (f64, f64) = (3.0, 7.0);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lookup() {
        assert_eq!(facility("theta").scheduler, SchedKind::Cobalt);
        assert_eq!(facility("cori").scheduler, SchedKind::Slurm);
        assert_eq!(facility("summit").total_nodes, 4608);
    }

    #[test]
    #[should_panic(expected = "unknown facility")]
    fn unknown_facility_panics() {
        facility("frontier");
    }

    #[test]
    fn runtime_ordering_matches_fig8() {
        // Fig 8/9: Cori runs XPCS ~2x faster than Theta/Summit.
        let (theta, _) = runtime_model("theta", "xpcs");
        let (summit, _) = runtime_model("summit", "xpcs");
        let (cori, _) = runtime_model("cori", "xpcs");
        assert!(cori < 0.6 * theta);
        assert!((theta - summit).abs() < 10.0);
    }

    #[test]
    fn md_large_slower_than_small_everywhere() {
        for f in ["theta", "summit", "cori"] {
            assert!(runtime_model(f, "md_large").0 > 3.0 * runtime_model(f, "md_small").0);
        }
    }

    #[test]
    fn route_ordering_matches_fig5() {
        // Fig 5 + Fig 9: effective APS rates order Theta < Summit < Cori.
        let t = route_cal("APS", "theta").task_bw_median;
        let s = route_cal("APS", "summit").task_bw_median;
        let c = route_cal("APS", "cori").task_bw_median;
        assert!(t < s && s < c);
        // Local staging is much faster still.
        assert!(route_cal("local", "theta").task_bw_median > 3.0 * c);
    }

    #[test]
    fn gridftp_efficiency_monotone() {
        let mut last = 0.0;
        for n in 0..8 {
            let e = gridftp_efficiency(n);
            assert!(e >= last && e <= 1.0);
            last = e;
        }
    }

    #[test]
    fn payloads_match_paper() {
        assert_eq!(payload_bytes("md_small").0, 200_000_000);
        assert_eq!(payload_bytes("md_large").0, 1_150_000_000);
        assert_eq!(payload_bytes("xpcs"), (878_000_000, 55_000_000));
    }
}
