//! HPC batch scheduler simulators: Cobalt (Theta), Slurm (Cori), LSF
//! (Summit).
//!
//! The model captures the *measured* behaviours the paper's evaluation
//! hinges on (§4.2, Fig. 3/4):
//!
//! * **Cobalt** job starts are serialized — one start per sampled
//!   interval — which produced a median 273 s per-job queueing delay on an
//!   exclusive idle 32-node reservation and makes the local-baseline
//!   throughput flat in node count;
//! * **Slurm/LSF** start jobs in parallel after a small sampled per-job
//!   delay (median 2.7 s on Cori), so the local baseline is moderately
//!   scalable;
//! * allocations end at their wall-time limit, can be deleted while
//!   queued, and can be killed ungracefully (fault injection, §4.4).

use std::collections::BTreeMap;

use crate::site::platform::{AllocStatus, SchedulerBackend};
use crate::substrates::facility::{facility, SchedKind};
use crate::util::rng::Pcg;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JState {
    Queued,
    Running,
    Finished,
    Killed,
    Deleted,
}

#[derive(Debug)]
struct LJob {
    nodes: u32,
    wall_s: f64,
    state: JState,
    submit_t: f64,
    /// Parallel-start model: job may start once `now >= submit_t + delay`.
    delay: f64,
    start_t: f64,
    end_t: f64,
}

/// One facility's batch scheduler. `reserved_nodes` caps the pool (the
/// paper ran on exclusive reservations to exclude other users).
pub struct BatchSim {
    pub fac_name: String,
    kind: SchedKind,
    pub total_nodes: u32,
    free: u32,
    jobs: BTreeMap<u64, LJob>,
    fifo: Vec<u64>,
    next_id: u64,
    /// Cobalt serialization: earliest time of the next job start.
    next_serial_start: f64,
    rng: Pcg,
    /// Median of the serialized start interval (Cobalt model).
    start_interval_median: f64,
    /// Median per-job start delay (Slurm/LSF model).
    start_delay_median: f64,
}

impl BatchSim {
    /// Scheduler for `fac_name` with an exclusive reservation of
    /// `reserved_nodes` (0 = whole machine).
    pub fn new(fac_name: &str, reserved_nodes: u32, seed: u64) -> BatchSim {
        let f = facility(fac_name);
        let nodes = if reserved_nodes == 0 { f.total_nodes } else { reserved_nodes };
        BatchSim {
            fac_name: fac_name.to_string(),
            kind: f.scheduler,
            total_nodes: nodes,
            free: nodes,
            jobs: BTreeMap::new(),
            fifo: Vec::new(),
            next_id: 0,
            next_serial_start: 0.0,
            rng: Pcg::seeded(seed ^ 0xbad5eed),
            start_interval_median: f.start_interval_median,
            start_delay_median: f.start_delay_median,
        }
    }

    /// Advance scheduler state: finish expired jobs, start eligible ones.
    pub fn pump(&mut self, now: f64) {
        // Finish running jobs at their wall-time limit.
        for j in self.jobs.values_mut() {
            if j.state == JState::Running && now >= j.end_t {
                j.state = JState::Finished;
                self.free += j.nodes;
            }
        }
        // Start queued jobs.
        match self.kind {
            SchedKind::Cobalt => {
                // Serialized starts, strict FIFO (no backfill on Theta's
                // default queue for this model). Starts are assigned to
                // serialization *slots*, so measured queue delays are
                // independent of how often the site polls qstat.
                loop {
                    let Some(&head) = self.fifo.first() else { break };
                    let j = &self.jobs[&head];
                    let slot = self.next_serial_start.max(j.submit_t);
                    if slot > now || self.free < j.nodes {
                        break;
                    }
                    self.start_job(head, slot);
                    self.fifo.remove(0);
                    self.next_serial_start =
                        slot + self.rng.lognormal_median(self.start_interval_median, 0.5);
                }
            }
            SchedKind::Slurm | SchedKind::Lsf => {
                // Parallel starts with per-job delay; FIFO with skip.
                let mut i = 0;
                while i < self.fifo.len() {
                    let id = self.fifo[i];
                    let j = &self.jobs[&id];
                    if now >= j.submit_t + j.delay && self.free >= j.nodes {
                        self.start_job(id, now);
                        self.fifo.remove(i);
                    } else {
                        i += 1;
                    }
                }
            }
        }
    }

    fn start_job(&mut self, id: u64, at: f64) {
        let j = self.jobs.get_mut(&id).unwrap();
        j.state = JState::Running;
        j.start_t = at;
        j.end_t = at + j.wall_s;
        self.free -= j.nodes;
    }

    /// Ungraceful termination of a *running* allocation (fault injection):
    /// nodes return, the pilot gets no chance to clean up.
    pub fn kill(&mut self, now: f64, id: u64) {
        self.pump(now);
        if let Some(j) = self.jobs.get_mut(&id) {
            if j.state == JState::Running {
                j.state = JState::Killed;
                j.end_t = now;
                self.free += j.nodes;
            }
        }
    }

    /// Graceful early release by the pilot itself (idle timeout).
    pub fn release(&mut self, now: f64, id: u64) {
        self.pump(now);
        if let Some(j) = self.jobs.get_mut(&id) {
            if j.state == JState::Running {
                j.state = JState::Finished;
                j.end_t = now;
                self.free += j.nodes;
            }
        }
    }

    pub fn running_ids(&self) -> Vec<u64> {
        self.jobs
            .iter()
            .filter(|(_, j)| j.state == JState::Running)
            .map(|(&id, _)| id)
            .collect()
    }

    /// Queueing delay (submit -> start) of a finished/running job.
    pub fn queue_delay(&self, id: u64) -> Option<f64> {
        let j = self.jobs.get(&id)?;
        if matches!(j.state, JState::Running | JState::Finished | JState::Killed) {
            Some(j.start_t - j.submit_t)
        } else {
            None
        }
    }
}

impl SchedulerBackend for BatchSim {
    fn submit(&mut self, now: f64, _fac: &str, nodes: u32, wall_s: f64) -> u64 {
        self.next_id += 1;
        let id = self.next_id;
        let delay = match self.kind {
            SchedKind::Cobalt => 0.0, // serialization dominates
            _ => self.rng.lognormal_median(self.start_delay_median, 0.5),
        };
        self.jobs.insert(
            id,
            LJob {
                nodes,
                wall_s,
                state: JState::Queued,
                submit_t: now,
                delay,
                start_t: f64::NAN,
                end_t: f64::INFINITY,
            },
        );
        self.fifo.push(id);
        self.pump(now);
        id
    }

    fn status(&mut self, now: f64, id: u64) -> AllocStatus {
        self.pump(now);
        match self.jobs.get(&id).map(|j| (j.state, j.end_t)) {
            Some((JState::Queued, _)) => AllocStatus::Queued,
            Some((JState::Running, end)) => AllocStatus::Running { end_by: end },
            Some((JState::Finished, _)) => AllocStatus::Finished,
            Some((JState::Killed, _)) | Some((JState::Deleted, _)) | None => AllocStatus::Killed,
        }
    }

    fn delete(&mut self, now: f64, id: u64) {
        self.pump(now);
        if let Some(j) = self.jobs.get_mut(&id) {
            if j.state == JState::Queued {
                j.state = JState::Deleted;
                self.fifo.retain(|&x| x != id);
            }
        }
    }

    fn release_early(&mut self, now: f64, id: u64) {
        self.release(now, id);
    }

    fn free_nodes(&mut self, now: f64) -> u32 {
        self.pump(now);
        self.free
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    #[test]
    fn slurm_starts_fast_and_parallel() {
        let mut s = BatchSim::new("cori", 32, 7);
        let ids: Vec<u64> = (0..8).map(|_| s.submit(0.0, "cori", 1, 100.0)).collect();
        for t in 0..30 {
            s.pump(t as f64); // site polls qstat every second
        }
        for id in &ids {
            assert!(matches!(s.status(30.0, *id), AllocStatus::Running { .. }));
        }
        let mut delays = Summary::new();
        for id in &ids {
            delays.add(s.queue_delay(*id).unwrap());
        }
        // Median-ish around 2.7 s (Fig. 4 Slurm).
        assert!(delays.percentile(50.0) < 10.0, "median={}", delays.percentile(50.0));
    }

    #[test]
    fn cobalt_serializes_starts() {
        let mut s = BatchSim::new("theta", 32, 7);
        let ids: Vec<u64> = (0..32).map(|_| s.submit(0.0, "theta", 1, 1e6)).collect();
        s.pump(3600.0);
        // All started eventually, but queue delays grow with position:
        // median over the batch is hundreds of seconds (paper: 273 s).
        let mut delays: Vec<f64> = ids.iter().map(|&i| s.queue_delay(i).unwrap()).collect();
        delays.sort_by(f64::total_cmp);
        let median = delays[delays.len() / 2];
        assert!(median > 100.0 && median < 600.0, "median={median}");
        // And starts are strictly ordered (FIFO).
        assert!(delays.windows(2).all(|w| w[0] <= w[1] + 1e-9));
    }

    #[test]
    fn wall_time_limit_enforced() {
        let mut s = BatchSim::new("cori", 8, 1);
        let id = s.submit(0.0, "cori", 4, 60.0);
        s.pump(20.0);
        let AllocStatus::Running { end_by } = s.status(20.0, id) else {
            panic!("should be running")
        };
        assert!(end_by <= 80.0);
        assert_eq!(s.status(end_by + 1.0, id), AllocStatus::Finished);
        assert_eq!(s.free_nodes(end_by + 1.0), 8);
    }

    #[test]
    fn node_accounting_never_negative_or_over() {
        let mut s = BatchSim::new("cori", 16, 3);
        let mut ids = Vec::new();
        for i in 0..10 {
            ids.push(s.submit(i as f64, "cori", 4, 50.0));
        }
        for t in 0..200 {
            s.pump(t as f64);
            let running: u32 = ids
                .iter()
                .filter(|&&i| matches!(s.status(t as f64, i), AllocStatus::Running { .. }))
                .count() as u32
                * 4;
            assert!(running <= 16);
            assert_eq!(s.free_nodes(t as f64), 16 - running);
        }
    }

    #[test]
    fn kill_frees_nodes_immediately() {
        let mut s = BatchSim::new("cori", 8, 5);
        let id = s.submit(0.0, "cori", 8, 1000.0);
        s.pump(30.0);
        assert!(matches!(s.status(30.0, id), AllocStatus::Running { .. }));
        s.kill(31.0, id);
        assert_eq!(s.status(31.0, id), AllocStatus::Killed);
        assert_eq!(s.free_nodes(31.0), 8);
    }

    #[test]
    fn delete_dequeues() {
        let mut s = BatchSim::new("cori", 4, 9);
        let a = s.submit(0.0, "cori", 4, 1e4);
        s.pump(20.0); // a running, pool full
        let b = s.submit(20.0, "cori", 4, 1e4);
        assert_eq!(s.status(21.0, b), AllocStatus::Queued);
        s.delete(22.0, b);
        assert_eq!(s.status(23.0, b), AllocStatus::Killed);
        let _ = a;
    }
}
