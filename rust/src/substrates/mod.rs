//! Facility substrates: everything the paper's evaluation ran *on*.
//!
//! The paper used real DOE infrastructure — Theta/Summit/Cori, the
//! APS/ALS light sources, ESNet, Globus Transfer, and the Cobalt/Slurm/
//! LSF batch schedulers. None of that is reachable from this repo, so
//! each piece is rebuilt as a calibrated simulator (constants in
//! [`facility`], sources cited in DESIGN.md §6). The site agent talks to
//! these through the same *platform interfaces* it uses for the real
//! backends in real-time mode, so no coordinator code knows whether it is
//! driving a simulator or the real thing.

pub mod facility;
pub mod netsim;
pub mod globus;
pub mod batchsim;
